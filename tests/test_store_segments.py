"""Segmented campaign stores: segment + manifest layout, incremental merge
(segment adoption, O(new segments) — asserted by counting bytes actually
parsed), orphan healing, compaction, layout guards, and the fleet riding on
``store_format: "segments"`` with a report byte-identical to the legacy
single-process reference."""
import json
import os

import pytest

from repro.core import (CampaignStore, CampaignStoreError, compact_store,
                        io_tally, is_segmented, manifest_status, merge_stores,
                        remove_store, segments_dir, store_exists)
from repro.core.segments import load_manifest, save_manifest


def _fill(path, region, ks, *, segmented=True, mode="m"):
    st = CampaignStore(path, segmented=segmented)
    st.append({"kind": "meta", "region": region, "mode": mode, "reps": 2,
               "compile_once": True})
    for k in ks:
        st.append({"kind": "point", "region": region, "mode": mode,
                   "k": k, "t": 1e-3 * (k + 1)})
    st.append({"kind": "done", "region": region, "mode": mode,
               "ks": list(ks), "drift": None, "stopped_early": False,
               "payload": None})
    st.close()
    return st


def _segment_files(path):
    sdir = segments_dir(path)
    return sorted(n for n in os.listdir(sdir) if n.endswith(".jsonl"))


# ---------------------------------------------------------------------------
# layout + session lifecycle
# ---------------------------------------------------------------------------

def test_segmented_roundtrip_one_segment_per_session(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "rA", [0, 2])
    _fill(path, "rB", [0, 4])
    assert is_segmented(path) and store_exists(path)
    assert not os.path.exists(path)          # the path is a NAME, not a file
    assert len(_segment_files(path)) == 2    # one sealed segment per session
    st = CampaignStore(path, readonly=True)
    st.close()
    assert st.stored_ts("rA", "m") == {0: 1e-3, 2: 3e-3}
    assert st.pair_status("rB", "m").complete
    m = load_manifest(segments_dir(path))
    assert [e["records"] for e in m["segments"]] == [4, 4]
    # per-segment pair coverage rides in the manifest (fleet watch's food)
    assert m["segments"][0]["pairs"] == [
        {"region": "rA", "mode": "m", "points": 2, "done": True}]


def test_segmented_supersede_across_segments(tmp_path):
    """Later segments supersede earlier ones at read time — same rule as
    later lines in a legacy file, including the meta-conflict discard."""
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0, 2])
    st = CampaignStore(path, segmented=True)
    st.append({"kind": "meta", "region": "r", "mode": "m", "reps": 5,
               "compile_once": True})        # conflicting settings
    st.close()
    st = CampaignStore(path, readonly=True)
    st.close()
    assert st.meta[("r", "m")]["reps"] == 5
    assert not st.points and not st.done     # discarded by the conflict


def test_layout_guards(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0], segmented=False)   # legacy file
    with pytest.raises(CampaignStoreError, match="legacy single-file"):
        CampaignStore(path, segmented=True)
    seg = str(tmp_path / "t.jsonl")
    _fill(seg, "r", [0])
    with pytest.raises(CampaignStoreError, match="segment"):
        CampaignStore(seg, segmented=False)
    # both layouts at one path: ambiguous, refuse
    with open(seg, "w") as f:
        f.write("")
    with pytest.raises(CampaignStoreError, match="both"):
        CampaignStore(seg)
    with pytest.raises(CampaignStoreError, match="both"):
        merge_stores(seg, [path])
    # readonly never creates either layout
    with pytest.raises(FileNotFoundError):
        CampaignStore(str(tmp_path / "absent.jsonl"), readonly=True,
                      segmented=True)
    assert not store_exists(str(tmp_path / "absent.jsonl"))


def test_remove_store_removes_either_layout(tmp_path):
    seg = str(tmp_path / "seg.jsonl")
    leg = str(tmp_path / "leg.jsonl")
    _fill(seg, "r", [0])
    _fill(leg, "r", [0], segmented=False)
    remove_store(seg)
    remove_store(leg)
    assert not store_exists(seg) and not store_exists(leg)


# ---------------------------------------------------------------------------
# corruption policy: checksummed manifest, immutable sealed segments
# ---------------------------------------------------------------------------

def test_manifest_checksum_detects_edits(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0, 2])
    mpath = os.path.join(segments_dir(path), "MANIFEST.json")
    m = json.load(open(mpath))
    m["segments"][0]["records"] = 999        # hand-edit without re-checksum
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(CampaignStoreError, match="checksum"):
        CampaignStore(path, readonly=True)


def test_missing_sealed_segment_file_hard_fails(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0, 2])
    os.unlink(os.path.join(segments_dir(path), _segment_files(path)[0]))
    with pytest.raises(CampaignStoreError, match="missing"):
        CampaignStore(path, readonly=True)


def test_mutated_sealed_segment_hard_fails(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0, 2])
    fp = os.path.join(segments_dir(path), _segment_files(path)[0])
    with open(fp, "a") as f:                 # sealed segments are immutable
        f.write('{"kind": "point", "region": "x", "mode": "m", '
                '"k": 9, "t": 1.0}\n')
    with pytest.raises(CampaignStoreError, match="immutable"):
        CampaignStore(path, readonly=True)


# ---------------------------------------------------------------------------
# orphan healing: writable opens heal, readonly opens tolerate
# ---------------------------------------------------------------------------

def _orphan_with_torn_tail(path):
    """An unsealed segment (writer died before sealing) with a torn tail."""
    good = json.dumps({"kind": "point", "region": "rO", "mode": "m",
                       "k": 7, "t": 2e-3})
    fp = os.path.join(segments_dir(path), "000099-dead-writer.jsonl")
    with open(fp, "wb") as f:
        f.write((good + "\n").encode() + good.encode()[:-9])
    return fp


def test_orphan_heals_on_writable_open_only(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0])
    fp = _orphan_with_torn_tail(path)
    before = os.path.getsize(fp)
    ro = CampaignStore(path, readonly=True)  # tolerate: replay, touch nothing
    ro.close()
    assert ro.stored_ts("rO", "m") == {7: 2e-3}
    assert os.path.getsize(fp) == before
    assert len(load_manifest(segments_dir(path))["segments"]) == 1
    st = CampaignStore(path)                 # writable: truncate + seal
    st.close()
    assert st.stored_ts("rO", "m") == {7: 2e-3}
    assert os.path.getsize(fp) < before      # torn tail truncated away
    m = load_manifest(segments_dir(path))
    assert [e["id"] for e in m["segments"]][-1] == "000099-dead-writer"
    assert manifest_status(path)["orphans"] == 0


def test_folded_orphan_is_garbage_not_data(tmp_path):
    """A segment id in ``folded`` whose file reappears (interrupted
    compaction cleanup) must be deleted, never replayed — its records
    already live in the compacted segment."""
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0])
    sdir = segments_dir(path)
    m = load_manifest(sdir)
    m["folded"] = ["000050-stale"]
    save_manifest(sdir, m)
    fp = os.path.join(sdir, "000050-stale.jsonl")
    with open(fp, "w") as f:
        f.write(json.dumps({"kind": "point", "region": "zombie", "mode": "m",
                            "k": 0, "t": 1.0}) + "\n")
    st = CampaignStore(path)
    st.close()
    assert ("zombie", "m") not in st.points
    assert not os.path.exists(fp)            # writable open deleted it


# ---------------------------------------------------------------------------
# incremental merge: O(new segments), idempotent, compaction-aware
# ---------------------------------------------------------------------------

def test_incremental_merge_reads_only_new_segments(tmp_path):
    """THE acceptance property: folding one new worker segment into an
    N-segment canonical store parses exactly the new segment's bytes —
    never the destination's, never an already-adopted source's."""
    dest = str(tmp_path / "canon.jsonl")
    for i in range(8):
        _fill(dest, f"r{i}", [0, 2, 4])
    w1 = str(tmp_path / "w1.jsonl")
    _fill(w1, "w1", [0, 2])
    merge_stores(dest, [w1])                 # adopt worker 1
    w2 = str(tmp_path / "w2.jsonl")
    _fill(w2, "w2", [0, 2])
    w2_bytes = sum(e["bytes"]
                   for e in load_manifest(segments_dir(w2))["segments"])
    io_tally(reset=True)
    stats = merge_stores(dest, [dest, w1, w2])
    tally = io_tally()
    assert stats.incremental
    assert stats.segments_new == 1           # only w2's segment is new
    assert stats.segments_skipped == 1       # w1's: skipped WITHOUT reading
    assert tally["records"] == 4             # w2's meta + 2 points + done
    assert tally["bytes"] == w2_bytes        # not one canonical byte parsed
    assert "folded 1 new segment(s)" in str(stats)
    st = CampaignStore(dest, readonly=True)
    st.close()
    assert len(st.done) == 10                # 8 canonical + both workers


def test_incremental_merge_idempotent_and_dest_as_source(tmp_path):
    dest = str(tmp_path / "canon.jsonl")
    _fill(dest, "r", [0])
    w = str(tmp_path / "w.jsonl")
    _fill(w, "w", [0])
    s1 = merge_stores(dest, [dest, w])
    assert (s1.segments_new, s1.segments_skipped) == (1, 0)
    s2 = merge_stores(dest, [dest, w])       # re-merge: nothing new
    assert (s2.segments_new, s2.segments_skipped) == (0, 1)
    files = _segment_files(dest)
    s3 = merge_stores(dest, [dest])          # self-merge: a no-op
    assert s3.segments_new == 0
    assert _segment_files(dest) == files


def test_incremental_merge_adopts_legacy_snapshot_once(tmp_path):
    """A legacy single-file source folds in as ONE content-addressed
    snapshot segment; re-merging the unchanged file is a no-op, a GROWN
    file is re-adopted and supersedes at read time."""
    dest = str(tmp_path / "canon.jsonl")
    _fill(dest, "r", [0])
    leg = str(tmp_path / "leg.jsonl")
    _fill(leg, "L", [0, 2], segmented=False)
    assert merge_stores(dest, [leg]).segments_new == 1
    assert merge_stores(dest, [leg]).segments_new == 0      # unchanged
    with open(leg, "a") as f:
        f.write(json.dumps({"kind": "point", "region": "L", "mode": "m",
                            "k": 8, "t": 9e-3}) + "\n")
    assert merge_stores(dest, [leg]).segments_new == 1      # grown: new snap
    st = CampaignStore(dest, readonly=True)
    st.close()
    assert st.stored_ts("L", "m")[8] == 9e-3


def test_incremental_merge_refuses_legacy_dest_file(tmp_path):
    dest = str(tmp_path / "canon.jsonl")
    _fill(dest, "r", [0], segmented=False)
    src = str(tmp_path / "w.jsonl")
    _fill(src, "w", [0])
    with pytest.raises(CampaignStoreError, match="legacy store file"):
        merge_stores(dest, [src], incremental=True)
    # but the auto dispatch keeps a legacy dest on the legacy path
    stats = merge_stores(dest, [dest, src])
    assert not stats.incremental
    st = CampaignStore(dest, readonly=True)
    st.close()
    assert ("r", "m") in st.done and ("w", "m") in st.done


def test_compact_collapses_and_future_merges_skip_folded(tmp_path):
    dest = str(tmp_path / "canon.jsonl")
    w = str(tmp_path / "w.jsonl")
    _fill(dest, "r", [0, 2])
    _fill(dest, "r", [0, 2])                 # superseded duplicate session
    _fill(w, "w", [0])
    merge_stores(dest, [dest, w])
    cstats = compact_store(dest)
    assert cstats.segments_in == 3 and cstats.records_in == 11
    assert cstats.records_out == 7           # one r sweep + one w sweep
    assert len(_segment_files(dest)) == 1
    assert "reclaimed" in str(cstats)
    st = CampaignStore(dest, readonly=True)
    st.close()
    assert st.pair_status("r", "m").complete
    assert st.pair_status("w", "m").complete
    # the original sources fold to nothing: their ids live in ``folded``
    s = merge_stores(dest, [dest, w])
    assert (s.segments_new, s.segments_skipped) == (0, 1)
    # and compacting a compacted store is a no-op shape (1 segment in/out)
    assert compact_store(dest).segments_in == 1


def test_compact_legacy_store_rewrites_canonical(tmp_path):
    path = str(tmp_path / "s.jsonl")
    _fill(path, "r", [0], segmented=False)
    _fill(path, "r", [0], segmented=False)   # superseded duplicate
    cstats = compact_store(path)
    assert cstats.records_in == 6 and cstats.records_out == 3
    assert not is_segmented(path)
    with pytest.raises(FileNotFoundError):
        compact_store(str(tmp_path / "absent.jsonl"))


def test_segmented_flatten_byte_identical_to_legacy(tmp_path):
    """Deterministic twin of the hypothesis property: the same stream in
    both layouts flattens to the byte-identical canonical file."""
    leg = str(tmp_path / "leg.jsonl")
    seg = str(tmp_path / "seg.jsonl")
    for region, ks in (("rA", [0, 2]), ("rB", [0, 4])):
        _fill(leg, region, ks, segmented=False)
        _fill(seg, region, ks)
    fl, fs = str(tmp_path / "fl.jsonl"), str(tmp_path / "fs.jsonl")
    merge_stores(fl, [leg], incremental=False)
    merge_stores(fs, [seg], incremental=False)
    assert open(fl).read() == open(fs).read()


def test_campaign_cli_compact_and_merge_canonical(tmp_path, capsys):
    from repro.core.campaign import _cli

    seg = str(tmp_path / "seg.jsonl")
    _fill(seg, "r", [0, 2])
    _fill(seg, "r", [0, 2])
    assert _cli(["compact", seg]) == 0
    assert "compacted 8 -> 4 record(s)" in capsys.readouterr().out
    flat = str(tmp_path / "flat.jsonl")
    assert _cli(["merge", "--canonical", flat, seg]) == 0
    assert os.path.isfile(flat) and not is_segmented(flat)
    assert _cli(["compact", str(tmp_path / "absent.jsonl")]) == 2


# ---------------------------------------------------------------------------
# the fleet on store_format: "segments"
# ---------------------------------------------------------------------------

@pytest.fixture
def synth_measure(monkeypatch):
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")


def _plan(tmp_path, *, shards=2, stem="segfleet", store_format="segments",
          launcher=None, save=True):
    from repro.fleet.plan import SweepPlan, TargetSpec

    plan = SweepPlan(
        name="fleet_probe", store=str(tmp_path / stem / "store.jsonl"),
        targets=[TargetSpec("pallas", ("fp", "mxu"),
                            {"kernel": "probe", "sizes": [8]})],
        reps=2, shards=shards, backend="interpret",
        store_format=store_format, launcher=launcher)
    path = str(tmp_path / f"{stem}_plan.json")
    if save:
        plan.save(path)
    return plan, path


def test_plan_store_format_validation(tmp_path):
    from repro.fleet.plan import PlanError, SweepPlan

    plan, path = _plan(tmp_path)
    assert SweepPlan.load(path).store_format == "segments"
    legacy_plan, _ = _plan(tmp_path, stem="legacyfmt", store_format=None)
    assert plan.digest() != legacy_plan.digest()    # the layout is pinned
    with pytest.raises(PlanError, match="one of"):
        _plan(tmp_path, stem="badfmt", store_format="parquet",
              save=False)[0].validate()
    ssh_plan, _ = _plan(tmp_path, stem="sshfmt", save=False,
                        launcher={"kind": "ssh", "hosts": [{"addr": "n0"}]})
    with pytest.raises(PlanError, match="single-file staging"):
        ssh_plan.validate()


def test_segmented_fleet_matches_legacy_single_process(tmp_path,
                                                       synth_measure):
    """Acceptance: an N=2 fleet writing SEGMENTED stores end-to-end (worker
    stores and canonical store) produces a report byte-identical to the
    same plan run single-process on the LEGACY layout."""
    from repro.fleet.executor import in_process_launcher, run_fleet, \
        run_worker
    from repro.fleet.plan import SweepPlan

    plan, path = _plan(tmp_path)
    res = run_fleet(path, launcher=in_process_launcher)
    assert res.launched == [0, 1]
    assert is_segmented(plan.store)
    assert all(is_segmented(ws) for ws in plan.worker_stores())
    assert res.state.merge.get("segments_new", 0) >= 2   # one per worker
    report = open(plan.report_path(), "rb").read()

    single, single_path = _plan(tmp_path, stem="legacy_ref", shards=1,
                                store_format=None)
    run_worker(SweepPlan.load(single_path))
    assert not is_segmented(single.store)
    assert open(single.report_path(), "rb").read() == report

    # a completed segmented fleet replays with zero measurements and the
    # incremental re-merge adopts nothing new
    res2 = run_fleet(path, resume=True, expect_no_measure=True)
    assert res2.launched == []
    assert res2.state.merge.get("segments_new") == 0


def test_segmented_fleet_crash_heal_and_drop_point(tmp_path, synth_measure):
    """The mock launcher's fault injection speaks segments: a 'crash' tears
    the worker's done-bearing segment back into an unsealed orphan, a
    resume heals it and re-measures only the torn point."""
    from repro.fleet.executor import run_fleet
    from repro.fleet.launchers import (MockClusterLauncher, drop_done_point,
                                       tear_store_tail)
    from repro.fleet.executor import FleetError, in_process_launcher

    plan, path = _plan(tmp_path, stem="segcrash")
    with pytest.raises(FleetError, match=r"shard\(s\) \[0\]"):
        run_fleet(path, launcher=MockClusterLauncher({0: ("crash",)}))
    ws = plan.worker_stores()[0]
    assert manifest_status(ws)["orphans"] == 1          # torn, unsealed
    res = run_fleet(path, resume=True, launcher=in_process_launcher)
    assert res.launched == [0]
    wstats = json.load(open(ws + ".stats.json"))
    assert wstats["measured"] == 1                      # only the torn point
    assert wstats["cached"] > 0

    # drop-point: store stays structurally valid, exactly one k missing
    drop_done_point(ws)
    st = CampaignStore(ws, readonly=True)
    st.close()
    bad = [ps for ps in st.grid_status(plan.grid()).values()
           if ps.done and not ps.complete]
    assert len(bad) == 1 and len(bad[0].missing) == 1

    # a segmented store with no done marker refuses both faults cleanly
    nodone = str(tmp_path / "nodone.jsonl")
    st = CampaignStore(nodone, segmented=True)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 1.0})
    st.close()
    with pytest.raises(FleetError, match="no done-promised point"):
        drop_done_point(nodone)
    with pytest.raises(FleetError, match="no done-marked sweep"):
        tear_store_tail(nodone)


def test_fleet_watch_once(tmp_path, synth_measure, capsys):
    from repro.fleet.cli import main
    from repro.fleet.executor import in_process_launcher, run_fleet

    plan, path = _plan(tmp_path, stem="watch")
    assert main(["watch", "--plan", path, "--once"]) == 1   # nothing yet
    out = capsys.readouterr().out
    assert "fleet watch" in out and "absent" in out
    assert "0/2 pair(s) done" in out
    run_fleet(path, launcher=in_process_launcher)
    assert main(["watch", "--plan", path, "--once"]) == 0
    out = capsys.readouterr().out
    assert "sealed segment(s)" in out
    assert "2/2 pair(s) done" in out


def test_fleet_cli_plan_writes_store_format(tmp_path, synth_measure):
    from repro.fleet.cli import main
    from repro.fleet.plan import SweepPlan

    out_plan = str(tmp_path / "p.json")
    store = str(tmp_path / "cli" / "store.jsonl")
    assert main(["plan", "--out", out_plan, "--pallas", "probe",
                 "--sizes", "8", "--modes", "fp", "--shards", "1",
                 "--backend", "interpret", "--store", store,
                 "--store-format", "segments"]) == 0
    assert SweepPlan.load(out_plan).store_format == "segments"
    assert main(["run", "--plan", out_plan, "--in-process"]) == 0
    assert is_segmented(store)
    assert main(["status", "--plan", out_plan]) == 0

"""SPMXV regime-transition harness: sweep the spmv_ell swap-probability
axis as a pallas family under the deterministic synthetic clock and pin
where the verdict flips.

Fig. 7's point is that one kernel CROSSES regimes as its fill pattern
degrades: the band matrix (q=0) is compute-shaped, heavy swapping (q=1)
is load/store-bound. The real crossover depends on the machine; this
harness forces it deterministically — each family member's modes run
under per-q ``SynthShape`` clocks (fp absorption grows with q, vmem
absorption collapses with q) so the classifier sees a kernel marching
from the compute corner through the mixed middle into the LSU corner:

    q:        0.0       0.25     0.5      0.75     1.0
    verdict:  compute   mixed    mixed    l1       l1

The whole (q -> label, confidence, Abs^raw) map is golden-pinned in
``tests/golden/regimes.json`` (regenerate via tests/golden/regen.py and
say why in the commit); the transition point — the first q classified
``l1`` — is pinned at ``TRANSITION_Q`` on top of the map, so a classifier
or fit change that MOVES the crossover fails that assertion by name even
if someone regenerates the map without looking.
"""
import json
import os

import pytest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "regimes.json")

#: the swept swap probabilities and the pinned crossover (first ``l1`` q)
QS = (0.0, 0.25, 0.5, 0.75, 1.0)
TRANSITION_Q = 0.75

#: synthetic clock base seconds — any value works (the map stores Abs^raw,
#: which is scale-free); pinned so regen and test agree byte-for-byte
BASE_S = "1e-3"


def _forced_family():
    """The spmxv family over QS, each member's modes forced onto its per-q
    clock shape: fp knee 1 + 30q (fp noise absorbed ever deeper as swaps
    dilute the FLOP pressure), vmem knee max(0, 25 - 30q) (vmem slack
    collapsing as gather traffic takes over)."""
    from repro.core.absorption import SynthShape
    from repro.core.calibration import forced_regime
    from repro.kernels.region import pallas_family

    members = pallas_family("spmxv", [512], qs=list(QS), backend="interpret")
    out = []
    for q, base in zip(QS, members):
        shapes = {"fp": SynthShape(knee=1.0 + 30.0 * q, slope=0.2),
                  "vmem": SynthShape(knee=max(0.0, 25.0 - 30.0 * q),
                                     slope=0.2)}
        out.append((q, forced_regime(base, base.name, shapes)))
    return out


def sweep_regime_map(store_path: str) -> dict:
    """Run (or replay) the forced q-sweep into ``store_path`` and return
    the ordered {region: {q, label, confidence, absorptions}} map — the
    exact structure tests/golden/regimes.json pins. Requires the synthetic
    clock (callers set REPRO_SYNTH_MEASURE)."""
    from repro.core.campaign import Campaign
    from repro.core.controller import Controller

    camp = Campaign(store_path, Controller(reps=2, verify_payload=False))
    out = {}
    for q, target in _forced_family():
        rep = camp.characterize(target, ["fp", "vmem"])
        out[target.name] = {
            "q": q,
            "label": rep.bottleneck.label,
            "confidence": rep.bottleneck.confidence,
            "absorptions": {m: r.fit.k1 for m, r in rep.results.items()},
        }
    return out


@pytest.fixture(scope="module")
def regime_map(tmp_path_factory):
    os.environ.setdefault("REPRO_SYNTH_MEASURE", BASE_S)
    store = str(tmp_path_factory.mktemp("regimes") / "regimes.jsonl")
    try:
        return sweep_regime_map(store)
    finally:
        if os.environ.get("REPRO_SYNTH_MEASURE") == BASE_S:
            del os.environ["REPRO_SYNTH_MEASURE"]


def test_verdict_flips_at_the_pinned_transition(regime_map):
    labels = [(cell["q"], cell["label"]) for cell in regime_map.values()]
    assert [q for q, _ in labels] == list(QS)          # sweep order kept
    flips = [q for q, label in labels if label == "l1"]
    assert flips, "the sweep never reached the LSU regime"
    assert flips[0] == TRANSITION_Q
    # l1 is absorbing: once crossed, the verdict stays
    assert flips == [q for q, _ in labels if q >= TRANSITION_Q]
    # and the walk starts in the compute corner, through the mixed middle
    assert labels[0][1] == "compute"
    assert {label for q, label in labels
            if 0.0 < q < TRANSITION_Q} == {"mixed"}


def test_regime_map_matches_golden(regime_map):
    if not os.path.exists(GOLDEN):
        pytest.fail(f"{GOLDEN} missing — generate via "
                    "PYTHONPATH=src python tests/golden/regen.py")
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert list(regime_map) == list(golden), \
        "family names changed — regenerate regimes.json and say why"
    for region, want in golden.items():
        got = regime_map[region]
        assert got["label"] == want["label"], region
        assert got["q"] == pytest.approx(want["q"]), region
        assert got["confidence"] == pytest.approx(want["confidence"]), region
        assert set(got["absorptions"]) == set(want["absorptions"]), region
        for mode, k1 in want["absorptions"].items():
            assert got["absorptions"][mode] == pytest.approx(k1), \
                f"{region}/{mode}"


def test_regime_sweep_replays_deterministically(regime_map, tmp_path):
    """The same sweep into a fresh store reproduces the map exactly — the
    synthetic clock is a function of (mode, k, shape), nothing else."""
    os.environ.setdefault("REPRO_SYNTH_MEASURE", BASE_S)
    try:
        again = sweep_regime_map(str(tmp_path / "again.jsonl"))
    finally:
        if os.environ.get("REPRO_SYNTH_MEASURE") == BASE_S:
            del os.environ["REPRO_SYNTH_MEASURE"]
    assert again == regime_map

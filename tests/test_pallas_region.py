"""The Pallas layer on the Controller/Campaign spine: compile-count
guarantees (≤2 executables per (kernel, mode) sweep), oracle payload
verification, campaign persist/replay with zero new measurements, and
multi-size families sharing one store namespace."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Campaign, Controller
from repro.kernels.region import (KERNEL_MODES, family_names, pallas_family,
                                  pallas_region, validate_size)


def _counting_region(kernel, **sizes):
    traces = {"n": 0}
    region = pallas_region(
        kernel, backend="interpret",
        trace_hook=lambda: traces.__setitem__("n", traces["n"] + 1), **sizes)
    return region, traces


# small interpret-mode shapes so sweeps stay fast
SIZES = {
    "matmul": {"n": 128},
    "spmxv": {"n": 256},
    "attention": {"seq": 128, "heads": 2, "kv_heads": 2, "bq": 64, "bk": 64},
    "probe": {"n_steps": 8},
}


@pytest.mark.parametrize("kernel", sorted(KERNEL_MODES))
def test_pallas_sweep_compiles_at_most_two_per_mode(kernel):
    """Acceptance: a full k-sweep over a Pallas region builds ≤2 executables
    per (kernel, mode) — the runtime-k sweep + the static payload check —
    extending the ≤2-executables guarantee from the loop/graph layers."""
    region, traces = _counting_region(kernel, **SIZES[kernel])
    ctl = Controller(reps=2, compile_once=True)
    before = 0
    for mode in KERNEL_MODES[kernel]:
        res = ctl.run_mode(region, mode, ks=(0, 1, 2, 4, 8, 16))
        built = traces["n"] - before
        before = traces["n"]
        assert built <= 2, f"{kernel}/{mode}: {built} executables for a sweep"
        assert len(res.curve.ks) >= 3
        assert res.injection is not None          # oracle payload check ran
        assert res.injection.payload == res.injection.expected > 0


def test_pallas_fallback_compiles_per_k():
    region, traces = _counting_region("probe", n_steps=8)
    ctl = Controller(reps=2, compile_once=False, verify_payload=False,
                     stop_ratio=100.0)
    ctl.run_mode(region, "fp", ks=(0, 2, 4, 8))
    assert traces["n"] >= 4          # the paper's cost model: one per k


def test_pallas_static_and_runtime_sweeps_agree():
    """A/B: both sweep paths measure the same program (payload verdicts
    identical; fit fields exist on both)."""
    region, _ = _counting_region("spmxv", n=256)
    ks = (0, 2, 4, 8)
    fast = Controller(reps=2, compile_once=True, stop_ratio=100.0)
    slow = Controller(reps=2, compile_once=False, stop_ratio=100.0)
    r_fast = fast.run_mode(region, "fp", ks=ks)
    r_slow = slow.run_mode(region, "fp", ks=ks)
    assert r_fast.curve.ks[:3] == r_slow.curve.ks[:3] == [0, 2, 4]
    assert r_fast.injection.payload == r_slow.injection.payload
    assert r_fast.fit.t0 > 0 and r_slow.fit.t0 > 0


def test_pallas_payload_check_oracle():
    """The Pallas payload check verifies the nacc oracle on a static trace:
    full survival for every supported mode, reported per the §2.3 schema."""
    region, _ = _counting_region("matmul", n=128)
    for mode in KERNEL_MODES["matmul"]:
        rep = region.payload_check(mode, 6)
        assert rep.expected == rep.payload == 6
        assert rep.overhead == 0 and rep.survival_fraction == 1.0
        assert rep.ok()


def test_pallas_region_rejects_unknown_mode():
    region, _ = _counting_region("spmxv", n=256)
    with pytest.raises(ValueError, match="supports noise modes"):
        region.build("mxu", 2)       # spmv has no noise operand -> no mxu
    with pytest.raises(ValueError, match="unknown pallas kernel"):
        pallas_region("nope")


def test_pallas_campaign_replays_with_zero_measurements(tmp_path):
    """Acceptance: a completed Pallas campaign replays from its store with
    ZERO new measurements, zero compiles, and identical classification."""
    store = str(tmp_path / "pallas.jsonl")
    modes = list(KERNEL_MODES["spmxv"])

    region1, _ = _counting_region("spmxv", n=256)
    c1 = Campaign(store, Controller(reps=2))
    rep1 = c1.characterize(region1, modes)
    assert c1.stats.measured > 0

    region2, traces2 = _counting_region("spmxv", n=256)
    c2 = Campaign(store, Controller(reps=2))
    rep2 = c2.characterize(region2, modes)
    assert c2.stats.measured == 0
    assert traces2["n"] == 0                      # not even a compile
    assert rep2.bottleneck.label == rep1.bottleneck.label
    for m in modes:
        assert rep2.results[m].curve.ks == rep1.results[m].curve.ks
        assert rep2.results[m].curve.ts == rep1.results[m].curve.ts
        assert rep2.results[m].injection.payload \
            == rep1.results[m].injection.payload


def test_pallas_region_clean_build_is_noise_free():
    region, _ = _counting_region("matmul", n=128)
    out, nacc = region.build("", 0)(*region.args_for("", 0))
    assert out.shape == (128, 128)
    np.testing.assert_array_equal(np.asarray(nacc), 0.0)


def test_pallas_family_spans_sizes_and_q_grid():
    """One family call yields one RegionTarget per size (× q for spmxv),
    each with a distinct name — the store-namespace contract."""
    fam = pallas_family("probe", [8, 16], backend="interpret")
    assert [r.name for r in fam] == ["pallas_probe_s8", "pallas_probe_s16"]
    fam = pallas_family("spmxv", [256], qs=[0.0, 1.0], backend="interpret")
    assert [r.name for r in fam] == ["pallas_spmxv_n256_L16_q0",
                                     "pallas_spmxv_n256_L16_q1"]
    with pytest.raises(ValueError, match="spmxv"):
        pallas_family("matmul", [128], qs=[0.0], backend="interpret")
    with pytest.raises(ValueError, match="multiple"):
        pallas_family("matmul", [129], backend="interpret")
    with pytest.raises(ValueError, match="collide"):
        pallas_family("probe", [8, 8], backend="interpret")
    with pytest.raises(ValueError, match="unknown pallas kernel"):
        validate_size("nope", 8)


@pytest.mark.parametrize("kernel,sizes,qs,extra", [
    ("matmul", [128, 256], None, {}),
    ("spmxv", [256], [0.0, 0.25, 1.0], {"nnz_per_row": 8}),
    ("attention", [64, 128], None, {"heads": 4}),
    ("probe", [8, 64], None, {}),
])
def test_family_names_agree_with_built_regions(kernel, sizes, qs, extra):
    """``family_names`` (the cheap, build-nothing grid query) must produce
    exactly the names ``pallas_family`` builds — including every default the
    namers duplicate from the spec builders' signatures."""
    names = family_names(kernel, sizes, qs=qs, **extra)
    built = pallas_family(kernel, sizes, qs=qs, backend="interpret", **extra)
    assert names == [r.name for r in built]


def test_family_rejects_unknown_spec_params():
    with pytest.raises(ValueError, match="does not accept"):
        pallas_family("matmul", [128], nnz_per_row=8, backend="interpret")
    with pytest.raises(ValueError, match="does not accept"):
        family_names("probe", [8], causal=True)


def test_pallas_family_shares_one_campaign_store(tmp_path, monkeypatch):
    """Acceptance (ROADMAP): a single campaign store holds a kernel's whole
    size grid and replays every member with zero new measurements."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    store = str(tmp_path / "family.jsonl")
    fam = pallas_family("probe", [8, 16], backend="interpret")
    c1 = Campaign(store, Controller(reps=2))
    for region in fam:
        c1.characterize(region, ["fp"])
    assert c1.stats.measured > 0

    fam2 = pallas_family("probe", [8, 16], backend="interpret")
    c2 = Campaign(store, Controller(reps=2))
    reps = {r.name: c2.characterize(r, ["fp"]) for r in fam2}
    assert c2.stats.measured == 0              # whole family replayed
    assert set(reps) == {"pallas_probe_s8", "pallas_probe_s16"}


def test_pallas_rt_callable_is_memoized_on_controller():
    """The controller's _rt_cache must hand the sensitivity probe and the
    sweep the SAME Pallas executable (one compile, not two)."""
    region, traces = _counting_region("probe", n_steps=8)
    ctl = Controller(reps=2, verify_payload=False)
    fn = ctl._rt_fn(region, "fp")
    assert fn is ctl._rt_fn(region, "fp")
    fn(jnp.int32(2), *region.args_for_rt("fp"))
    assert traces["n"] == 1

"""Pluggable launchers + retry budgets: the MockClusterLauncher fault paths
(scripted crash -> retried shard heals its torn store and re-measures only
the missing points, final report byte-identical to a clean single-process
run; attempts-exhausted exits nonzero and fleet.json says why), the
per-shard lifetime cap, the fleet doctor's diagnosis, the SSH launcher's
command construction and its documented degrade when ssh is missing, and
the launcher->worker plan-digest handshake.

Measurement determinism: REPRO_SYNTH_MEASURE (the deterministic stand-in
clock) makes independently-run shards byte-comparable."""
import json
import os

import pytest

from repro.core.campaign import host_store, read_store_records
from repro.fleet.executor import (FleetError, FleetState, fleet_doctor,
                                  run_fleet, run_worker)
from repro.fleet.launchers import (MANUAL_RECIPE, HostSpec, LocalLauncher,
                                   MockClusterLauncher, RetryBudget,
                                   SSHLauncher, load_hosts, resolve_launcher,
                                   tear_store_tail)
from repro.fleet.plan import PlanError, SweepPlan, TargetSpec


@pytest.fixture
def synth_measure(monkeypatch):
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")


def _plan(tmp_path, *, shards=2, modes=("fp", "mxu"), sizes=(8,),
          name="fleet_probe", stem="fleet", launcher=None, retry=None):
    plan = SweepPlan(
        name=name, store=str(tmp_path / stem / "store.jsonl"),
        targets=[TargetSpec("pallas", tuple(modes),
                            {"kernel": "probe", "sizes": list(sizes)})],
        reps=2, shards=shards, backend="interpret",
        launcher=launcher, retry=retry)
    path = str(tmp_path / f"{stem}_plan.json")
    plan.save(path)
    return plan, path


# ---------------------------------------------------------------------------
# RetryBudget
# ---------------------------------------------------------------------------

def test_retry_budget_validation_and_backoff():
    b = RetryBudget(max_attempts=3, backoff=0.5, per_shard_cap=5)
    assert b.delay(1) == 0.0                       # first round: no wait
    assert b.delay(2) == 0.5
    assert b.delay(3) == 1.0                       # doubles per round
    assert RetryBudget.from_dict(None) == RetryBudget()
    assert RetryBudget.from_dict({"max_attempts": 2}).max_attempts == 2
    with pytest.raises(FleetError, match="max_attempts"):
        RetryBudget(max_attempts=0)
    with pytest.raises(FleetError, match="unknown retry setting"):
        RetryBudget.from_dict({"attempts": 2})


# ---------------------------------------------------------------------------
# MockClusterLauncher: the scripted-fault multi-host path on one machine
# ---------------------------------------------------------------------------

def test_mock_crash_retries_heal_and_match_single_process(tmp_path,
                                                          synth_measure):
    """Tentpole acceptance: shard 0's first attempt crashes (torn worker
    store); within ONE run the retry budget re-launches only shard 0, the
    store heals, only the missing point is re-measured, and the final
    report is byte-identical to a clean single-process run."""
    plan, path = _plan(tmp_path, stem="mockcrash",
                       launcher={"kind": "mock", "script": {"0": ["crash"]}},
                       retry={"max_attempts": 2})
    res = run_fleet(path)
    assert res.launched == [0, 1]
    s0 = res.state.shards[0]
    assert s0.attempts == 2
    assert [a["rc"] for a in s0.attempt_log] == [-9, 0]
    assert [a["launcher"] for a in s0.attempt_log] == ["mock", "mock"]
    assert s0.attempt_log[0]["host"] == "mock-host-0"
    heal = s0.attempt_log[1]
    assert heal["measured"] == 1 and heal["cached"] > 0   # healed, not redone
    assert res.state.shards[1].attempts == 1

    single, single_path = _plan(tmp_path, stem="mockcrash_ref", shards=1)
    run_worker(SweepPlan.load(single_path))
    assert open(plan.report_path(), "rb").read() \
        == open(single.report_path(), "rb").read()

    # completed fleet replays free, launching nothing
    res2 = run_fleet(path, resume=True, expect_no_measure=True)
    assert res2.launched == []


def test_mock_attempts_exhausted_exits_nonzero_and_ledger_says_why(
        tmp_path, synth_measure):
    """Satellite: a shard that fails every allowed attempt -> nonzero exit
    through the CLI, and fleet.json records each attempt (launcher, host,
    rc) with status 'failed'."""
    from repro.fleet.cli import main

    plan, path = _plan(tmp_path, stem="mockdead",
                       launcher={"kind": "mock",
                                 "script": {"0": ["dead", "timeout"]}},
                       retry={"max_attempts": 2})
    with pytest.raises(SystemExit) as ei:
        main(["run", "--plan", path])
    assert "did not complete after 2 attempt round" in str(ei.value)
    state = FleetState.load(plan.fleet_path())
    s0 = state.shards[0]
    assert s0.status == "failed"
    assert [a["rc"] for a in s0.attempt_log] == [1, 124]
    # attempts whose worker never ran must not inherit stale heal stats
    assert all(a["measured"] is None and a["cached"] is None
               for a in s0.attempt_log)
    assert state.shards[1].status == "done"


def test_per_shard_cap_marks_shard_exhausted(tmp_path, synth_measure):
    """A shard may not burn the budget forever: once its LIFETIME attempts
    hit per_shard_cap, resume refuses to relaunch it and the ledger says
    'exhausted'."""
    plan, path = _plan(tmp_path, stem="capped",
                       launcher={"kind": "mock",
                                 "script": {"1": ["dead", "dead", "dead"]}},
                       retry={"per_shard_cap": 2})
    with pytest.raises(FleetError, match="did not complete"):
        run_fleet(path)
    # attempt 2 also fails; the cap is now reached, mid-run
    with pytest.raises(FleetError, match="per-shard attempt cap"):
        run_fleet(path, resume=True)
    # a further resume refuses to launch the shard at all
    with pytest.raises(FleetError, match="per-shard attempt cap"):
        run_fleet(path, resume=True)
    state = FleetState.load(plan.fleet_path())
    assert state.shards[1].status == "exhausted"
    assert state.shards[1].attempts == 2
    code, report = fleet_doctor(plan)
    assert code == 1
    assert "attempts exhausted" in report


def test_mock_attempt_ordinals_follow_the_ledger_across_resumes(
        tmp_path, synth_measure):
    """The executor passes LIFETIME attempt ordinals to the launcher, so a
    fault script stays deterministic across --resume runs: attempt 2 in a
    fresh process still reads script[1]."""
    plan, path = _plan(tmp_path, stem="ordinal",
                       launcher={"kind": "mock",
                                 "script": {"0": ["crash", "dead"]}})
    with pytest.raises(FleetError):
        run_fleet(path)                                   # attempt 1: crash
    with pytest.raises(FleetError):
        run_fleet(path, resume=True)                      # attempt 2: dead
    state = FleetState.load(plan.fleet_path())
    log = state.shards[0].attempt_log
    assert [a["rc"] for a in log] == [-9, 1]
    # the crash attempt really ran (stats recorded); the dead attempt must
    # NOT inherit the crash attempt's stale stats file
    assert log[0]["measured"] and log[1]["measured"] is None
    res = run_fleet(path, resume=True)                    # attempt 3: ok
    assert res.launched == [0]


# ---------------------------------------------------------------------------
# fleet doctor: explain WHY a shard is incomplete
# ---------------------------------------------------------------------------

def test_doctor_names_missing_pair_and_k_points(tmp_path, synth_measure):
    """Acceptance: on the pre-retry state after a scripted 'drop-point'
    fault, doctor names the incomplete shard and the exact missing
    (pair, k); after the healing retry it reports COMPLETE."""
    plan, path = _plan(tmp_path, stem="doctor",
                       launcher={"kind": "mock",
                                 "script": {"0": ["drop-point"]}})
    with pytest.raises(FleetError, match=r"shard\(s\) \[0\]"):
        run_fleet(path)
    code, report = fleet_doctor(plan)
    assert code == 1
    assert "shard 0: INCOMPLETE" in report
    assert "missing k(s) [" in report        # the exact missing point named
    assert "shard 1: complete" in report
    assert "rc=-9" in report                 # the attempt history
    # the missing k doctor names is exactly what pair_status reports
    ws = plan.worker_stores()[0]
    from repro.core import CampaignStore
    st = CampaignStore(ws, readonly=True)
    missing = [ps.missing for ps in
               st.grid_status(plan.grid()[0::2]).values() if ps.missing]
    assert missing and str(sorted(missing[0])) in report

    res = run_fleet(path, resume=True, retry=RetryBudget(max_attempts=2))
    assert res.launched == [0]
    wstats = json.load(open(ws + ".stats.json"))
    assert wstats["measured"] == 1           # ONLY the dropped point
    code, report = fleet_doctor(plan)
    assert code == 0 and "COMPLETE" in report


def test_doctor_reports_torn_tail_and_absent_stores(tmp_path, synth_measure):
    plan, path = _plan(tmp_path, stem="docttorn")
    # nothing ran yet: everything absent, verdict INCOMPLETE
    code, report = fleet_doctor(plan)
    assert code == 1
    assert "not created yet" in report and "absent" in report
    # run shard 0 then tear its store like a SIGKILL mid-append
    run_worker(SweepPlan.load(path), index=0, count=2)
    tear_store_tail(plan.worker_stores()[0])
    code, report = fleet_doctor(plan)
    assert code == 1
    assert "torn tail" in report
    assert "in progress" in report           # the done-less pair explained
    valid = read_store_records(plan.worker_stores()[0])[1]
    assert valid < os.path.getsize(plan.worker_stores()[0])


# ---------------------------------------------------------------------------
# SSH launcher: geometry, command construction, documented degrade
# ---------------------------------------------------------------------------

def _hosts_file(tmp_path):
    hosts = {"hosts": [
        {"addr": "alice@n0", "python": "/opt/venv/bin/python",
         "workdir": "/scratch/repro",
         "env": {"PYTHONPATH": "src",
                 # hostile: tries to clobber the handshake digest
                 "REPRO_FLEET_EXPECT_DIGEST": "bogus"}},
        {"addr": "n1"}]}
    path = str(tmp_path / "hosts.json")
    with open(path, "w") as f:
        json.dump(hosts, f)
    return path


def test_load_hosts_and_validation(tmp_path):
    hosts = load_hosts(_hosts_file(tmp_path))
    assert [h.addr for h in hosts] == ["alice@n0", "n1"]
    assert hosts[0].python == "/opt/venv/bin/python"
    assert dict(hosts[0].env)["PYTHONPATH"] == "src"
    assert hosts[1].workdir == "."             # defaults fill in
    with pytest.raises(FleetError, match="addr"):
        HostSpec.from_dict({"python": "python3"})
    with pytest.raises(FleetError, match="unknown key"):
        HostSpec.from_dict({"addr": "n0", "port": 22})
    empty = str(tmp_path / "empty.json")
    with open(empty, "w") as f:
        json.dump([], f)
    with pytest.raises(FleetError, match="non-empty"):
        load_hosts(empty)


def test_ssh_remote_command_carries_handshake_and_geometry(tmp_path,
                                                           monkeypatch):
    plan, path = _plan(tmp_path, stem="sshcmd")
    plan.store = "experiments/campaigns/s.jsonl"   # relative, as ssh needs
    hosts = load_hosts(_hosts_file(tmp_path))
    lch = SSHLauncher(hosts)
    assert lch.host_for(0).addr == "alice@n0"
    assert lch.host_for(3).addr == "n1"            # round-robin ring
    cmd = lch._remote_command(hosts[0], plan, "plan.json", 0)
    assert cmd[:2] == ["ssh", "-o"]
    line = cmd[-1]
    assert "cd /scratch/repro" in line
    # the handshake digest wins over a hosts.json env that tries to set it
    assert f"REPRO_FLEET_EXPECT_DIGEST={plan.digest()}" in line
    assert "REPRO_FLEET_EXPECT_DIGEST=bogus" not in line
    assert "REPRO_FLEET_HOST=alice@n0" in line
    assert "PYTHONPATH=src" in line
    assert "/opt/venv/bin/python -m repro.launch.probe" in line
    assert "--shard 0/2" in line
    # stale remote stats are wiped so a dead attempt can't inherit them
    assert "rm -f " in line and ".stats.json" in line


def test_ssh_degrades_to_manual_recipe_without_ssh(tmp_path, monkeypatch):
    """Satellite of the tentpole: no ssh on PATH -> the launcher refuses
    with the documented manual per-host recipe instead of half-running."""
    plan, path = _plan(tmp_path, stem="sshless")
    lch = SSHLauncher([HostSpec(addr="n0")])
    monkeypatch.setattr("shutil.which", lambda name: None)
    assert not SSHLauncher.available()
    with pytest.raises(FleetError) as ei:
        lch.launch(path, plan, [0])
    assert "manual multi-host recipe" in str(ei.value)
    assert str(ei.value) == MANUAL_RECIPE


def test_ssh_requires_relative_store(tmp_path, monkeypatch):
    plan, path = _plan(tmp_path, stem="sshabs")   # tmp store path: absolute
    lch = SSHLauncher([HostSpec(addr="n0")])
    monkeypatch.setattr("shutil.which", lambda name: f"/usr/bin/{name}")
    with pytest.raises(FleetError, match="RELATIVE"):
        lch.launch(path, plan, [0])


def test_host_store_namespacing():
    name = host_store("a/b.jsonl", "alice@n0")
    assert name.startswith("a/b.halice-n0-") and name.endswith(".jsonl")
    assert host_store("a/b", "n0").startswith("a/b.hn0-")
    assert host_store("a/b", "n0").endswith(".jsonl")
    # stable: the same host always stages under the same name
    assert host_store("a/b.jsonl", "alice@n0") == name


def test_host_store_distinct_hosts_never_collide():
    """Regression: sanitization used to map distinct raw host names (every
    non-alnum char -> '-') onto ONE staging file, so two hosts' pulled
    stores could clobber each other. A short hash of the raw name now keeps
    them apart."""
    a = host_store("a/b.jsonl", "node:1")
    b = host_store("a/b.jsonl", "node-1")
    assert a != b
    assert host_store("s.jsonl", "user@h.x") != host_store("s.jsonl",
                                                           "user-h-x")


# ---------------------------------------------------------------------------
# resolution + plan serialization of launcher/retry
# ---------------------------------------------------------------------------

def test_resolve_launcher_precedence(tmp_path):
    plan, _ = _plan(tmp_path, stem="resolve",
                    launcher={"kind": "mock", "script": {"0": ["crash"]}})
    assert isinstance(resolve_launcher(plan=plan), MockClusterLauncher)
    assert resolve_launcher(plan=plan).script == {0: ("crash",)}
    # an explicit kind beats the plan's spec
    assert isinstance(resolve_launcher("local", plan=plan), LocalLauncher)
    lch = resolve_launcher("mock", plan=plan, mock_script={1: ["dead"]})
    assert lch.script == {1: ("dead",)}
    with pytest.raises(FleetError, match="unknown launcher kind"):
        resolve_launcher("k8s")
    with pytest.raises(FleetError, match="--in-process"):
        resolve_launcher("mock", in_process=True)
    with pytest.raises(FleetError, match="hosts"):
        resolve_launcher("ssh")
    # --hosts/--mock-script must never be silently dropped onto a local
    # launcher (the sweep would run on the wrong hosts / without faults)
    with pytest.raises(FleetError, match="ssh/mock"):
        resolve_launcher(hosts_path="hosts.json")
    with pytest.raises(FleetError, match="ssh/mock"):
        resolve_launcher(mock_script={0: ["crash"]})
    # a bad script is a clean FleetError, not a raw ValueError traceback
    with pytest.raises(FleetError, match="shard indices"):
        MockClusterLauncher({"x": ["ok"]})


def test_plan_serializes_launcher_and_retry_into_digest(tmp_path):
    bare, _ = _plan(tmp_path, stem="bare")
    armed, path = _plan(tmp_path, stem="armed",
                        launcher={"kind": "mock", "script": {"0": ["crash"]}},
                        retry={"max_attempts": 2, "backoff": 0.1})
    # distribution settings are plan identity: the digest pins them
    assert bare.digest() != armed.digest()
    loaded = SweepPlan.load(path)
    assert loaded.launcher == armed.launcher
    assert loaded.retry == armed.retry
    assert loaded.digest() == armed.digest()
    # ...but a plan WITHOUT them keeps its pre-launcher digest bytes
    assert "launcher" not in bare.to_dict() and "retry" not in bare.to_dict()
    with pytest.raises(PlanError, match="launcher kind"):
        _plan(tmp_path, stem="badl", launcher={"kind": "k8s"})
    with pytest.raises(PlanError, match="mock action"):
        _plan(tmp_path, stem="bads",
              launcher={"kind": "mock", "script": {"0": ["explode"]}})
    with pytest.raises(PlanError, match="hosts"):
        _plan(tmp_path, stem="badh", launcher={"kind": "ssh"})
    with pytest.raises(PlanError, match="retry"):
        _plan(tmp_path, stem="badr", retry={"retries": 3})


# ---------------------------------------------------------------------------
# launcher -> worker handshake
# ---------------------------------------------------------------------------

def test_worker_refuses_mismatched_plan_digest(tmp_path, synth_measure,
                                               monkeypatch):
    plan, path = _plan(tmp_path, stem="shake")
    monkeypatch.setenv("REPRO_FLEET_EXPECT_DIGEST", "deadbeef0000")
    with pytest.raises(FleetError, match="handshake"):
        run_worker(SweepPlan.load(path), index=0, count=2)
    monkeypatch.setenv("REPRO_FLEET_EXPECT_DIGEST", plan.digest())
    run_worker(SweepPlan.load(path), index=0, count=2)   # matching: runs


# ---------------------------------------------------------------------------
# CLI round trip: plan flags -> embedded spec -> run/doctor
# ---------------------------------------------------------------------------

def test_cli_plan_run_doctor_with_mock_launcher(tmp_path, synth_measure,
                                                capsys):
    from repro.fleet.cli import main

    out_plan = str(tmp_path / "cli_plan.json")
    store = str(tmp_path / "cli" / "store.jsonl")
    assert main(["plan", "--out", out_plan, "--pallas", "probe",
                 "--sizes", "8", "--modes", "fp", "--reps", "2",
                 "--shards", "2", "--backend", "interpret",
                 "--store", store, "--launcher", "mock",
                 "--mock-script", '{"0": ["crash"]}',
                 "--max-attempts", "2"]) == 0
    plan = SweepPlan.load(out_plan)
    assert plan.launcher == {"kind": "mock", "script": {"0": ["crash"]}}
    assert plan.retry == {"max_attempts": 2}
    # run uses the plan's embedded mock launcher + retry budget: the
    # scripted crash is healed by the in-run retry, rc 0
    assert main(["run", "--plan", out_plan]) == 0
    out = capsys.readouterr().out
    assert "scripted action 'crash'" in out
    assert "round 2/2" in out
    assert main(["doctor", "--plan", out_plan]) == 0
    assert "COMPLETE" in capsys.readouterr().out
    with pytest.raises(SystemExit, match="--in-process"):
        main(["run", "--plan", out_plan, "--resume", "--in-process",
              "--launcher", "ssh"])

"""Fleet orchestrator: SweepPlan round-trip and grid identity, shard
completeness queries, executor resume/crash-heal semantics, and the
acceptance run — N=2 shards produce a classification byte-identical to a
single-process run, and a completed fleet replays with ZERO measurements.

Measurement determinism: these tests set REPRO_SYNTH_MEASURE, the
deterministic stand-in clock in ``repro.core.absorption.measure``, so
independently-run processes (and shards) produce byte-comparable stores."""
import json
import os

import pytest

from repro.core import CampaignStore, PairStatus
from repro.fleet.executor import (FleetError, FleetState, _incomplete_shards,
                                  in_process_launcher, report_json,
                                  run_fleet, run_worker)
from repro.fleet.plan import PlanError, SweepPlan, TargetSpec


@pytest.fixture
def synth_measure(monkeypatch):
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")


def _plan(tmp_path, *, shards=2, modes=("fp", "mxu"), sizes=(8,),
          name="fleet_probe", stem="fleet"):
    plan = SweepPlan(
        name=name, store=str(tmp_path / stem / "store.jsonl"),
        targets=[TargetSpec("pallas", tuple(modes),
                            {"kernel": "probe", "sizes": list(sizes)})],
        reps=2, shards=shards, backend="interpret")
    path = str(tmp_path / f"{stem}_plan.json")
    plan.save(path)
    return plan, path


# ---------------------------------------------------------------------------
# SweepPlan: serialization, identity, grid enumeration
# ---------------------------------------------------------------------------

def test_plan_round_trip_and_digest(tmp_path):
    plan, path = _plan(tmp_path, sizes=(8, 16))
    loaded = SweepPlan.load(path)
    assert loaded.to_dict() == plan.to_dict()
    assert loaded.digest() == plan.digest()
    # the digest pins content: ANY settings change (here reps) changes it
    loaded.reps = 3
    assert loaded.digest() != plan.digest()


def test_plan_grid_spans_family_and_orders_canonically(tmp_path):
    plan, _ = _plan(tmp_path, modes=("fp", "mxu"), sizes=(8, 16))
    grid = plan.grid()
    assert grid == [("pallas_probe_s8", "fp"), ("pallas_probe_s8", "mxu"),
                    ("pallas_probe_s16", "fp"), ("pallas_probe_s16", "mxu")]
    # worker i of N takes every N-th pair; slices partition the grid
    slices = [grid[i::3] for i in range(3)]
    assert sorted(p for s in slices for p in s) == sorted(grid)


def test_plan_validation_errors(tmp_path):
    with pytest.raises(PlanError, match="no targets"):
        SweepPlan(name="x", store="s", targets=[]).validate()
    with pytest.raises(PlanError, match="unknown pallas kernel"):
        SweepPlan(name="x", store="s", targets=[
            TargetSpec("pallas", ("fp",), {"kernel": "nope", "sizes": [8]})
        ]).validate()
    with pytest.raises(PlanError, match="supports modes"):
        SweepPlan(name="x", store="s", targets=[
            TargetSpec("pallas", ("mxu",), {"kernel": "spmxv", "sizes": [256]})
        ]).validate()
    with pytest.raises(PlanError, match="unknown target kind"):
        SweepPlan(name="x", store="s",
                  targets=[TargetSpec("what", ("fp",), {})]).validate()
    with pytest.raises(PlanError, match="unknown graph-level mode"):
        SweepPlan(name="x", store="s", targets=[
            TargetSpec("step", ("not_a_mode",), {"arch": "gemma_2b"})
        ]).validate()
    # duplicate (region, mode) pairs across targets are a plan bug
    dup = SweepPlan(name="x", store="s", targets=[
        TargetSpec("pallas", ("fp",), {"kernel": "probe", "sizes": [8]}),
        TargetSpec("pallas", ("fp",), {"kernel": "probe", "sizes": [8]})])
    with pytest.raises(PlanError, match="duplicate"):
        dup.grid()


def test_plan_rejects_bad_family_params_at_build_time(tmp_path):
    """qs on a non-spmxv kernel and unknown spec kwargs must fail when the
    plan is VALIDATED, not later in every worker subprocess at resolve()."""
    with pytest.raises(PlanError, match="spmxv"):
        SweepPlan(name="x", store="s", targets=[
            TargetSpec("pallas", ("fp",),
                       {"kernel": "matmul", "sizes": [128], "qs": [0.5]})
        ]).validate()
    with pytest.raises(PlanError, match="does not accept"):
        SweepPlan(name="x", store="s", targets=[
            TargetSpec("pallas", ("fp",),
                       {"kernel": "matmul", "sizes": [128],
                        "nnz_per_row": 8})
        ]).validate()
    # and the CLI refuses to write the invalid plan file at all
    from repro.fleet.cli import main
    out = str(tmp_path / "bad_plan.json")
    with pytest.raises(SystemExit):
        main(["plan", "--out", out, "--pallas", "matmul", "--sizes", "128",
              "--qs", "0.5", "--store", str(tmp_path / "s.jsonl")])
    assert not os.path.exists(out)


def test_plan_cheap_grid_matches_resolved_pairs(tmp_path):
    """grid() derives names without building targets; it must enumerate
    exactly what pairs() resolves, in the same order."""
    plan, _ = _plan(tmp_path, modes=("fp", "mxu"), sizes=(8, 16))
    assert plan.grid() == [(r.name, m) for r, m in plan.pairs()]


def test_plan_not_a_plan_file(tmp_path):
    path = str(tmp_path / "nope.json")
    with open(path, "w") as f:
        json.dump({"hello": 1}, f)
    with pytest.raises(PlanError, match="not a sweep plan"):
        SweepPlan.load(path)


# ---------------------------------------------------------------------------
# completeness queries (the per-(region, mode) grid query the executor needs)
# ---------------------------------------------------------------------------

def test_pair_status_lifecycle(tmp_path):
    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    assert st.pair_status("r", "m") == PairStatus(points=0, expected=None,
                                                  done=False)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 1.0})
    assert st.pair_status("r", "m").points == 1
    assert not st.pair_status("r", "m").complete          # no done marker
    st.append({"kind": "done", "region": "r", "mode": "m", "ks": [0, 2],
               "drift": None, "stopped_early": False, "payload": None})
    ps = st.pair_status("r", "m")
    assert ps.done and ps.expected == 2 and ps.missing == (2,)
    assert not ps.complete                                # truncated store
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 2, "t": 1.0})
    assert st.pair_status("r", "m").complete
    st.close()
    assert st.grid_status([("r", "m"), ("r", "z")])[("r", "z")].points == 0


def test_incomplete_shards_consults_stores_not_bookkeeping(tmp_path,
                                                           synth_measure):
    plan, path = _plan(tmp_path, modes=("fp", "mxu"))
    grid = plan.grid()
    # nothing on disk: every shard owes its slice
    assert _incomplete_shards(plan, grid) == [0, 1]
    # one worker done: only the other still owes
    run_worker(SweepPlan.load(path), index=0, count=2)
    assert _incomplete_shards(plan, grid) == [1]
    run_worker(SweepPlan.load(path), index=1, count=2)
    assert _incomplete_shards(plan, grid) == []


# ---------------------------------------------------------------------------
# the fleet pipeline: spawn -> merge -> classify, resume, crash-heal
# ---------------------------------------------------------------------------

def test_fleet_matches_single_process_and_resumes_free(tmp_path,
                                                       synth_measure,
                                                       capsys):
    """Acceptance: N=2 shards -> merged store -> classification byte-identical
    to the same plan run single-process; a second run --resume launches
    nothing and measures nothing."""
    plan, path = _plan(tmp_path, stem="fan")
    res = run_fleet(path, launcher=in_process_launcher)
    assert res.launched == [0, 1]
    assert res.stats.measured == 0            # classify REPLAYS the merge
    assert {s.status for s in res.state.shards.values()} == {"done"}
    fleet_report = open(plan.report_path(), "rb").read()

    # same targets, fresh store, one process — the reference run
    single, single_path = _plan(tmp_path, stem="single", shards=1)
    reports, stats = run_worker(SweepPlan.load(single_path))
    assert stats.measured > 0
    assert open(single.report_path(), "rb").read() == fleet_report

    # completed fleet: --resume relaunches nothing, replays everything
    res2 = run_fleet(path, resume=True, expect_no_measure=True)
    assert res2.launched == []
    assert res2.stats.measured == 0 and res2.stats.cached > 0
    assert open(plan.report_path(), "rb").read() == fleet_report
    assert report_json(res2.reports) == report_json(res.reports)


def test_fleet_requires_resume_or_fresh_on_existing_state(tmp_path,
                                                          synth_measure):
    _, path = _plan(tmp_path, stem="twice")
    run_fleet(path, launcher=in_process_launcher)
    with pytest.raises(FleetError, match="--resume"):
        run_fleet(path, launcher=in_process_launcher)
    # --fresh restarts from zero: everything is re-measured
    res = run_fleet(path, fresh=True, launcher=in_process_launcher)
    assert res.launched == [0, 1]


def test_fleet_refuses_changed_plan_digest(tmp_path, synth_measure):
    plan, path = _plan(tmp_path, stem="pin")
    run_fleet(path, launcher=in_process_launcher)
    plan.reps = 3                       # a different measurement settings
    plan.save(path)                     # ... under the same plan path
    with pytest.raises(FleetError, match="digest"):
        run_fleet(path, resume=True, launcher=in_process_launcher)


def _kill_after_measuring(store_path):
    """Simulate a shard killed mid-sweep: drop the final 'done' marker and
    tear the (new) trailing point record — exactly the torn-tail shape a
    SIGKILL mid-append leaves behind."""
    lines = open(store_path).read().strip().split("\n")
    assert json.loads(lines[-1])["kind"] == "done"
    with open(store_path, "w") as f:
        f.write("\n".join(lines[:-1]) + "\n")
    with open(store_path, "r+b") as f:
        f.truncate(os.path.getsize(store_path) - 9)


def test_fleet_crash_resume_heals_and_remeasures_only_missing(tmp_path,
                                                              synth_measure):
    """Satellite: a shard killed mid-sweep (truncated trailing line in its
    worker store) is healed by --resume, which re-measures ONLY the missing
    point(s), and the final classification matches the clean run."""
    plan, path = _plan(tmp_path, stem="crash")

    def crashing_launcher(plan_path, p, indices):
        rcs = in_process_launcher(plan_path, p, indices)
        if 0 in indices:
            _kill_after_measuring(p.worker_stores()[0])
            rcs[0] = -9
        return rcs

    with pytest.raises(FleetError, match=r"shard\(s\) \[0\]"):
        run_fleet(path, launcher=crashing_launcher)
    state = FleetState.load(plan.fleet_path())
    assert state.shards[0].status == "failed"
    assert state.shards[1].status == "done"
    # crash aborted pre-merge: the canonical store holds only the pre-launch
    # audit records, no measured points
    canon = CampaignStore(plan.store, readonly=True)
    assert not canon.points
    assert set(canon.audits) == set(plan.grid())
    canon.close()

    res = run_fleet(path, resume=True, launcher=in_process_launcher)
    assert res.launched == [0]                     # ONLY the dead shard
    wstats = json.load(open(plan.worker_stores()[0] + ".stats.json"))
    assert wstats["measured"] == 1                 # the torn point, nothing else
    assert wstats["cached"] > 0                    # the surviving prefix replayed
    assert res.stats.measured == 0

    # reference: same targets, clean single-process run
    single, single_path = _plan(tmp_path, stem="crash_ref", shards=1)
    run_worker(SweepPlan.load(single_path))
    assert open(plan.report_path(), "rb").read() \
        == open(single.report_path(), "rb").read()


def test_fleet_subprocess_end_to_end(tmp_path, synth_measure):
    """The real thing: 2 OS subprocesses (python -m repro.launch.probe
    --plan P --shard i/2), streamed, merged, classified; then a resume that
    spawns nothing."""
    plan, path = _plan(tmp_path, stem="subproc")
    res = run_fleet(path)                          # default: subprocess_launcher
    assert res.launched == [0, 1]
    assert all(s.returncode == 0 for s in res.state.shards.values())
    assert all(s.measured and not s.cached for s in res.state.shards.values())
    assert res.stats.measured == 0
    assert os.path.exists(plan.store)

    res2 = run_fleet(path, resume=True, expect_no_measure=True)
    assert res2.launched == []


# ---------------------------------------------------------------------------
# probe CLI integration (the worker entry + flag conflicts)
# ---------------------------------------------------------------------------

def test_probe_plan_flag_runs_worker_and_replays(tmp_path, synth_measure,
                                                 capsys):
    from repro.launch import probe

    _, path = _plan(tmp_path, stem="cli", modes=("fp",))
    probe.main(["--plan", path, "--shard", "0/2"])
    out = capsys.readouterr().out
    assert "worker store" in out and "points measured" in out
    # whole-plan mode on the merged... here: unsharded store; measures the rest
    probe.main(["--plan", path])
    # an already-complete canonical store replays under --expect-no-measure
    probe.main(["--plan", path, "--expect-no-measure"])
    with pytest.raises(SystemExit, match="conflicting"):
        probe.main(["--plan", path, "--pallas", "probe"])
    with pytest.raises(SystemExit, match="--reps"):
        probe.main(["--plan", path, "--reps", "5"])      # plan owns reps
    with pytest.raises(SystemExit, match="shards"):
        probe.main(["--plan", path, "--shard", "0/3"])   # N != plan.shards


def test_campaign_inspect_against_plan_grid(tmp_path, synth_measure, capsys):
    """``inspect --plan`` checks a store against a plan's FULL expected grid:
    pairs absent from the store entirely are reported (exit 1), a covering
    store passes (exit 0)."""
    from repro.core.campaign import _cli

    plan, path = _plan(tmp_path, stem="inspect", modes=("fp", "mxu"))
    ws = plan.worker_stores()[0]
    run_worker(SweepPlan.load(path), index=0, count=2)   # half the grid
    assert _cli(["inspect", ws, "--plan", path]) == 1
    out = capsys.readouterr().out
    assert "plan 'fleet_probe': 1/2 pair(s) complete" in out
    assert "missing pallas_probe_s8/mxu (absent)" in out

    run_fleet(path, resume=True, launcher=in_process_launcher)
    assert _cli(["inspect", plan.store, "--plan", path]) == 0
    assert "2/2 pair(s) complete" in capsys.readouterr().out


def test_fleet_cli_plan_run_status(tmp_path, synth_measure, capsys):
    from repro.fleet.cli import main

    out_plan = str(tmp_path / "cli_plan.json")
    store = str(tmp_path / "cli" / "store.jsonl")
    assert main(["plan", "--out", out_plan, "--pallas", "probe",
                 "--sizes", "8", "--modes", "fp,mxu", "--reps", "2",
                 "--shards", "2", "--backend", "interpret",
                 "--store", store]) == 0
    assert main(["status", "--plan", out_plan]) == 1      # nothing run yet
    assert main(["run", "--plan", out_plan, "--in-process"]) == 0
    assert main(["run", "--plan", out_plan, "--in-process", "--resume",
                 "--expect-no-measure"]) == 0
    assert main(["status", "--plan", out_plan]) == 0
    out = capsys.readouterr().out
    assert "2/2 pair(s) complete" in out

"""Core noise-injection machinery: semantics preservation, payload
verification, three-phase fit (property-based), classifier rules, analytic
saturation model, clustering."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:   # property tests skip; the rest still runs
    from conftest import hypothesis_stub as hypothesis
    from conftest import strategies_stub as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TPU_V5E
from repro.core import (StepTerms, classify, cluster_times,
                        cross_check_with_decan, fit_three_phase, inject,
                        init_state, predict_absorption, predict_curve,
                        verify_semantics)
from repro.core.analytic import pattern_deltas, predict_time
from repro.core.noise import NoiseScale, make_modes
from repro.core.payload import analyze_injection

MODES = make_modes(NoiseScale(hbm_mib=4, chase_len=1 << 16, mxu_dim=32))


def _step(x):
    W = jnp.eye(64) * 0.5
    return jnp.tanh(x @ W) @ W


X = jax.random.normal(jax.random.PRNGKey(0), (16, 64))


@pytest.mark.parametrize("mode", ["fp_add32", "mxu_fma128", "vmem_ld",
                                  "hbm_stream", "hbm_latency"])
@pytest.mark.parametrize("k", [1, 7])
def test_semantics_preserved(mode, k):
    """Paper §2.3: injection must not change program outputs (bitwise)."""
    assert verify_semantics(_step, (X,), MODES[mode], k=k)


@pytest.mark.parametrize("mode", ["fp_add32", "vmem_ld", "hbm_stream"])
def test_payload_survives_optimization(mode):
    """k injected patterns survive XLA -O3 as >= k payload ops."""
    m = MODES[mode]
    k = 6
    fn = inject(_step, m, k)
    txt = jax.jit(fn).lower(init_state(m), X).compile().as_text()
    rep = analyze_injection(txt, mode=mode, target=m.target, expected=k)
    assert rep.payload >= k, rep
    assert rep.survival_fraction >= 1.0
    assert rep.ok()


def test_zero_noise_zero_payload():
    m = MODES["fp_add32"]
    fn = inject(_step, m, 0)
    txt = jax.jit(fn).lower(init_state(m), X).compile().as_text()
    rep = analyze_injection(txt, mode="fp_add32", target="compute", expected=0)
    assert rep.payload == 0


# ---------------------------------------------------------------------------
# Three-phase fit: property-based — recover (k1, slope) from synthetic curves
# ---------------------------------------------------------------------------

@hypothesis.given(
    t0=st.floats(1e-4, 1.0),
    k1=st.integers(0, 60),              # interior knee: >=2 points past it
    slope_rel=st.floats(0.05, 0.5),     # slope clearly above the noise floor
    noise=st.floats(0.0, 0.002),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_fit_recovers_knee(t0, k1, slope_rel, noise):
    ks = [0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128]
    rng = np.random.RandomState(42)
    slope = slope_rel * t0
    ts = [t0 * (1 + rng.uniform(-noise, noise))
          + slope * max(0, k - k1) for k in ks]
    fit = fit_three_phase(ks, ts, tol=0.05)
    # k1 recovered within the local grid spacing
    grid = np.asarray(ks)
    spacing = np.diff(grid)[np.searchsorted(grid[1:], max(k1, 1))] \
        if k1 < grid[-1] else 32
    assert abs(fit.k1 - k1) <= max(2.0 * spacing, 4.0), (fit.k1, k1)
    assert fit.slope == pytest.approx(slope, rel=0.5, abs=1e-6)


def test_fit_flat_curve_unbounded():
    ks = [0, 4, 8, 16, 32]
    fit = fit_three_phase(ks, [1.0] * len(ks))
    assert fit.k1 >= 16 and fit.slope == pytest.approx(0.0, abs=1e-9)


def test_fit_immediate_degradation():
    ks = [0, 1, 2, 4, 8]
    fit = fit_three_phase(ks, [1.0, 1.5, 2.0, 3.0, 5.0])
    assert fit.k1 <= 1.0


# ---------------------------------------------------------------------------
# Classifier
# ---------------------------------------------------------------------------

def test_classifier_signatures():
    assert classify({"fp_add": 0, "l1_ld": 13, "mem_ld": 0}).label == "compute"
    assert classify({"fp_add": 65, "l1_ld": 26, "mem_ld": 0}).label == "bandwidth"
    assert classify({"fp_add": 250, "l1_ld": 240, "mem_ld": 15}).label == "latency"
    assert classify({"fp_add": 1, "l1_ld": 1, "mem_ld": 0}).label == "overlap"
    assert classify({"fp_add": 30, "l1_ld": 2, "mem_ld": 1}).label == "l1"
    r = classify({"fp_add": 40, "l1_ld": 30, "ici_allreduce": 1})
    assert r.label == "ici"


def test_cross_check():
    overlap = classify({"fp_add": 1, "l1_ld": 1})
    assert overlap.label == "overlap"
    # paper fig6 numbers: DECAN rules out case 3 -> frontend
    out = cross_check_with_decan(overlap, sat_fp=0.81, sat_ls=0.12)
    assert out.label == "frontend"
    # both variants ~ ref: genuine overlap confirmed
    out2 = cross_check_with_decan(overlap, sat_fp=0.97, sat_ls=0.93)
    assert out2.label == "overlap"


# ---------------------------------------------------------------------------
# Analytic saturation model
# ---------------------------------------------------------------------------

def test_analytic_absorption_closed_form():
    """alpha=1: Abs == slack of the targeted resource / per-pattern cost."""
    terms = StepTerms(compute=2e-3, memory=5e-3, ici=1e-3)   # memory-bound
    mode = MODES["mxu_fma128"]
    deltas = pattern_deltas(mode, TPU_V5E)
    fit = predict_absorption(terms, mode, TPU_V5E, tol=0.05, k_max=1 << 26)
    # hand-derived knee: (1.05*T_mem - T_compute) / delta_compute
    expect = (1.05 * 5e-3 - 2e-3) / deltas["compute"]
    assert fit.k1 == pytest.approx(expect, rel=0.01)


def test_analytic_bound_resource_zero_absorption():
    terms = StepTerms(compute=1e-3, memory=5e-3)
    fit = predict_absorption(terms, MODES["hbm_stream"], TPU_V5E, tol=0.001)
    slack_patterns = fit.k1
    # memory is the bottleneck: only ~tol worth of memory noise fits
    delta_mem = pattern_deltas(MODES["hbm_stream"], TPU_V5E)["memory"]
    assert slack_patterns <= 0.002 * 5e-3 / delta_mem + 2


@hypothesis.given(
    tc=st.floats(1e-5, 1e-2), tm=st.floats(1e-5, 1e-2),
    alpha=st.floats(0.0, 1.0), k=st.integers(0, 1000))
@hypothesis.settings(max_examples=40, deadline=None)
def test_predict_time_monotone(tc, tm, alpha, k):
    terms = StepTerms(compute=tc, memory=tm)
    d = pattern_deltas(MODES["fp_add32"], TPU_V5E)
    t_k = predict_time(terms, d, k, alpha=alpha)
    t_k1 = predict_time(terms, d, k + 1, alpha=alpha)
    assert t_k1 >= t_k >= 0
    assert t_k >= (alpha * max(tc, tm) + (1 - alpha) * (tc + tm)) - 1e-12


def test_predict_curve_matches_pointwise():
    terms = StepTerms(compute=1e-3, memory=2e-3)
    ks = [0, 10, 100]
    cur = predict_curve(terms, MODES["mxu_fma128"], TPU_V5E, ks)
    d = pattern_deltas(MODES["mxu_fma128"], TPU_V5E)
    for k, t in zip(ks, cur):
        assert t == pytest.approx(predict_time(terms, d, k), rel=1e-9)


def test_cluster_times():
    groups = cluster_times([1.0, 1.02, 0.98, 5.0, 5.1, 1.01])
    assert len(groups) == 2
    sizes = sorted(len(g) for g in groups)
    assert sizes == [2, 4]

import os
import sys

# tests see ONE cpu device (the dry-run forces 512 only in its own process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _fresh_measure_state():
    """Per-test isolation for absorption's process-level measurement state:
    the per-series floor_time warning dedup and the synthetic clock's
    drift counter / hang latch."""
    import importlib

    # note: ``repro.core.absorption`` the *attribute* is the absorption()
    # function (re-exported by the package); go through importlib to get
    # the module itself
    absorption_mod = importlib.import_module("repro.core.absorption")
    absorption_mod.reset_floor_warnings()
    absorption_mod.reset_synth_state()
    yield
    absorption_mod.release_synth_hang()  # never leave a parked thread behind


class _HypothesisStub:
    """Stands in for ``hypothesis`` when it isn't installed: ``@given`` marks
    the test skipped (instead of the import crashing collection), ``settings``
    is identity, and strategies return inert placeholders. Non-property tests
    in the same module keep running."""

    def given(self, *a, **k):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(see requirements-dev.txt)")

    def settings(self, *a, **k):
        return lambda f: f

    def __getattr__(self, name):
        return lambda *a, **k: None


hypothesis_stub = _HypothesisStub()
strategies_stub = _HypothesisStub()


def assert_close(a, b, rtol=2e-3, atol=2e-3, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=msg)

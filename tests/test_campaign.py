"""Campaign engine + compile-once sweep path: trace-count guarantees,
static/runtime-k equivalence, store resume semantics, multi-store
fan-out/merge, store-backed DECAN."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Campaign, CampaignStore, CampaignStoreError,
                        Controller, DecanTarget, merge_stores, step_region,
                        worker_store)
from repro.core.absorption import DEFAULT_KS
from repro.core.controller import loop_region
from repro.core.loopnoise import make_loop_modes
from repro.core.noise import NoiseScale, make_modes

MODES = make_modes(NoiseScale(hbm_mib=4, chase_len=1 << 16, mxu_dim=32))

def _make_counting_region(name="tiny"):
    """A tiny region whose step counts Python traces — each jit compilation
    traces exactly once, so the counter counts compiled executables."""
    traces = {"n": 0}

    def step(x):
        traces["n"] += 1
        W = jnp.eye(64) * 0.5
        return jnp.tanh(x @ W) @ W

    X = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    return step_region(name, step, (X,), MODES), traces


# ---------------------------------------------------------------------------
# compile-once path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fp_add32", "mxu_fma128", "vmem_ld",
                                  "hbm_stream", "hbm_latency"])
@pytest.mark.parametrize("k", [1, 3, 8])
def test_runtime_k_matches_static(mode, k):
    """apply_rt(state, k) must emit the same patterns as apply(state, k):
    identical aux and identical new state, so both sweep paths measure the
    same injected work."""
    m = MODES[mode]
    state = m.make_state(jax.random.PRNGKey(0))
    aux_s, new_s = m.apply(state, k)
    aux_r, new_r = jax.jit(m.apply_rt)(state, jnp.int32(k))
    np.testing.assert_allclose(np.asarray(aux_s), np.asarray(aux_r),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(new_s), jax.tree.leaves(new_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["fp_add", "fp_fma", "l1_ld", "chase"])
def test_loop_emit_rt_matches_static(mode):
    m = make_loop_modes()[mode]
    nc = m.init(jax.random.PRNGKey(0))
    for k in (1, 5):
        s = m.emit(nc, k, jnp.int32(3))
        r = jax.jit(lambda c, kk: m.emit_rt(c, kk, jnp.int32(3)))(
            nc, jnp.int32(k))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


def test_sweep_compiles_at_most_two_executables():
    """Acceptance: a DEFAULT_KS sweep on the compile-once path traces at most
    2 executables (the runtime-k one + the static payload check) instead of
    one per k."""
    region, traces = _make_counting_region()
    ctl = Controller(reps=2, compile_once=True)
    res = ctl.run_mode(region, "fp_add32", ks=DEFAULT_KS)
    assert traces["n"] <= 2, f"{traces['n']} executables for one sweep"
    assert len(res.curve.ks) >= 3    # the sweep actually happened
    assert res.injection is not None  # payload was verified (static trace)


def test_fallback_compiles_per_k():
    region, traces = _make_counting_region()
    # stop_ratio high: a wall-clock spike on a loaded container must not
    # trigger the online stop and truncate the sweep under test
    ctl = Controller(reps=2, compile_once=False, verify_payload=False,
                     stop_ratio=100.0)
    ctl.run_mode(region, "fp_add32", ks=(0, 2, 4, 8))
    assert traces["n"] >= 4          # the paper's cost model: one per k


def test_compile_once_and_fallback_same_classification():
    """A/B check: both sweep paths characterize a small region identically
    (same surviving-payload verdicts; classification from real timings may
    wobble, absorption fit fields must exist on both)."""
    region, _ = _make_counting_region("ab_region")
    ks = (0, 2, 4, 8, 16)
    # stop_ratio high: load spikes must not early-stop either sweep (the
    # ks[:3] assertion below relies on all three points being measured)
    fast = Controller(reps=2, compile_once=True, stop_ratio=100.0)
    slow = Controller(reps=2, compile_once=False, stop_ratio=100.0)
    r_fast = fast.run_mode(region, "fp_add32", ks=ks)
    r_slow = slow.run_mode(region, "fp_add32", ks=ks)
    assert r_fast.curve.ks[:3] == r_slow.curve.ks[:3] == [0, 2, 4]
    assert r_fast.injection.payload == r_slow.injection.payload
    assert r_fast.fit.t0 > 0 and r_slow.fit.t0 > 0


def test_loop_region_build_rt_matches_static():
    from repro.bench.kernels import stream_region

    r = stream_region(n=1 << 14)
    out_s = r.build("fp_add", 4)(*r.args_for("fp_add", 4))
    out_rt = r.build_rt("fp_add")(jnp.int32(4), *r.args_for_rt("fp_add"))
    for a, b in zip(jax.tree.leaves(out_s), jax.tree.leaves(out_rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# campaign store + resume
# ---------------------------------------------------------------------------

def test_campaign_resume_measures_nothing(tmp_path):
    """Acceptance: re-running a completed campaign performs ZERO new
    measurements and reproduces the same RegionReport classification."""
    store = str(tmp_path / "store.jsonl")
    region1, _ = _make_counting_region("resume_region")
    c1 = Campaign(store, Controller(reps=2))
    rep1 = c1.characterize(region1, ["fp_add32", "vmem_ld"])
    assert c1.stats.measured > 0

    region2, traces2 = _make_counting_region("resume_region")
    c2 = Campaign(store, Controller(reps=2))
    rep2 = c2.characterize(region2, ["fp_add32", "vmem_ld"])
    assert c2.stats.measured == 0
    assert traces2["n"] == 0                      # not even a compile
    assert rep2.bottleneck.label == rep1.bottleneck.label
    for m in rep1.results:
        assert rep2.results[m].curve.ks == rep1.results[m].curve.ks
        assert rep2.results[m].curve.ts == rep1.results[m].curve.ts
        assert rep2.results[m].fit.k1 == rep1.results[m].fit.k1
        if rep1.results[m].injection is not None:
            assert (rep2.results[m].injection.payload
                    == rep1.results[m].injection.payload)


def test_campaign_partial_store_resumes_missing_points(tmp_path):
    """An interrupted campaign (points stored, no 'done' marker) resumes at
    the missing ks instead of remeasuring the stored prefix."""
    store_path = str(tmp_path / "store.jsonl")
    region, _ = _make_counting_region("partial_region")
    # stop_ratio high: a wall-clock spike on a loaded container must not
    # early-stop either sweep (the point-count asserts need the full ks)
    ctl = Controller(reps=2, verify_payload=False, stop_ratio=100.0)

    c1 = Campaign(store_path, ctl)
    full = c1.sweep_mode(region, "fp_add32")
    n_points = len(full.curve.ks)

    # rebuild a truncated store: sensitivity + the first two points only
    trunc = str(tmp_path / "trunc.jsonl")
    st = CampaignStore(trunc)
    st.append({"kind": "sens", "region": "partial_region",
               "mode": "fp_add32", "value": c1.store.sens[
                   ("partial_region", "fp_add32")]})
    for k in full.curve.ks[:2]:
        st.append({"kind": "point", "region": "partial_region",
                   "mode": "fp_add32", "k": k,
                   "t": c1.store.stored_ts("partial_region", "fp_add32")[k]})
    st.close()

    region2, _ = _make_counting_region("partial_region")
    c2 = Campaign(trunc, ctl)
    res = c2.sweep_mode(region2, "fp_add32")
    assert c2.stats.cached == 2                  # stored prefix replayed
    assert c2.stats.measured == n_points - 2     # only the tail measured
    assert res.curve.ks == full.curve.ks
    assert c2.store.is_done("partial_region", "fp_add32")


def test_campaign_settings_mismatch_discards_store(tmp_path):
    """A store measured under different settings (reps / sweep path) must not
    be spliced into a new curve: the pair is discarded and remeasured."""
    store = str(tmp_path / "s.jsonl")
    region1, _ = _make_counting_region("meta_region")
    c1 = Campaign(store, Controller(reps=2, verify_payload=False))
    c1.sweep_mode(region1, "fp_add32")

    region2, _ = _make_counting_region("meta_region")
    c2 = Campaign(store, Controller(reps=3, verify_payload=False))
    c2.sweep_mode(region2, "fp_add32")
    assert c2.stats.measured > 0          # stored sweep was NOT replayed
    assert c2.stats.cached == 0

    # same settings again -> replay, nothing measured
    region3, _ = _make_counting_region("meta_region")
    c3 = Campaign(store, Controller(reps=3, verify_payload=False))
    c3.sweep_mode(region3, "fp_add32")
    assert c3.stats.measured == 0


def test_campaign_worker_pool(tmp_path):
    region, _ = _make_counting_region("pool_region")
    c = Campaign(str(tmp_path / "s.jsonl"),
                 Controller(reps=2, verify_payload=False), workers=3)
    reps = c.run([region], ["fp_add32", "vmem_ld", "hbm_stream"])
    assert set(reps["pool_region"].results) == {"fp_add32", "vmem_ld",
                                                "hbm_stream"}
    assert c.stats.measured > 0


def test_store_survives_reload(tmp_path):
    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 4, "t": 0.5})
    st.append({"kind": "sens", "region": "r", "mode": "m", "value": 1.5})
    st.close()
    st2 = CampaignStore(path)
    assert st2.stored_ts("r", "m") == {4: 0.5}
    assert st2.sens[("r", "m")] == 1.5
    st2.close()


# ---------------------------------------------------------------------------
# div-zero hardening (satellite)
# ---------------------------------------------------------------------------

def test_zero_baseline_clamped_with_warning():
    from repro.core.absorption import AbsorptionCurve

    curve = AbsorptionCurve(mode="m", ks=[0, 1], ts=[0.0, 1.0])
    with pytest.warns(RuntimeWarning, match="timer resolution"):
        r = curve.ratios()
    assert np.all(np.isfinite(r))


def test_probe_sensitivity_zero_baseline(monkeypatch):
    import repro.core.controller as ctl_mod

    region, _ = _make_counting_region("zero_t0")
    monkeypatch.setattr(ctl_mod, "measure", lambda *a, **k: 0.0)
    c = Controller(reps=2)
    with pytest.warns(RuntimeWarning, match="timer resolution"):
        s = c.probe_sensitivity(region, "fp_add32")
    assert np.isfinite(s)


# ---------------------------------------------------------------------------
# truncated / corrupt stores (the "loses at most one point" guarantee)
# ---------------------------------------------------------------------------

def _cut_final_record(path, src, nbytes=9):
    data = open(src, "rb").read()
    assert data.endswith(b"\n")
    with open(path, "wb") as f:
        f.write(data[:-nbytes])     # torn mid-append: partial last record


def test_truncated_final_line_resumes_with_one_point_lost(tmp_path):
    """A process killed mid-append leaves a partial last record; reopening
    the store must warn, drop ONLY that record, and resume."""
    full = str(tmp_path / "full.jsonl")
    region, _ = _make_counting_region("trunc_region")
    ctl = Controller(reps=2, verify_payload=False)
    res = Campaign(full, ctl).sweep_mode(region, "fp_add32")
    n_points = len(res.curve.ks)

    # cut mid-"done": the sweep resumes from its points, remeasures nothing
    trunc = str(tmp_path / "t1.jsonl")
    _cut_final_record(trunc, full)
    region2, traces2 = _make_counting_region("trunc_region")
    c2 = Campaign(trunc, ctl)
    assert not c2.store.is_done("trunc_region", "fp_add32")
    res2 = c2.sweep_mode(region2, "fp_add32")
    assert c2.stats.measured == 0 and c2.stats.cached == n_points
    assert res2.curve.ks == res.curve.ks

    # cut mid-"point" (strip the done line first): exactly one k remeasured
    lines = open(full).read().strip().split("\n")
    assert json.loads(lines[-1])["kind"] == "done"
    trunc2 = str(tmp_path / "t2.jsonl")
    with open(trunc2, "w") as f:
        f.write("\n".join(lines[:-1]) + "\n")
    _cut_final_record(trunc2, trunc2)
    region3, _ = _make_counting_region("trunc_region")
    c3 = Campaign(trunc2, ctl)
    c3.sweep_mode(region3, "fp_add32")
    assert c3.stats.measured == 1                 # the torn point only
    assert c3.stats.cached == n_points - 1
    # and the store is fully healed: a fresh campaign replays everything
    region4, _ = _make_counting_region("trunc_region")
    c4 = Campaign(trunc2, ctl)
    c4.sweep_mode(region4, "fp_add32")
    assert c4.stats.measured == 0


def test_corruption_before_final_record_hard_fails(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "sens", "region": "r", "mode": "m", "value": 1.5})
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 0.5})
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 2, "t": 0.6})
    st.close()
    lines = open(path).read().strip().split("\n")
    with open(path, "w") as f:   # damage a MIDDLE record, keep the tail
        f.write(lines[0] + "\n" + lines[1][:-4] + "\n" + lines[2] + "\n")
    with pytest.raises(CampaignStoreError, match="corrupt record"):
        CampaignStore(path)


# ---------------------------------------------------------------------------
# multi-store fan-out + merge (acceptance: split across >=2 stores, merged,
# replays with ZERO new measurements and identical classification)
# ---------------------------------------------------------------------------

def _fake_measure(fn, args=(), **kw):
    """Deterministic synthetic wall-clock: t(k) has a knee at k=6. The
    fan-out/merge tests compare two independently-run campaigns, so timing
    must be a pure function of k (args[0] on the runtime-k path)."""
    k = int(args[0]) if args else 0
    return 1e-3 * (1.0 + max(0, k - 6) * 0.05)


@pytest.fixture
def fake_measure(monkeypatch):
    import repro.core.campaign as campaign_mod
    import repro.core.controller as ctl_mod

    monkeypatch.setattr(campaign_mod, "measure", _fake_measure)
    monkeypatch.setattr(ctl_mod, "measure", _fake_measure)


def test_fanout_merge_replay_matches_single_store(tmp_path, fake_measure):
    """Acceptance: a campaign split across 2 worker stores, merged with
    merge_stores(), replays with ZERO new measurements, byte-identical
    ModeResults, and the same classification as the single-store run."""
    modes = ["fp_add32", "vmem_ld", "hbm_stream"]

    def fresh(name="fan_region"):
        region, traces = _make_counting_region(name)
        return region, traces, Controller(reps=2, verify_payload=False)

    # reference: one store, one process
    region, _, ctl = fresh()
    single = Campaign(str(tmp_path / "single.jsonl"), ctl)
    ref = single.characterize(region, modes)

    # fan-out: every (region, mode) pair measured by exactly one worker
    base = str(tmp_path / "fan.jsonl")
    worker_results = {}
    for i in (0, 1):
        region, _, ctl = fresh()
        c = Campaign(worker_store(base, i, 2), ctl)
        res = c.measure_shard([region], modes, index=i, count=2)
        assert c.stats.cached == 0 and c.stats.measured > 0
        assert not set(res) & set(worker_results)    # disjoint slices
        worker_results.update(res)
    assert set(worker_results) == {("fan_region", m) for m in modes}

    stats = merge_stores(base, [worker_store(base, i, 2) for i in (0, 1)])
    assert not stats.conflicts

    region, traces, ctl = fresh()
    merged = Campaign(base, ctl)
    rep = merged.characterize(region, modes)
    assert merged.stats.measured == 0               # ZERO new measurements
    assert traces["n"] == 0                         # not even a compile
    for m in modes:                                 # byte-identical replay
        assert rep.results[m] == worker_results[("fan_region", m)]
        assert rep.results[m] == ref.results[m]     # == single-store run
    assert rep.bottleneck.label == ref.bottleneck.label


def test_merge_is_idempotent_and_order_independent(tmp_path, fake_measure):
    a = str(tmp_path / "a.jsonl")
    b = str(tmp_path / "b.jsonl")
    for path, name in ((a, "rA"), (b, "rB")):     # disjoint key sets
        region, _, = _make_counting_region(name)[:2]
        Campaign(path, Controller(reps=2, verify_payload=False)) \
            .sweep_mode(region, "fp_add32")
    ab = str(tmp_path / "ab.jsonl")
    ba = str(tmp_path / "ba.jsonl")
    merge_stores(ab, [a, b])
    merge_stores(ba, [b, a])
    assert open(ab).read() == open(ba).read()     # order-independent
    again = str(tmp_path / "again.jsonl")
    merge_stores(again, [ab])
    assert open(again).read() == open(ab).read()  # idempotent
    merge_stores(ab, [ab, ba])                    # dest may be a source
    assert open(again).read() == open(ab).read()


def test_merge_meta_conflict_later_store_wins(tmp_path):
    """The same pair measured under different settings in two stores must
    not splice: the later source supersedes the earlier pair entirely."""
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    for path, reps, t in ((a, 2, 0.5), (b, 3, 0.9)):
        st = CampaignStore(path)
        st.append({"kind": "meta", "region": "r", "mode": "m",
                   "reps": reps, "compile_once": True})
        st.append({"kind": "point", "region": "r", "mode": "m",
                   "k": 0, "t": t})
        st.append({"kind": "point", "region": "r", "mode": "m",
                   "k": 4 if reps == 2 else 8, "t": t})
        st.close()
    out = str(tmp_path / "m.jsonl")
    stats = merge_stores(out, [a, b])
    assert ("r", "m") in stats.conflicts
    st = CampaignStore(out)
    st.close()
    assert st.meta[("r", "m")]["reps"] == 3
    assert st.stored_ts("r", "m") == {0: 0.9, 8: 0.9}   # a's points dropped


def test_merge_stores_cleans_tmp_on_corrupt_source(tmp_path):
    """Satellite regression: a source raising CampaignStoreError mid-merge
    must not leave ``dest + '.merge-tmp'`` behind, and must not touch an
    existing dest."""
    good = str(tmp_path / "good.jsonl")
    st = CampaignStore(good)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 0.5})
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 2, "t": 0.6})
    st.close()
    bad = str(tmp_path / "bad.jsonl")
    lines = open(good).read().strip().split("\n")
    with open(bad, "w") as f:   # corrupt MIDDLE record: loader hard-fails
        f.write(lines[0][:-4] + "\n" + lines[1] + "\n")
    dest = str(tmp_path / "dest.jsonl")
    with open(dest, "w") as f:
        f.write(lines[0] + "\n")
    before = open(dest).read()
    with pytest.raises(CampaignStoreError):
        merge_stores(dest, [good, bad])
    assert not glob.glob(dest + ".merge-tmp*")
    assert open(dest).read() == before          # dest untouched by the abort
    # and a successful merge leaves no tmp either
    merge_stores(dest, [good])
    assert not glob.glob(dest + ".merge-tmp*")


def test_concurrent_merges_use_distinct_tmp_names(tmp_path):
    """Regression: two merges into the SAME dest used to share the literal
    ``dest + '.merge-tmp'`` scratch name, so concurrent merges could rename
    each other's half-written tmp into place. The tmp name is now unique
    per call, and every call still cleans its own tmp up."""
    import threading

    from repro.core.campaign import _MERGE_TMP_COUNT

    srcs = []
    for i in range(4):
        p = str(tmp_path / f"s{i}.jsonl")
        st = CampaignStore(p)
        for k in range(32):
            st.append({"kind": "point", "region": f"r{i}", "mode": "m",
                       "k": k, "t": 0.1 * (k + 1)})
        st.close()
        srcs.append(p)
    dest = str(tmp_path / "dest.jsonl")
    c0 = next(_MERGE_TMP_COUNT)
    errs = []

    def one():
        try:
            merge_stores(dest, srcs)
        except Exception as e:          # pragma: no cover - the regression
            errs.append(e)

    threads = [threading.Thread(target=one) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert next(_MERGE_TMP_COUNT) >= c0 + 5     # each call drew a fresh name
    assert not glob.glob(dest + ".merge-tmp*")  # nobody leaked a tmp
    st = CampaignStore(dest, readonly=True)
    st.close()
    assert len(st.points) == 4 and all(len(v) == 32
                                       for v in st.points.values())


def _meta(reps, **kw):
    return {"kind": "meta", "region": "r", "mode": "m", "reps": reps,
            "compile_once": True, **kw}


_AUDIT = {"kind": "audit", "region": "r", "mode": "m", "verdict": "dead",
          "survival": 0.0, "corruption": None, "predicted": "fp",
          "target": "fp", "agrees": None, "resources": {}, "k_lo": 1,
          "k_hi": 8, "detail": "stale"}


def test_meta_conflict_drops_stale_audit_in_store_replay(tmp_path):
    """Regression: a settings change discarded the pair's points/sens/done
    but KEPT its audit record, so stale static-audit evidence (measured
    under the old settings) annotated the re-measured pair. preds carry
    their settings inline and must survive."""
    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append(_meta(2))
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 1.0})
    st.append(dict(_AUDIT))
    st.append({"kind": "pred", "region": "r", "mode": "m", "ks": [0],
               "ts": [1.0], "fit": {}, "hw": {}, "terms": {}, "alpha": 1.0,
               "tol": 0.05, "k_max": 8})
    st.append(_meta(3))                         # settings conflict
    st.close()
    assert ("r", "m") not in st.points
    assert ("r", "m") not in st.audits          # the stale audit is gone
    assert ("r", "m") in st.preds               # preds supersede on their own
    # and the same discard happens on a cold replay of the file
    st2 = CampaignStore(path, readonly=True)
    st2.close()
    assert ("r", "m") not in st2.audits and ("r", "m") in st2.preds


def test_merge_meta_conflict_drops_stale_audit(tmp_path):
    """The merge view applies the same rule across stores: the earlier
    source's audit must not survive a meta conflict with a later source."""
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    st = CampaignStore(a)
    st.append(_meta(2))
    st.append(dict(_AUDIT))
    st.close()
    st = CampaignStore(b)
    st.append(_meta(3))
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 2.0})
    st.close()
    out = str(tmp_path / "m.jsonl")
    stats = merge_stores(out, [a, b])
    assert ("r", "m") in stats.conflicts
    merged = CampaignStore(out, readonly=True)
    merged.close()
    assert ("r", "m") not in merged.audits
    assert merged.stored_ts("r", "m") == {0: 2.0}
    assert "audit" not in open(out).read()      # dropped from the bytes too


def test_inspect_reports_grid_completeness(tmp_path, capsys):
    """Satellite: ``inspect`` reports per-(region, mode) points present vs
    expected, flags missing ks, and summarizes grid completeness."""
    from repro.core.campaign import _cli

    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "point", "region": "rA", "mode": "m", "k": 0, "t": 1.0})
    st.append({"kind": "point", "region": "rA", "mode": "m", "k": 2, "t": 1.0})
    st.append({"kind": "done", "region": "rA", "mode": "m", "ks": [0, 2, 4],
               "drift": None, "stopped_early": False, "payload": None})
    st.append({"kind": "point", "region": "rB", "mode": "m", "k": 0, "t": 1.0})
    st.close()
    assert _cli(["inspect", path]) == 0
    out = capsys.readouterr().out
    assert "measured rA/m: 2/3 point(s), done, MISSING ks [4]" in out
    assert "measured rB/m: 1 point(s), in progress" in out
    assert "grid: 0/2 measured pair(s) complete" in out


def test_merge_cli_round_trip(tmp_path, capsys):
    from repro.core.campaign import _cli

    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    for path, region in ((a, "r1"), (b, "r2")):
        st = CampaignStore(path)
        st.append({"kind": "point", "region": region, "mode": "m",
                   "k": 0, "t": 0.25})
        st.close()
    out = str(tmp_path / "merged.jsonl")
    assert _cli(["merge", out, a, b]) == 0
    st = CampaignStore(out)
    st.close()
    assert st.stored_ts("r1", "m") == {0: 0.25}
    assert st.stored_ts("r2", "m") == {0: 0.25}
    assert _cli(["inspect", out]) == 0
    assert "r1/m" in capsys.readouterr().out


def test_measure_shard_covers_grid_exactly_once(tmp_path, fake_measure):
    regions = [_make_counting_region(f"g{i}")[0] for i in range(2)]
    modes = ["fp_add32", "vmem_ld"]
    seen = []
    for i in range(3):
        c = Campaign(str(tmp_path / f"w{i}.jsonl"),
                     Controller(reps=2, verify_payload=False))
        seen += list(c.measure_shard(regions, modes, index=i, count=3))
    assert sorted(seen) == sorted((r.name, m) for r in regions for m in modes)
    with pytest.raises(ValueError, match="shard index"):
        Campaign(str(tmp_path / "w9.jsonl")).measure_shard(
            regions, modes, index=3, count=3)


# ---------------------------------------------------------------------------
# store-backed DECAN + the compile-once noise arm of a DecanTarget
# ---------------------------------------------------------------------------

def _counting_decan(name="dec"):
    traces = {"n": 0}
    X = jax.random.normal(jax.random.PRNGKey(0), (16, 64))

    def kernel(fp, ls, noise=None, k=0):
        def fn(x, *nc):
            traces["n"] += 1
            out = jnp.float32(0)
            if fp:
                out = out + jnp.sum(jnp.tanh(x) * 0.5)
            if ls:
                out = out + jnp.sum(x[::4])
            if noise is not None:
                c = jax.lax.fori_loop(
                    0, 8, lambda i, c: noise.emit(c, k, i), nc[0])
                return out, noise.finalize(c)
            return out
        return jax.jit(fn)

    target = DecanTarget(name, kernel, lambda: (X,),
                         build_noisy=lambda noise, k:
                             kernel(True, True, noise, k))
    return target, traces


def test_run_decan_replays_from_store(tmp_path):
    target, _ = _counting_decan()
    c1 = Campaign(str(tmp_path / "d.jsonl"),
                  Controller(reps=2, verify_payload=False))
    r1 = c1.run_decan(target)
    assert c1.stats.measured == 3 and c1.stats.cached == 0

    target2, traces2 = _counting_decan()
    c2 = Campaign(str(tmp_path / "d.jsonl"),
                  Controller(reps=2, verify_payload=False))
    r2 = c2.run_decan(target2)
    assert c2.stats.measured == 0 and c2.stats.cached == 3
    assert traces2["n"] == 0                       # replay never compiles
    assert r2 == r1                                # byte-identical timings

    # different settings supersede instead of replaying
    target3, _ = _counting_decan()
    c3 = Campaign(str(tmp_path / "d.jsonl"),
                  Controller(reps=3, verify_payload=False))
    c3.run_decan(target3)
    assert c3.stats.measured == 3


def test_decan_region_noise_arm_compiles_at_most_two(tmp_path):
    """Acceptance (table3 pattern): the noise arm of a DecanTarget sweeps a
    whole (scenario, mode) grid point on ≤2 executables — including the
    sensitivity probe — instead of one per k."""
    target, traces = _counting_decan("dec_rt")
    region = target.region()
    camp = Campaign(str(tmp_path / "d.jsonl"),
                    Controller(reps=2, verify_payload=False))
    res = camp.sweep_mode(region, "fp_add")
    assert traces["n"] <= 2, f"{traces['n']} executables for one sweep"
    assert len(res.curve.ks) >= 3

    # second mode: its own runtime-k executable, still ≤2 more
    camp.sweep_mode(region, "l1_ld")
    assert traces["n"] <= 4


def test_decan_region_requires_build_noisy():
    target = DecanTarget("bare", lambda fp, ls: (lambda: 0), lambda: ())
    with pytest.raises(ValueError, match="build_noisy"):
        target.region()


def test_campaign_sweep_with_sensitivity_compiles_at_most_two():
    """The memoized runtime-k callable: sensitivity probe + sweep + drift
    check share ONE executable (payload verification adds the second)."""
    region, traces = _make_counting_region("memo_region")
    camp = Campaign(CampaignStore(os.devnull), Controller(reps=2))
    camp.sweep_mode(region, "fp_add32")
    assert traces["n"] <= 2, f"{traces['n']} executables incl. sensitivity"


def test_final_record_missing_newline_is_healed(tmp_path):
    """A torn append that flushed the whole record but not its '\\n' must
    not glue the next append onto the same line: the loader heals the
    terminator and keeps the record (zero points lost)."""
    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 0.5})
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 2, "t": 0.6})
    st.close()
    with open(path, "r+b") as f:        # strip ONLY the final newline
        f.truncate(os.path.getsize(path) - 1)

    st2 = CampaignStore(path)
    assert st2.stored_ts("r", "m") == {0: 0.5, 2: 0.6}   # nothing lost
    st2.append({"kind": "point", "region": "r", "mode": "m", "k": 4, "t": 0.7})
    st2.close()
    st3 = CampaignStore(path)            # and the file stayed line-per-record
    st3.close()
    assert st3.stored_ts("r", "m") == {0: 0.5, 2: 0.6, 4: 0.7}


def test_readonly_store_neither_creates_nor_heals(tmp_path):
    missing = str(tmp_path / "nope.jsonl")
    with pytest.raises(FileNotFoundError):
        CampaignStore(missing, readonly=True)
    assert not os.path.exists(missing)   # inspection must not create stores

    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 0, "t": 0.5})
    st.close()
    with open(path, "ab") as f:          # torn tail
        f.write(b'{"kind": "poi')
    before = open(path, "rb").read()
    ro = CampaignStore(path, readonly=True)
    ro.close()
    assert ro.stored_ts("r", "m") == {0: 0.5}
    assert open(path, "rb").read() == before     # readonly: file untouched
    with pytest.raises(RuntimeError, match="readonly"):
        ro.append({"kind": "sens", "region": "r", "mode": "m", "value": 1.0})
    CampaignStore(path).close()                  # writable open heals it
    assert open(path, "rb").read() != before


def test_rt_cache_is_per_target_not_per_name():
    """Two same-named targets on one Controller must not share a runtime-k
    executable: the cache keys on target identity."""
    ctl = Controller(reps=2, verify_payload=False)
    region_a, traces_a = _make_counting_region("same_name")
    region_b, traces_b = _make_counting_region("same_name")
    fn_a = ctl._rt_fn(region_a, "fp_add32")
    fn_b = ctl._rt_fn(region_b, "fp_add32")
    assert fn_a is ctl._rt_fn(region_a, "fp_add32")   # memoized per target
    assert fn_a is not fn_b                           # not shared by name
    fn_b(jnp.int32(1), *region_b.args_for_rt("fp_add32"))
    assert traces_b["n"] == 1 and traces_a["n"] == 0  # b's fn runs b's step

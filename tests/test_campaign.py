"""Campaign engine + compile-once sweep path: trace-count guarantees,
static/runtime-k equivalence, store resume semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Campaign, CampaignStore, Controller, step_region
from repro.core.absorption import DEFAULT_KS
from repro.core.controller import loop_region
from repro.core.loopnoise import make_loop_modes
from repro.core.noise import NoiseScale, make_modes

MODES = make_modes(NoiseScale(hbm_mib=4, chase_len=1 << 16, mxu_dim=32))


def _make_counting_region(name="tiny"):
    """A tiny region whose step counts Python traces — each jit compilation
    traces exactly once, so the counter counts compiled executables."""
    traces = {"n": 0}

    def step(x):
        traces["n"] += 1
        W = jnp.eye(64) * 0.5
        return jnp.tanh(x @ W) @ W

    X = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    return step_region(name, step, (X,), MODES), traces


# ---------------------------------------------------------------------------
# compile-once path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fp_add32", "mxu_fma128", "vmem_ld",
                                  "hbm_stream", "hbm_latency"])
@pytest.mark.parametrize("k", [1, 3, 8])
def test_runtime_k_matches_static(mode, k):
    """apply_rt(state, k) must emit the same patterns as apply(state, k):
    identical aux and identical new state, so both sweep paths measure the
    same injected work."""
    m = MODES[mode]
    state = m.make_state(jax.random.PRNGKey(0))
    aux_s, new_s = m.apply(state, k)
    aux_r, new_r = jax.jit(m.apply_rt)(state, jnp.int32(k))
    np.testing.assert_allclose(np.asarray(aux_s), np.asarray(aux_r),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(new_s), jax.tree.leaves(new_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["fp_add", "fp_fma", "l1_ld", "chase"])
def test_loop_emit_rt_matches_static(mode):
    m = make_loop_modes()[mode]
    nc = m.init(jax.random.PRNGKey(0))
    for k in (1, 5):
        s = m.emit(nc, k, jnp.int32(3))
        r = jax.jit(lambda c, kk: m.emit_rt(c, kk, jnp.int32(3)))(
            nc, jnp.int32(k))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


def test_sweep_compiles_at_most_two_executables():
    """Acceptance: a DEFAULT_KS sweep on the compile-once path traces at most
    2 executables (the runtime-k one + the static payload check) instead of
    one per k."""
    region, traces = _make_counting_region()
    ctl = Controller(reps=2, compile_once=True)
    res = ctl.run_mode(region, "fp_add32", ks=DEFAULT_KS)
    assert traces["n"] <= 2, f"{traces['n']} executables for one sweep"
    assert len(res.curve.ks) >= 3    # the sweep actually happened
    assert res.injection is not None  # payload was verified (static trace)


def test_fallback_compiles_per_k():
    region, traces = _make_counting_region()
    ctl = Controller(reps=2, compile_once=False, verify_payload=False)
    ctl.run_mode(region, "fp_add32", ks=(0, 2, 4, 8))
    assert traces["n"] >= 4          # the paper's cost model: one per k


def test_compile_once_and_fallback_same_classification():
    """A/B check: both sweep paths characterize a small region identically
    (same surviving-payload verdicts; classification from real timings may
    wobble, absorption fit fields must exist on both)."""
    region, _ = _make_counting_region("ab_region")
    ks = (0, 2, 4, 8, 16)
    fast = Controller(reps=2, compile_once=True)
    slow = Controller(reps=2, compile_once=False)
    r_fast = fast.run_mode(region, "fp_add32", ks=ks)
    r_slow = slow.run_mode(region, "fp_add32", ks=ks)
    assert r_fast.curve.ks[:3] == r_slow.curve.ks[:3] == [0, 2, 4]
    assert r_fast.injection.payload == r_slow.injection.payload
    assert r_fast.fit.t0 > 0 and r_slow.fit.t0 > 0


def test_loop_region_build_rt_matches_static():
    from repro.bench.kernels import stream_region

    r = stream_region(n=1 << 14)
    out_s = r.build("fp_add", 4)(*r.args_for("fp_add", 4))
    out_rt = r.build_rt("fp_add")(jnp.int32(4), *r.args_for_rt("fp_add"))
    for a, b in zip(jax.tree.leaves(out_s), jax.tree.leaves(out_rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# campaign store + resume
# ---------------------------------------------------------------------------

def test_campaign_resume_measures_nothing(tmp_path):
    """Acceptance: re-running a completed campaign performs ZERO new
    measurements and reproduces the same RegionReport classification."""
    store = str(tmp_path / "store.jsonl")
    region1, _ = _make_counting_region("resume_region")
    c1 = Campaign(store, Controller(reps=2))
    rep1 = c1.characterize(region1, ["fp_add32", "vmem_ld"])
    assert c1.stats.measured > 0

    region2, traces2 = _make_counting_region("resume_region")
    c2 = Campaign(store, Controller(reps=2))
    rep2 = c2.characterize(region2, ["fp_add32", "vmem_ld"])
    assert c2.stats.measured == 0
    assert traces2["n"] == 0                      # not even a compile
    assert rep2.bottleneck.label == rep1.bottleneck.label
    for m in rep1.results:
        assert rep2.results[m].curve.ks == rep1.results[m].curve.ks
        assert rep2.results[m].curve.ts == rep1.results[m].curve.ts
        assert rep2.results[m].fit.k1 == rep1.results[m].fit.k1
        if rep1.results[m].injection is not None:
            assert (rep2.results[m].injection.payload
                    == rep1.results[m].injection.payload)


def test_campaign_partial_store_resumes_missing_points(tmp_path):
    """An interrupted campaign (points stored, no 'done' marker) resumes at
    the missing ks instead of remeasuring the stored prefix."""
    store_path = str(tmp_path / "store.jsonl")
    region, _ = _make_counting_region("partial_region")
    ctl = Controller(reps=2, verify_payload=False)

    c1 = Campaign(store_path, ctl)
    full = c1.sweep_mode(region, "fp_add32")
    n_points = len(full.curve.ks)

    # rebuild a truncated store: sensitivity + the first two points only
    trunc = str(tmp_path / "trunc.jsonl")
    st = CampaignStore(trunc)
    st.append({"kind": "sens", "region": "partial_region",
               "mode": "fp_add32", "value": c1.store.sens[
                   ("partial_region", "fp_add32")]})
    for k in full.curve.ks[:2]:
        st.append({"kind": "point", "region": "partial_region",
                   "mode": "fp_add32", "k": k,
                   "t": c1.store.stored_ts("partial_region", "fp_add32")[k]})
    st.close()

    region2, _ = _make_counting_region("partial_region")
    c2 = Campaign(trunc, ctl)
    res = c2.sweep_mode(region2, "fp_add32")
    assert c2.stats.cached == 2                  # stored prefix replayed
    assert c2.stats.measured == n_points - 2     # only the tail measured
    assert res.curve.ks == full.curve.ks
    assert c2.store.is_done("partial_region", "fp_add32")


def test_campaign_settings_mismatch_discards_store(tmp_path):
    """A store measured under different settings (reps / sweep path) must not
    be spliced into a new curve: the pair is discarded and remeasured."""
    store = str(tmp_path / "s.jsonl")
    region1, _ = _make_counting_region("meta_region")
    c1 = Campaign(store, Controller(reps=2, verify_payload=False))
    c1.sweep_mode(region1, "fp_add32")

    region2, _ = _make_counting_region("meta_region")
    c2 = Campaign(store, Controller(reps=3, verify_payload=False))
    c2.sweep_mode(region2, "fp_add32")
    assert c2.stats.measured > 0          # stored sweep was NOT replayed
    assert c2.stats.cached == 0

    # same settings again -> replay, nothing measured
    region3, _ = _make_counting_region("meta_region")
    c3 = Campaign(store, Controller(reps=3, verify_payload=False))
    c3.sweep_mode(region3, "fp_add32")
    assert c3.stats.measured == 0


def test_campaign_worker_pool(tmp_path):
    region, _ = _make_counting_region("pool_region")
    c = Campaign(str(tmp_path / "s.jsonl"),
                 Controller(reps=2, verify_payload=False), workers=3)
    reps = c.run([region], ["fp_add32", "vmem_ld", "hbm_stream"])
    assert set(reps["pool_region"].results) == {"fp_add32", "vmem_ld",
                                                "hbm_stream"}
    assert c.stats.measured > 0


def test_store_survives_reload(tmp_path):
    path = str(tmp_path / "s.jsonl")
    st = CampaignStore(path)
    st.append({"kind": "point", "region": "r", "mode": "m", "k": 4, "t": 0.5})
    st.append({"kind": "sens", "region": "r", "mode": "m", "value": 1.5})
    st.close()
    st2 = CampaignStore(path)
    assert st2.stored_ts("r", "m") == {4: 0.5}
    assert st2.sens[("r", "m")] == 1.5
    st2.close()


# ---------------------------------------------------------------------------
# div-zero hardening (satellite)
# ---------------------------------------------------------------------------

def test_zero_baseline_clamped_with_warning():
    from repro.core.absorption import AbsorptionCurve

    curve = AbsorptionCurve(mode="m", ks=[0, 1], ts=[0.0, 1.0])
    with pytest.warns(RuntimeWarning, match="timer resolution"):
        r = curve.ratios()
    assert np.all(np.isfinite(r))


def test_probe_sensitivity_zero_baseline(monkeypatch):
    import repro.core.controller as ctl_mod

    region, _ = _make_counting_region("zero_t0")
    monkeypatch.setattr(ctl_mod, "measure", lambda *a, **k: 0.0)
    c = Controller(reps=2)
    with pytest.warns(RuntimeWarning, match="timer resolution"):
        s = c.probe_sensitivity(region, "fp_add32")
    assert np.isfinite(s)

"""Golden-signature regression suite.

``tests/golden/signatures.jsonl`` is a checked-in campaign store of tiny
fixed-seed synthetic absorption signatures (one region per paper bottleneck
class); ``tests/golden/expected.json`` holds the fit fields and
BottleneckReport each must replay to. Replaying the store through the
Campaign engine exercises the full curve-assembly path — stored raw points,
recorded drift correction, hinge fit, threshold cross-check, classification
— so a refactor that changes any of those FAILS HERE instead of silently
reclassifying the paper's decision table.

Intentional changes: regenerate with
``PYTHONPATH=src python tests/golden/regen.py`` and say why in the commit.
"""
import json
import os
import shutil

import pytest

from repro.core import Campaign, Controller, RegionTarget

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

with open(os.path.join(GOLDEN_DIR, "expected.json")) as f:
    EXPECTED = json.load(f)


def _fail_build(*a, **k):
    raise AssertionError("golden replay must never build or measure")


@pytest.fixture()
def golden_store(tmp_path):
    # copy: replaying opens the store for append, and the checked-in
    # fixture must never be touched by a test run
    dst = str(tmp_path / "signatures.jsonl")
    shutil.copy(os.path.join(GOLDEN_DIR, "signatures.jsonl"), dst)
    return dst


@pytest.mark.parametrize("region", sorted(EXPECTED), ids=sorted(EXPECTED))
def test_golden_signature_replays_identically(golden_store, region):
    exp = EXPECTED[region]
    camp = Campaign(golden_store, Controller(reps=2, verify_payload=False))
    target = RegionTarget(name=region, build=_fail_build,
                          args_for=_fail_build)
    rep = camp.characterize(target, sorted(exp["modes"]))

    assert camp.stats.measured == 0
    assert rep.bottleneck.label == exp["label"]
    assert rep.bottleneck.confidence == pytest.approx(exp["confidence"],
                                                      rel=1e-6, abs=1e-9)
    assert rep.body_size == exp["body_size"]
    for mode, fields in exp["modes"].items():
        fit = rep.results[mode].fit
        for name, want in fields.items():
            got = getattr(fit, name)
            assert got == pytest.approx(want, rel=1e-6, abs=1e-12), (
                f"{region}/{mode}.{name}: replayed {got!r}, golden {want!r} "
                "— curve assembly / fit / classifier changed; if intended, "
                "regenerate via tests/golden/regen.py")


def test_golden_store_is_policyless_and_guard_invariant(golden_store):
    """The measurement-integrity guard grew the store schema — "quality"
    records, point "spread", done "sentinels" — but the golden fixtures are
    intentionally UNCHANGED: they were measured without a policy so they
    carry none of the new fields, and replaying them with a quality policy
    attached still measures nothing and classifies identically (every point
    is cached and nothing is quarantined, so nothing heals)."""
    from repro.core import QualityPolicy

    with open(os.path.join(GOLDEN_DIR, "signatures.jsonl")) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    assert all(r["kind"] != "quality" for r in recs)
    assert all("spread" not in r for r in recs if r["kind"] == "point")
    assert all("sentinels" not in r for r in recs if r["kind"] == "done")

    region = sorted(EXPECTED)[0]
    exp = EXPECTED[region]
    camp = Campaign(golden_store, Controller(reps=2, verify_payload=False),
                    quality=QualityPolicy())
    target = RegionTarget(name=region, build=_fail_build,
                          args_for=_fail_build)
    rep = camp.characterize(target, sorted(exp["modes"]))
    assert camp.stats.measured == 0
    assert rep.bottleneck.label == exp["label"]


def test_golden_covers_every_decision_label():
    labels = {e["label"] for e in EXPECTED.values()}
    assert labels == {"compute", "bandwidth", "latency", "ici", "overlap",
                      "mixed", "l1"}


def test_golden_mixes_all_mode_vocabularies():
    modes = {m for e in EXPECTED.values() for m in e["modes"]}
    assert modes & {"fp_add", "l1_ld", "mem_ld"}          # loop-level
    assert modes & {"fp_add32", "vmem_ld", "hbm_stream"}  # graph-level
    assert modes & {"fp", "mxu", "vmem"}                  # Pallas kernel-level

"""Mamba2/SSD invariants: chunked form == sequential recurrence oracle;
decode step == one-step chunked; state carry across chunk boundaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("mamba2_780m")


def ssd_sequential(x, Bm, Cm, dt, A, init_state=None):
    """The O(S·N) sequential recurrence the chunked form must match."""
    Bb, S, nh, hp = x.shape
    ng, N = Bm.shape[2], Bm.shape[3]
    hpg = nh // ng
    Bh = jnp.repeat(Bm, hpg, axis=2) if ng != nh else Bm
    Ch = jnp.repeat(Cm, hpg, axis=2) if ng != nh else Cm
    state = (jnp.zeros((Bb, nh, hp, N), jnp.float32) if init_state is None
             else init_state)
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])                     # (B,nh)
        upd = (dt[:, t, :, None] * x[:, t])[..., None] * Bh[:, t, :, None, :]
        state = state * decay[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t]))
    return jnp.stack(ys, axis=1), state


def _random_ssd_inputs(cfg, B, S, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    nh, hp, ng, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    x = jax.random.normal(ks[0], (B, S, nh, hp), jnp.float32)
    Bm = jax.random.normal(ks[1], (B, S, ng, N), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, ng, N), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh), jnp.float32))
    A = -jnp.exp(jnp.linspace(-1.0, 1.0, nh))
    return x, Bm, Cm, dt, A


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (24, 24)])
def test_chunked_matches_sequential(cfg, S, chunk):
    c = cfg.scaled(ssm_chunk=chunk)
    x, Bm, Cm, dt, A = _random_ssd_inputs(c, 2, S)
    y_chunk, st_chunk = ssm.ssd_chunked(c, x, Bm, Cm, dt, A)
    y_seq, st_seq = ssd_sequential(x, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_seq),
                               rtol=2e-4, atol=2e-4)


def test_initial_state_carry(cfg):
    """Processing [first half] then [second half with carried state] equals
    processing the full sequence — the prefill-chunking invariant."""
    c = cfg.scaled(ssm_chunk=8)
    x, Bm, Cm, dt, A = _random_ssd_inputs(c, 2, 32)
    y_full, st_full = ssm.ssd_chunked(c, x, Bm, Cm, dt, A)
    y1, st1 = ssm.ssd_chunked(c, x[:, :16], Bm[:, :16], Cm[:, :16],
                              dt[:, :16], A)
    y2, st2 = ssm.ssd_chunked(c, x[:, 16:], Bm[:, 16:], Cm[:, 16:],
                              dt[:, 16:], A, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward(cfg):
    """Block-level: sequential ssm_decode_step == ssm_block on the prefix."""
    c = cfg
    p = ssm.init_ssm(jax.random.PRNGKey(0), c)
    B, S = 2, 12
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, c.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out_full = ssm.ssm_block(p, c, h)
    cache = ssm.init_ssm_cache(c, B)
    outs = []
    for t in range(S):
        o, cache = ssm.ssm_decode_step(p, c, h[:, t:t + 1], cache)
        outs.append(o[:, 0])
    out_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec, np.float32),
                               np.asarray(out_full, np.float32),
                               rtol=5e-2, atol=5e-2)

"""The JAX version-compat layer: every shim must resolve on the installed
JAX (whatever its version) and the fallback branches must behave like the
modern API they stand in for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


def test_no_direct_new_api_uses_in_src():
    """Compat policy: nothing under src/repro/ (except compat.py itself)
    touches a version-dependent JAX surface directly — every such call goes
    through repro.compat so both CI pins keep working.  The walk must
    actually reach every package (kernels/, fleet/, analysis/, ... were
    added after this scan was first written; a silent miss would void it)."""
    import os
    root = os.path.join(os.path.dirname(compat.__file__))
    banned = ("jax.sharding.get_abstract_mesh", "jax.sharding.AxisType",
              "jax.lax.axis_size", "jax.sharding.use_mesh", "jax.set_mesh",
              "jax.shard_map", "jax.experimental.shard_map",
              "pltpu.PrefetchScalarGridSpec")
    must_scan = {"core", "hlo", "kernels", "fleet", "launch", "analysis"}
    scanned_pkgs = set()
    hits = []
    for dirpath, _, files in os.walk(root):
        rel = os.path.relpath(dirpath, root)
        if rel != ".":
            scanned_pkgs.add(rel.split(os.sep)[0])
        for fn in files:
            if not fn.endswith(".py") or fn == "compat.py":
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                text = f.read()
            hits += [f"{path}: {b}" for b in banned if b in text]
    missing = must_scan - scanned_pkgs
    assert not missing, f"compat scan never reached packages: {missing}"
    assert not hits, hits


def test_make_mesh_works_on_installed_jax():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert compat.mesh_axis_sizes(mesh) == {"data": 1}


def test_axis_types_auto_matches_feature_detection():
    kw = compat.axis_types_auto(2)
    if compat.AxisType is None:
        assert kw == {}
    else:
        assert kw == {"axis_types": (compat.AxisType.Auto,) * 2}


def test_abstract_mesh_both_signatures():
    m = compat.abstract_mesh((2, 4), ("data", "model"))
    assert m.axis_names == ("data", "model")
    assert compat.mesh_axis_sizes(m) == {"data": 2, "model": 4}


def test_get_abstract_mesh_none_outside_context():
    assert compat.get_abstract_mesh() is None


def test_set_mesh_roundtrip():
    mesh = compat.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        m = compat.get_abstract_mesh()
        assert m is not None
        assert tuple(m.axis_names) == ("data",)
    assert compat.get_abstract_mesh() is None


def test_shard_map_psum():
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "data"),
                         mesh, in_specs=P(), out_specs=P())
    out = f(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


def test_axis_size_inside_shard_map():
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: x * compat.axis_size("data"),
                         mesh, in_specs=P(), out_specs=P())
    np.testing.assert_allclose(np.asarray(f(jnp.ones(3))), np.ones(3))


def test_cost_analysis_normalized_to_dict():
    compiled = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
    cost = compat.cost_analysis(compiled)
    assert cost is None or hasattr(cost, "get")
    if cost is not None:
        assert cost.get("flops") is not None


def test_fallback_branches_when_modern_api_missing(monkeypatch):
    """Force the 0.4.x fallbacks regardless of installed version: the shims
    must still produce a working mesh context and shard_map."""
    monkeypatch.setattr(compat, "_get_abstract_mesh", None)
    monkeypatch.setattr(compat, "_set_mesh", None)
    monkeypatch.setattr(compat, "_shard_map", None)
    assert compat.get_abstract_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        m = compat.get_abstract_mesh()
        assert m is not None and tuple(m.axis_names) == ("data",)
    f = compat.shard_map(lambda x: jax.lax.psum(x, "data"),
                         mesh, in_specs=P(), out_specs=P())
    np.testing.assert_allclose(np.asarray(f(jnp.ones(2))), np.ones(2))

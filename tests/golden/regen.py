"""Regenerate the golden-signature and golden-HLO-audit fixtures.

    PYTHONPATH=src python tests/golden/regen.py

Writes ``signatures.jsonl`` (a campaign store of tiny fixed-seed synthetic
absorption signatures — one region per paper-style bottleneck class, with
curves drawn from the three-phase model plus deterministic jitter) and
``expected.json`` (the fit fields and BottleneckReport each region must
replay to). ``tests/test_golden_signatures.py`` replays the store through
the Campaign engine and compares against ``expected.json`` — a refactor of
curve assembly, the hinge fit, or the classifier that changes any signature
fails loudly instead of silently reclassifying.

Also writes ``hlo/*.txt.gz`` — optimized-HLO dumps (clean / K_LO / K_HI
static compiles) for every Pallas kernel plus a loop region — and
``audit_expected.json``, the exact ``AuditReport`` each trio must audit to.
``tests/test_analysis.py`` replays the checked-in texts through
``repro.analysis.audit_texts`` (pure text -> verdict, no compiler), so a
change to the census, the corruption detectors, or the resource tagging
fails loudly instead of silently re-verdicting. Compiled-HLO fixtures are
pin-dependent only at REGEN time; the replay itself never compiles.

Also writes ``regimes.json`` — the SPMXV regime-transition map: the
spmv_ell swap-probability sweep under per-q forced synthetic clocks
(``tests/test_regimes.py`` owns the sweep; this script just persists its
output), pinning each q's label/confidence/Abs^raw and hence where the
verdict crosses from compute through mixed into l1.

Regenerate ONLY when a change to curve assembly / fitting / classification
/ the audit pass / the regime-transition model is intentional, and say so
in the commit that updates these files.

NOTE (measurement-integrity guard): the runtime quality guard grew the
store schema — "quality" records, an optional "spread" on points and
"sentinels" on done markers — but these goldens are deliberately left
byte-identical: they are synthesized without a quality policy, so the new
fields never appear and every curve/fit/classify expectation is unchanged.
``test_golden_store_is_policyless_and_guard_invariant`` pins exactly that.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

HERE = os.path.dirname(os.path.abspath(__file__))
STORE = os.path.join(HERE, "signatures.jsonl")
EXPECTED = os.path.join(HERE, "expected.json")

SEED = 20260731
REPS = 2          # meta settings the replaying Controller must match
JITTER = 0.003    # multiplicative noise on each point (deterministic rng)

KS = [0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64]

# region -> (expected label, drift factor recorded in "done",
#            {mode: (t0_seconds, k1_knee, slope_fraction_per_pattern)})
# Mode vocabularies deliberately mix loop-level, graph-level and Pallas
# kernel-level names so the suite pins ALL THREE against the classifier's
# alias table.
REGIONS = {
    "golden_compute": ("compute", None, {            # HACCmk row (loop vocab)
        "fp_add": (2.0e-3, 0.0, 0.30),
        "l1_ld": (2.0e-3, 13.0, 0.20),
        "mem_ld": (2.0e-3, 30.0, 0.15),
    }),
    "golden_bandwidth": ("bandwidth", 1.10, {        # STREAM row (graph vocab)
        "fp_add32": (5.0e-3, 48.0, 0.25),
        "vmem_ld": (5.0e-3, 9.0, 0.22),
        "hbm_stream": (5.0e-3, 1.0, 0.40),
    }),
    "golden_latency": ("latency", None, {            # lat_mem_rd (graph vocab)
        "fp_add32": (1.0e-3, 40.0, 0.20),
        "hbm_stream": (1.0e-3, 11.0, 0.18),
    }),
    "golden_overlap": ("overlap", None, {            # Table 3 case 3
        "fp_add": (3.0e-3, 0.0, 0.35),
        "l1_ld": (3.0e-3, 1.0, 0.30),
    }),
    "golden_ici": ("ici", None, {                    # TPU extension
        "ici_allreduce": (8.0e-3, 1.0, 0.30),
        "fp_add32": (8.0e-3, 14.0, 0.20),
        "vmem_ld": (8.0e-3, 12.0, 0.20),
    }),
    "golden_mixed": ("mixed", None, {                # Table 3 case 4
        "fp_add": (4.0e-3, 8.0, 0.12),
        "l1_ld": (4.0e-3, 7.0, 0.12),
    }),
    "golden_pallas_lsu": ("l1", 1.05, {              # Fig 4a -O0 matmul row
        "fp": (1.5e-3, 30.0, 0.18),                  # (Pallas kernel vocab)
        "vmem": (1.5e-3, 1.0, 0.35),
    }),
}


def synth_ts(rng: np.random.Generator, t0: float, k1: float,
             slope_frac: float) -> list[float]:
    """Three-phase model samples: flat to k1, then linear, ±JITTER."""
    ts = []
    for k in KS:
        t = t0 * (1.0 + max(0.0, k - k1) * slope_frac)
        ts.append(float(t * (1.0 + rng.uniform(-JITTER, JITTER))))
    return ts


def build_store() -> list[dict]:
    rng = np.random.default_rng(SEED)
    records: list[dict] = []
    for region, (_, drift, modes) in REGIONS.items():
        records.append({"kind": "region", "region": region, "body_size": 24})
        for mode, (t0, k1, slope) in modes.items():
            ts = synth_ts(rng, t0, k1, slope)
            records.append({"kind": "meta", "region": region, "mode": mode,
                            "reps": REPS, "compile_once": False})
            records.append({"kind": "sens", "region": region, "mode": mode,
                            "value": ts[-1] / ts[0]})
            for k, t in zip(KS, ts):
                records.append({"kind": "point", "region": region,
                                "mode": mode, "k": k, "t": t})
            records.append({"kind": "done", "region": region, "mode": mode,
                            "ks": KS, "stopped_early": False,
                            "drift": drift, "payload": None})
    return records


def replay(store_path: str) -> dict:
    from repro.core import Campaign, Controller, RegionTarget

    def _fail(*a, **k):
        raise AssertionError("golden replay must never build or measure")

    out = {}
    for region, (label, _, modes) in REGIONS.items():
        camp = Campaign(store_path, Controller(reps=REPS,
                                               verify_payload=False))
        target = RegionTarget(name=region, build=_fail, args_for=_fail)
        rep = camp.characterize(target, list(modes))
        assert camp.stats.measured == 0, region
        assert rep.bottleneck.label == label, (
            f"{region}: synthetic signature classified as "
            f"{rep.bottleneck.label!r}, wanted {label!r} — retune REGIONS")
        out[region] = {
            "label": rep.bottleneck.label,
            "confidence": rep.bottleneck.confidence,
            "body_size": rep.body_size,
            "modes": {m: {f: getattr(r.fit, f) for f in
                          ("k1", "k2", "t0", "slope", "k1_threshold", "sse")}
                      for m, r in rep.results.items()},
        }
    return out


# ---------------------------------------------------------------------------
# Golden HLO audit fixtures: all four Pallas kernels + one loop region.
# Small sizes keep the gzipped texts a few hundred KB total; `interpret`
# keeps the compiles host-runnable on both CI pins.
# ---------------------------------------------------------------------------

HLO_DIR = os.path.join(HERE, "hlo")
AUDIT_EXPECTED = os.path.join(HERE, "audit_expected.json")
REGIMES_JSON = os.path.join(HERE, "regimes.json")


def build_regime_map() -> dict:
    """Delegate to tests/test_regimes.py's sweep (the harness owns the
    forced-shape model; regen only persists what it produces)."""
    import tempfile

    sys.path.insert(0, os.path.join(HERE, ".."))
    import test_regimes

    prior = os.environ.get("REPRO_SYNTH_MEASURE")
    os.environ["REPRO_SYNTH_MEASURE"] = test_regimes.BASE_S
    try:
        with tempfile.TemporaryDirectory() as d:
            return test_regimes.sweep_regime_map(
                os.path.join(d, "regimes.jsonl"))
    finally:
        if prior is None:
            del os.environ["REPRO_SYNTH_MEASURE"]
        else:
            os.environ["REPRO_SYNTH_MEASURE"] = prior


def _audit_targets():
    from repro.bench.kernels import stream_region
    from repro.kernels.region import pallas_region

    return [
        (pallas_region("probe", backend="interpret", n_steps=8), ["fp"]),
        (pallas_region("matmul", backend="interpret", n=256), ["mxu"]),
        (pallas_region("attention", backend="interpret", seq=64), ["vmem"]),
        (pallas_region("spmxv", backend="interpret", n=256), ["fp"]),
        (stream_region(n=4096, chunk=512), ["fp_add", "mem_ld"]),
    ]


def _write_gz(name: str, text: str) -> None:
    import gzip

    # fixed mtime=0 so a content-identical regen is byte-identical in git
    with open(os.path.join(HLO_DIR, name), "wb") as raw:
        with gzip.GzipFile(fileobj=raw, mode="wb", mtime=0) as f:
            f.write(text.encode())


def build_audit_fixtures() -> list[dict]:
    from repro.analysis import audit_texts, compile_text, compile_texts
    from repro.core.controller import _default_target

    os.makedirs(HLO_DIR, exist_ok=True)
    entries = []
    for target, modes in _audit_targets():
        clean = compile_text(target, "", 0)
        _write_gz(f"{target.name}__clean.txt.gz", clean)
        for mode in modes:
            _, lo, hi = compile_texts(target, mode, clean_text=clean)
            _write_gz(f"{target.name}__{mode}__lo.txt.gz", lo)
            _write_gz(f"{target.name}__{mode}__hi.txt.gz", hi)
            tgt = target.payload_target.get(mode, _default_target(mode))
            rep = audit_texts(clean, lo, hi, region=target.name, mode=mode,
                              target=tgt, hint=target.audit_hint)
            assert rep.verdict == "intact", (
                f"golden fixture must audit intact, got: {rep.explain()} — "
                "the kernel or the audit regressed; fix before regenerating")
            entries.append({"region": target.name, "mode": mode,
                            "target": tgt, "hint": dict(target.audit_hint),
                            "report": rep.to_dict()})
    return entries


def main() -> None:
    records = build_store()
    with open(STORE, "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    expected = replay(STORE)
    with open(EXPECTED, "w") as f:
        json.dump(expected, f, indent=1, sort_keys=True)
        f.write("\n")
    n_modes = sum(len(m) for _, _, m in REGIONS.values())
    print(f"wrote {STORE} ({len(records)} records, {len(REGIONS)} regions, "
          f"{n_modes} signatures) and {EXPECTED}")
    audits = build_audit_fixtures()
    with open(AUDIT_EXPECTED, "w") as f:
        json.dump(audits, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {HLO_DIR}/*.txt.gz and {AUDIT_EXPECTED} "
          f"({len(audits)} audited pairs)")
    regimes = build_regime_map()
    with open(REGIMES_JSON, "w") as f:
        json.dump(regimes, f, indent=1)     # sweep order matters: no sort
        f.write("\n")
    print(f"wrote {REGIMES_JSON} ({len(regimes)} q-cells)")


if __name__ == "__main__":
    main()

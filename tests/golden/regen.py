"""Regenerate the golden-signature fixtures.

    PYTHONPATH=src python tests/golden/regen.py

Writes ``signatures.jsonl`` (a campaign store of tiny fixed-seed synthetic
absorption signatures — one region per paper-style bottleneck class, with
curves drawn from the three-phase model plus deterministic jitter) and
``expected.json`` (the fit fields and BottleneckReport each region must
replay to). ``tests/test_golden_signatures.py`` replays the store through
the Campaign engine and compares against ``expected.json`` — a refactor of
curve assembly, the hinge fit, or the classifier that changes any signature
fails loudly instead of silently reclassifying.

Regenerate ONLY when a change to curve assembly / fitting / classification
is intentional, and say so in the commit that updates these files.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

HERE = os.path.dirname(os.path.abspath(__file__))
STORE = os.path.join(HERE, "signatures.jsonl")
EXPECTED = os.path.join(HERE, "expected.json")

SEED = 20260731
REPS = 2          # meta settings the replaying Controller must match
JITTER = 0.003    # multiplicative noise on each point (deterministic rng)

KS = [0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64]

# region -> (expected label, drift factor recorded in "done",
#            {mode: (t0_seconds, k1_knee, slope_fraction_per_pattern)})
# Mode vocabularies deliberately mix loop-level, graph-level and Pallas
# kernel-level names so the suite pins ALL THREE against the classifier's
# alias table.
REGIONS = {
    "golden_compute": ("compute", None, {            # HACCmk row (loop vocab)
        "fp_add": (2.0e-3, 0.0, 0.30),
        "l1_ld": (2.0e-3, 13.0, 0.20),
        "mem_ld": (2.0e-3, 30.0, 0.15),
    }),
    "golden_bandwidth": ("bandwidth", 1.10, {        # STREAM row (graph vocab)
        "fp_add32": (5.0e-3, 48.0, 0.25),
        "vmem_ld": (5.0e-3, 9.0, 0.22),
        "hbm_stream": (5.0e-3, 1.0, 0.40),
    }),
    "golden_latency": ("latency", None, {            # lat_mem_rd (graph vocab)
        "fp_add32": (1.0e-3, 40.0, 0.20),
        "hbm_stream": (1.0e-3, 11.0, 0.18),
    }),
    "golden_overlap": ("overlap", None, {            # Table 3 case 3
        "fp_add": (3.0e-3, 0.0, 0.35),
        "l1_ld": (3.0e-3, 1.0, 0.30),
    }),
    "golden_ici": ("ici", None, {                    # TPU extension
        "ici_allreduce": (8.0e-3, 1.0, 0.30),
        "fp_add32": (8.0e-3, 14.0, 0.20),
        "vmem_ld": (8.0e-3, 12.0, 0.20),
    }),
    "golden_mixed": ("mixed", None, {                # Table 3 case 4
        "fp_add": (4.0e-3, 8.0, 0.12),
        "l1_ld": (4.0e-3, 7.0, 0.12),
    }),
    "golden_pallas_lsu": ("l1", 1.05, {              # Fig 4a -O0 matmul row
        "fp": (1.5e-3, 30.0, 0.18),                  # (Pallas kernel vocab)
        "vmem": (1.5e-3, 1.0, 0.35),
    }),
}


def synth_ts(rng: np.random.Generator, t0: float, k1: float,
             slope_frac: float) -> list[float]:
    """Three-phase model samples: flat to k1, then linear, ±JITTER."""
    ts = []
    for k in KS:
        t = t0 * (1.0 + max(0.0, k - k1) * slope_frac)
        ts.append(float(t * (1.0 + rng.uniform(-JITTER, JITTER))))
    return ts


def build_store() -> list[dict]:
    rng = np.random.default_rng(SEED)
    records: list[dict] = []
    for region, (_, drift, modes) in REGIONS.items():
        records.append({"kind": "region", "region": region, "body_size": 24})
        for mode, (t0, k1, slope) in modes.items():
            ts = synth_ts(rng, t0, k1, slope)
            records.append({"kind": "meta", "region": region, "mode": mode,
                            "reps": REPS, "compile_once": False})
            records.append({"kind": "sens", "region": region, "mode": mode,
                            "value": ts[-1] / ts[0]})
            for k, t in zip(KS, ts):
                records.append({"kind": "point", "region": region,
                                "mode": mode, "k": k, "t": t})
            records.append({"kind": "done", "region": region, "mode": mode,
                            "ks": KS, "stopped_early": False,
                            "drift": drift, "payload": None})
    return records


def replay(store_path: str) -> dict:
    from repro.core import Campaign, Controller, RegionTarget

    def _fail(*a, **k):
        raise AssertionError("golden replay must never build or measure")

    out = {}
    for region, (label, _, modes) in REGIONS.items():
        camp = Campaign(store_path, Controller(reps=REPS,
                                               verify_payload=False))
        target = RegionTarget(name=region, build=_fail, args_for=_fail)
        rep = camp.characterize(target, list(modes))
        assert camp.stats.measured == 0, region
        assert rep.bottleneck.label == label, (
            f"{region}: synthetic signature classified as "
            f"{rep.bottleneck.label!r}, wanted {label!r} — retune REGIONS")
        out[region] = {
            "label": rep.bottleneck.label,
            "confidence": rep.bottleneck.confidence,
            "body_size": rep.body_size,
            "modes": {m: {f: getattr(r.fit, f) for f in
                          ("k1", "k2", "t0", "slope", "k1_threshold", "sse")}
                      for m, r in rep.results.items()},
        }
    return out


def main() -> None:
    records = build_store()
    with open(STORE, "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    expected = replay(STORE)
    with open(EXPECTED, "w") as f:
        json.dump(expected, f, indent=1, sort_keys=True)
        f.write("\n")
    n_modes = sum(len(m) for _, _, m in REGIONS.values())
    print(f"wrote {STORE} ({len(records)} records, {len(REGIONS)} regions, "
          f"{n_modes} signatures) and {EXPECTED}")


if __name__ == "__main__":
    main()

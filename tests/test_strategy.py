"""Strategy trees: decision-table completeness against the historical
if-chain, vocabulary equivalence, the YAML-subset parser, guarded-eval
rejection, and the calibrated-confidence regression.

The historical ``classify`` if-chain was deleted when the default strategy
tree replaced it; ``_legacy_classify`` below is a frozen verbatim copy (the
reference implementation, kept ONLY here) and the completeness test proves
the shipped tree reproduces it on every cell of a boundary-exhaustive
decision table — all four slots crossed over every threshold boundary, the
ICI group present/saturated/slack, under default AND non-default
thresholds.
"""
import glob
import os

import pytest

from repro.core.classifier import HIGH, LOW, classify
from repro.core.strategy import (StrategyError, StrategyTree,
                                 _parse_simple_yaml, default_tree,
                                 strategies_dir)

# ---------------------------------------------------------------------------
# The pre-strategy-tree classify if-chain, verbatim (labels, confidences and
# explanation strings). Do NOT edit: it is the fixed point the tree must
# reproduce.
# ---------------------------------------------------------------------------


def _legacy_classify(fp, l1, mem, chase, icis, *, low, high):
    known = {k: v for k, v in dict(fp=fp, l1=l1, mem=mem, chase=chase).items()
             if v is not None}

    def conf(sep):
        return max(0.0, min(1.0, sep / high))

    if icis and min(icis.values()) <= low:
        others = [v for v in known.values() if v is not None]
        if not others or min(others) >= high / 2:
            worst = min(icis, key=icis.get)
            return ("ici",
                    conf((min(others) if others else high) - icis[worst]),
                    f"collective noise ({worst}) not absorbed while core "
                    "resources have slack -> interconnect-bound")
    if fp is not None and fp <= low and (
            (l1 is not None and l1 >= max(high / 2, 3.0 * max(fp, 1.0)))
            or (mem is not None and mem >= high)):
        return ("compute", conf((l1 if l1 is not None else mem) - fp),
                "fp noise degrades immediately while data-access noise is "
                "absorbed -> compute-bound (HACCmk signature)")
    if mem is not None and mem <= low and (fp is None or fp >= high) \
            and (l1 is None or l1 > low):
        return ("bandwidth", conf((fp or high) - mem),
                "memory-stream noise not absorbed while fp noise is -> "
                "bandwidth-saturated (parallel-STREAM signature)")
    if (mem is not None and mem > low) and (fp is None or fp >= high):
        return ("latency", conf(mem - low),
                "substantial memory noise absorbed (stalls come from load "
                "dependencies, not bandwidth) -> latency-bound "
                "(lat_mem_rd signature)")
    if known and max(known.values()) <= low:
        return ("overlap", conf(low - max(known.values()) + high / 2),
                "no mode is absorbed: either full resource overlap (Table 3 "
                "case 3) or a shared upstream bottleneck (case 4) — run the "
                "DECAN cross-check to distinguish")
    if l1 is not None and l1 <= low and (fp is None or fp > low):
        return ("l1", conf((fp or high) - l1),
                "L1/LSU noise degrades first -> load/store-unit bound "
                "(the -O0 matmul signature, Fig. 4a)")
    return ("mixed", 0.3,
            "ambiguous absorption levels (moderate everywhere) indicating "
            "strong interdependencies (Table 3 case 4)")


def _cells(low, high):
    """Boundary-exhaustive slot values: every comparison in the chain
    (<= low, > low, >= high/2, >= high, the 3*max(fp,1) pivot) has values
    on both sides and exactly at the cut."""
    vals = (None, 0.0, 3.0, low, low + 0.125, high / 2, high - 0.25, high,
            high + 6.0)
    ici_options = ({}, {"ici_allreduce": 0.0},
                   {"ici_allreduce": high + 1.0},
                   {"ici_allreduce": low, "ici_all2all": high})
    for fp in vals:
        for l1 in vals:
            for mem in vals:
                for chase in (None, 0.0, high):
                    for icis in ici_options:
                        yield fp, l1, mem, chase, icis


def _signature(fp, l1, mem, chase, icis):
    sig = {}
    if fp is not None:
        sig["fp_add"] = fp
    if l1 is not None:
        sig["l1_ld"] = l1
    if mem is not None:
        sig["mem_ld"] = mem
    if chase is not None:
        sig["chase"] = chase
    sig.update(icis)
    return sig


@pytest.mark.parametrize("low,high", [(LOW, HIGH), (4.5, 16.5)])
def test_tree_matches_legacy_chain_on_every_decision_cell(low, high):
    checked = 0
    for fp, l1, mem, chase, icis in _cells(low, high):
        sig = _signature(fp, l1, mem, chase, icis)
        want = _legacy_classify(fp, l1, mem, chase, icis, low=low, high=high)
        got = classify(sig, low=low, high=high)
        cell = f"cell {sig!r} low={low} high={high}"
        assert got.label == want[0], cell
        assert got.confidence == pytest.approx(want[1]), cell
        assert got.explanation == want[2], cell
        checked += 1
    assert checked == 9 * 9 * 9 * 3 * 4      # nobody shrank the table


def test_vocabulary_equivalence():
    """The same signature expressed in the loop-level, graph-level and
    Pallas vocabularies binds the same slots and classifies identically."""
    loop = {"fp_add": 0.0, "l1_ld": 25.0, "mem_ld": 25.0}
    graph = {"fp_add32": 0.0, "vmem_ld": 25.0, "hbm_stream": 25.0}
    pallas = {"fp": 0.0, "vmem": 25.0, "mem_ld": 25.0}
    want = classify(loop)
    for sig in (graph, pallas):
        got = classify(sig)
        assert (got.label, got.confidence) == (want.label, want.confidence)
    # chase aliases too
    assert classify({"chase": 1.0, "fp_add": 21.0}).label \
        == classify({"hbm_latency": 1.0, "fp_add32": 21.0}).label \
        == classify({"memory_chase": 1.0, "fp": 21.0}).label


def test_classify_reports_the_decision_path():
    rep = classify({"fp_add": 0.0, "l1_ld": 25.0, "mem_ld": 25.0})
    assert rep.path is not None
    assert rep.path["strategy"] == "default"
    assert rep.path["fired"] == "compute"
    assert rep.path["nodes"][-1] == {"node": "compute", "fired": True}
    assert all(not n["fired"] for n in rep.path["nodes"][:-1])
    assert rep.path["low"] == LOW and rep.path["high"] == HIGH
    assert rep.path["slots"]["fp"] == 0.0


def test_confidence_uses_the_effective_high_threshold():
    """Regression: confidence is separation / EFFECTIVE high, so calibrated
    thresholds change the saturation point, not just the label cuts."""
    sig = {"fp_add": 25.0, "l1_ld": 25.0, "mem_ld": 8.0}   # latency signature
    default = classify(sig)
    calibrated = classify(sig, low=4.5, high=16.5)
    assert default.label == calibrated.label == "latency"
    assert default.confidence == pytest.approx((8.0 - LOW) / HIGH)
    assert calibrated.confidence == pytest.approx((8.0 - 4.5) / 16.5)
    assert calibrated.confidence > default.confidence


# ---------------------------------------------------------------------------
# parser + loader
# ---------------------------------------------------------------------------

def test_subset_parser_agrees_with_pyyaml_on_every_shipped_tree():
    yaml = pytest.importorskip("yaml")
    paths = sorted(glob.glob(os.path.join(strategies_dir(), "*.yaml")))
    assert paths, "no shipped strategy trees found"
    for path in paths:
        with open(path) as f:
            text = f.read()
        assert _parse_simple_yaml(text) == yaml.safe_load(text), path


def test_default_tree_loads_and_is_cached():
    t1 = default_tree()
    assert t1 is default_tree()
    assert [n.name for n in t1.nodes][-1] == "mixed"     # catch-all last
    assert t1.name == "default"


def _spec(**over):
    base = {
        "strategy": 1,
        "name": "t",
        "slots": {"fp": ["fp_add"]},
        "nodes": [{"name": "n", "label": "x", "when": "True",
                   "fixed": 0.5, "explanation": "e"}],
    }
    base.update(over)
    return base


def test_loader_rejects_unknown_names_lambdas_and_bad_nodes():
    with pytest.raises(StrategyError, match="unknown name"):
        StrategyTree(_spec(nodes=[{"name": "n", "label": "x",
                                   "when": "__import__('os')",
                                   "fixed": 0.5, "explanation": "e"}]))
    with pytest.raises(StrategyError, match="not allowed"):
        StrategyTree(_spec(nodes=[{"name": "n", "label": "x",
                                   "when": "min([v for v in known])",
                                   "fixed": 0.5, "explanation": "e"}]))
    with pytest.raises(StrategyError, match="exactly one"):
        StrategyTree(_spec(nodes=[{"name": "n", "label": "x", "when": "True",
                                   "sep": "fp", "fixed": 0.5,
                                   "explanation": "e"}]))
    with pytest.raises(StrategyError, match="missing 'label'"):
        StrategyTree(_spec(nodes=[{"name": "n", "when": "True",
                                   "fixed": 0.5, "explanation": "e"}]))
    with pytest.raises(StrategyError, match="schema"):
        StrategyTree(_spec(strategy=2))


def test_tree_without_a_firing_node_raises():
    t = StrategyTree(_spec(nodes=[{"name": "never", "label": "x",
                                   "when": "False", "fixed": 0.5,
                                   "explanation": "e"}]))
    with pytest.raises(StrategyError, match="no node fired"):
        t.decide({"fp_add": 1.0}, low=LOW, high=HIGH)

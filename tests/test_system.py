"""End-to-end system tests: one real dry-run cell compiled on the 512-device
production mesh (subprocess — device count is locked at first jax init), the
roofline record it produces, and the measured-probe pipeline on a reduced
model."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest


def test_dryrun_one_cell_single_pod(tmp_path):
    """gemma-2b decode_32k lowers + compiles on the 16x16 mesh and yields
    sane memory/roofline numbers."""
    code = (
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('gemma_2b', 'decode_32k', multi_pod=False,"
        f" out_dir={str(tmp_path)!r}, verbose=False)\n"
        "import json; print(json.dumps(rec['status']))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.load(open(tmp_path / "16x16" / "gemma_2b_decode_32k.json"))
    assert rec["status"] == "ok", rec.get("error")
    r = rec["roofline"]
    assert r["flops_per_chip"] > 0
    assert r["hbm_bytes_per_chip"] > 0
    assert rec["memory"]["argument_size_in_bytes"] > 0
    # per-chip argument bytes must fit v5e HBM
    assert rec["memory"]["argument_size_in_bytes"] < 16 * 2**30


def test_probe_end_to_end_measured():
    """The paper's tool against a real (reduced) train step: absorption
    sweeps, payload verification, classification."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core import probe_step
    from repro.core.noise import NoiseScale, make_modes
    from repro.models.model import build

    cfg = get_smoke_config("gemma_2b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = api.dummy_batch(ShapeConfig("p", "train", 64, 2))

    modes = make_modes(NoiseScale(mxu_dim=32, hbm_mib=4, chase_len=1 << 16))
    pr = probe_step(lambda p, b: api.loss(p, b)[0], (params, batch),
                    modes["fp_add32"], ks=(0, 2, 4, 8), reps=2)
    assert pr.injection.payload >= 4
    assert pr.fit.t0 > 0
    assert len(pr.curve.ks) >= 3


def test_analytic_probe_from_record(tmp_path):
    """launch.probe --analytic consumes a dry-run record, persists pred
    records to its campaign store, and replays them on a re-run (the
    --expect-no-measure contract)."""
    from repro.launch.probe import analytic_probe

    rec = {"status": "ok", "mesh": "16x16",
           "roofline": {"t_compute": 2e-3, "t_memory": 8e-3, "t_ici": 1e-3,
                        "dominant": "memory"}}
    d = tmp_path / "16x16"
    d.mkdir()
    with open(d / "gemma_2b_train_4k.json", "w") as f:
        json.dump(rec, f)
    store = str(tmp_path / "pred.jsonl")
    analytic_probe("gemma-2b", "train_4k", str(d),
                   ["fp_add32", "hbm_stream"], tol=0.05, store=store)
    # second run must be pure replay — expect_no_measure raises otherwise
    analytic_probe("gemma-2b", "train_4k", str(d),
                   ["fp_add32", "hbm_stream"], tol=0.05, store=store,
                   expect_no_measure=True)
    # a tol change invalidates the stored predictions
    with pytest.raises(SystemExit, match="expect-no-measure"):
        analytic_probe("gemma-2b", "train_4k", str(d),
                       ["fp_add32", "hbm_stream"], tol=0.02, store=store,
                       expect_no_measure=True)


def test_benchmark_analytic_suite():
    """The pure-analytic benchmarks run and reproduce the paper findings."""
    from benchmarks import table4_memsys

    out = table4_memsys.run(quick=True)
    assert out["hbm_collapse"] is True


def test_loop_noise_composition():
    """noisy_loop: generic injection site wraps an arbitrary body."""
    from repro.core import make_loop_modes, noisy_loop

    modes = make_loop_modes()

    def body(i, acc):
        return acc + 1.0

    out, aux = jax.jit(
        lambda a: noisy_loop(body, 16, a, modes["fp_add"], k=2))(
            jnp.zeros((), jnp.float32))
    assert float(out) == 16.0
    assert jnp.isfinite(aux)

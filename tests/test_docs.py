"""Documentation integrity (tier-1 fast path of tools/check_docs.py): every
relative link, file:line reference, backticked repo path, and dotted code
reference in docs/*.md + README.md resolves against the tree, and quoted
example scripts compile. (The CI docs job additionally executes every
quoted ``python -m`` command in --help form.)"""
import glob
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import check_docs  # noqa: E402

ROOT = check_docs.ROOT
MD_FILES = [os.path.join(ROOT, "README.md")] + sorted(
    glob.glob(os.path.join(ROOT, "docs", "*.md")))


def test_docs_exist():
    names = {os.path.basename(p) for p in MD_FILES}
    assert {"README.md", "methodology.md", "architecture.md",
            "orchestration.md"} <= names


@pytest.mark.parametrize("md_path", MD_FILES,
                         ids=[os.path.basename(p) for p in MD_FILES])
def test_every_reference_resolves(md_path):
    rel = os.path.relpath(md_path, ROOT)
    text = open(md_path).read()
    problems: list = []
    check_docs.check_links(rel, text, problems)
    check_docs.check_file_lines(rel, text, problems)
    check_docs.check_backticks(rel, text, problems)
    _, scripts = check_docs.fenced_commands(text)
    check_docs.check_scripts(rel, scripts, problems)
    assert not problems, "\n".join(problems)


def test_checker_catches_breakage(tmp_path):
    """The checker itself must not be a rubber stamp: feed it one of each
    breakage class and assert each is reported."""
    problems: list = []
    check_docs.check_links("x.md", "[a](does/not/exist.md)", problems)
    check_docs.check_file_lines("x.md", "see src/repro/compat.py:999999",
                                problems)
    check_docs.check_backticks("x.md", "`src/repro/nope.py`", problems)
    check_docs.check_backticks("x.md", "`repro.core.campaign.not_a_symbol`",
                               problems)
    assert len(problems) == 4, problems


def test_quoted_commands_reference_real_modules():
    """Every quoted ``python -m X`` module maps to a real module file (the
    CI job actually executes them; tier-1 just pins existence)."""
    for md_path in MD_FILES:
        modules, _ = check_docs.fenced_commands(open(md_path).read())
        for mod in modules:
            err = check_docs._resolve_dotted(mod)
            assert err is None, f"{md_path}: {err}"
            parts = mod.split(".")
            cands = [os.path.join(ROOT, "src", *parts),
                     os.path.join(ROOT, *parts)]
            assert any(os.path.isfile(c + ".py") or os.path.isdir(c)
                       for c in cands), f"{md_path}: no module for {mod}"

"""Runtime measurement-integrity guard: the valid/re-measure/quarantine
decision table, the bounded re-measure loop, Sample dispersion math, the
deterministic synthetic-clock perturbations (jitter/drift/hang), watchdog
timeouts, and the campaign-level quarantine/heal round-trip.

Measurement determinism: REPRO_SYNTH_MEASURE + REPRO_SYNTH_JITTER /
REPRO_SYNTH_DRIFT / REPRO_SYNTH_HANG drive every scenario with a pure
function of (k, rep), so the quarantine sets asserted here are exact."""
import importlib
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import (Campaign, CampaignStore, Controller, MeasureTimeout,
                        QualityPolicy, RemeasureBudget, Sample,
                        apply_quality_evidence, classify, measure_quality,
                        measure_sample, quality_from_dict, step_region)
from repro.core.noise import NoiseScale, make_modes
from repro.core.quality import (REASON_SPREAD, REASON_TIMER_FLOOR,
                                VERDICT_QUARANTINE, VERDICT_REMEASURE,
                                VERDICT_VALID, decide)

# the package re-export shadows the submodule attribute, so import the
# module explicitly to reach the synth-state helpers
absorption_mod = importlib.import_module("repro.core.absorption")

MODES = make_modes(NoiseScale(hbm_mib=4, chase_len=1 << 16, mxu_dim=32))


def _region(name):
    def step(x):
        W = jnp.eye(64) * 0.5
        return jnp.tanh(x @ W) @ W

    X = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    return step_region(name, step, (X,), MODES)


# ---------------------------------------------------------------------------
# Sample math
# ---------------------------------------------------------------------------

def test_sample_min_spread_mad_and_merge():
    s = Sample(reps=(2.0, 1.0, 1.5))
    assert s.t == 1.0
    assert s.spread == pytest.approx(1.0)          # (2 - 1) / 1
    assert s.mad == pytest.approx(0.5 / 1.5)       # median-relative MAD
    m = s.merged(Sample(reps=(0.5,)))
    assert m.reps == (2.0, 1.0, 1.5, 0.5)
    assert m.t == 0.5


def test_sample_rejects_empty():
    with pytest.raises(ValueError):
        Sample(reps=())


# ---------------------------------------------------------------------------
# decision table
# ---------------------------------------------------------------------------

def test_decide_timer_floor_beats_spread():
    """A sub-floor time quarantines even when the spread is also terrible:
    more reps cannot fix a timer that cannot resolve the kernel."""
    policy = QualityPolicy(max_spread=0.1, timer_floor_s=1e-6)
    s = Sample(reps=(1e-9, 5e-9))
    assert decide(s, policy) == (VERDICT_QUARANTINE, REASON_TIMER_FLOOR)


def test_decide_valid_remeasure_quarantine():
    policy = QualityPolicy(max_spread=0.1)
    clean = Sample(reps=(1.0, 1.05))
    noisy = Sample(reps=(1.0, 1.5))
    assert decide(clean, policy) == (VERDICT_VALID, None)
    assert decide(noisy, policy) == (VERDICT_REMEASURE, None)
    assert decide(noisy, policy, can_remeasure=False) == \
        (VERDICT_QUARANTINE, REASON_SPREAD)


def test_measure_quality_stabilizes_with_extra_reps():
    """A noisy first sample earns extra reps; once the merged spread is in
    tolerance the point is valid and the loop stops."""
    calls = []

    def once(n):
        calls.append(n)
        # first round noisy, extra rounds tight around the true minimum
        return Sample(reps=(1.0, 1.4) if len(calls) == 1
                      else tuple([1.0] * n))

    policy = QualityPolicy(max_spread=0.1)
    sample, verdict, reason = measure_quality(
        once, reps=2, policy=policy,
        budget=RemeasureBudget(max_attempts=2, extra_reps=3))
    assert verdict == VERDICT_VALID and reason is None
    assert calls == [2, 3]
    assert len(sample.reps) == 5 and sample.t == 1.0


def test_measure_quality_exhausts_budget_to_quarantine():
    def once(n):
        # spread never settles: reps alternate around a 40% band
        return Sample(reps=tuple(1.0 + 0.4 * (i % 2) for i in range(n)))

    policy = QualityPolicy(max_spread=0.1)
    budget = RemeasureBudget(max_attempts=2, extra_reps=3, max_total_reps=6)
    sample, verdict, reason = measure_quality(
        once, reps=2, policy=policy, budget=budget)
    assert (verdict, reason) == (VERDICT_QUARANTINE, REASON_SPREAD)
    assert len(sample.reps) <= budget.max_total_reps


def test_quality_from_dict_round_trip_and_validation():
    policy, budget = quality_from_dict(
        {"max_spread": 0.2, "sentinel_every": 4, "extra_reps": 2})
    assert policy.max_spread == 0.2 and policy.sentinel_every == 4
    assert budget.extra_reps == 2
    with pytest.raises(ValueError, match="unknown quality key"):
        quality_from_dict({"max_spred": 0.2})
    with pytest.raises(ValueError, match="max_spread"):
        quality_from_dict({"max_spread": -1.0})
    with pytest.raises(ValueError, match="dict"):
        quality_from_dict([1, 2])


def test_watchdog_deadline_shape():
    off = QualityPolicy()
    assert off.deadline(1e-3, stop_ratio=4.0, reps=3) is None
    on = QualityPolicy(watchdog_floor_s=0.5, watchdog_margin=8.0)
    # before t(0) exists only the floor applies
    assert on.deadline(None, stop_ratio=4.0, reps=3) == 0.5
    # 8 * 4 * 1e-3 * (2 warmup + 3 reps) = 0.16 < floor
    assert on.deadline(1e-3, stop_ratio=4.0, reps=3, warmup=2) == 0.5
    assert on.deadline(1.0, stop_ratio=4.0, reps=3, warmup=2) == \
        pytest.approx(8.0 * 4.0 * 1.0 * 5)


# ---------------------------------------------------------------------------
# synthetic clock perturbations
# ---------------------------------------------------------------------------

def test_synth_jitter_is_deterministic_and_min_invariant(monkeypatch):
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    args = (jnp.int32(8),)
    clean = measure_sample(None, args, reps=4)
    monkeypatch.setenv("REPRO_SYNTH_JITTER", "0.6")
    j1 = measure_sample(None, args, reps=4)
    j2 = measure_sample(None, args, reps=4)
    assert j1.reps == j2.reps                  # hash-derived, not random
    assert j1.reps[0] == clean.t               # rep 0 is always exact
    assert j1.t == clean.t                     # min-of-reps is unchanged
    assert j1.spread > clean.spread == 0.0


def test_synth_hang_trips_the_watchdog(monkeypatch):
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_HANG", "8")
    t0 = time.monotonic()
    with pytest.raises(MeasureTimeout, match="deadline"):
        measure_sample(None, (jnp.int32(8),), reps=2, deadline=0.1)
    assert time.monotonic() - t0 < 5.0         # bounded, not stuck
    absorption_mod.release_synth_hang()
    # un-hung ks measure normally under the same deadline
    assert measure_sample(None, (jnp.int32(4),), reps=2,
                          deadline=0.1).t == pytest.approx(1e-3)


# ---------------------------------------------------------------------------
# campaign integration: quarantine, heal, sentinel spans, timeouts
# ---------------------------------------------------------------------------

def _quality_campaign(path, policy):
    return Campaign(path, Controller(reps=2, verify_payload=False),
                    quality=policy)


def test_sweep_quarantines_jitter_and_heals_on_clean_resume(tmp_path,
                                                            monkeypatch):
    """The tentpole round-trip: a jittery clock condemns points (recorded,
    not dropped), a resume under a clean clock re-measures EXACTLY those
    points, and the healed curve is identical to the undisturbed one."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_JITTER", "0.6")
    path = str(tmp_path / "q.jsonl")
    policy = QualityPolicy(max_spread=0.15)
    camp = _quality_campaign(path, policy)
    res = camp.sweep_mode(_region("qr"), "fp_add32")
    quar = camp.store.quarantined_ks("qr", "fp_add32")
    assert quar, "deterministic jitter at amp 0.6 must condemn some ks"
    ps = camp.store.pair_status("qr", "fp_add32")
    assert ps.quarantined == quar and ps.complete
    # every measured point carries a quality record and its spread
    qrecs = camp.store.quality[("qr", "fp_add32")]
    assert set(qrecs) == set(res.curve.ks)
    assert all(rec["spread"] is not None for rec in qrecs.values())
    camp.store.close()

    monkeypatch.delenv("REPRO_SYNTH_JITTER")
    absorption_mod.reset_synth_state()
    camp2 = _quality_campaign(path, policy)
    res2 = camp2.sweep_mode(_region("qr"), "fp_add32")
    # only the condemned points re-measured; fresh valid records supersede
    assert camp2.stats.measured == len(quar)
    assert camp2.store.quarantined_ks("qr", "fp_add32") == ()
    # rep 0 is always the exact model time, so the healed curve is
    # byte-identical to the jittered one (and to an undisturbed run)
    assert res2.curve.ks == res.curve.ks and res2.curve.ts == res.curve.ts
    camp2.store.close()

    # a third open replays with zero measurements — the pair is clean now
    camp3 = _quality_campaign(path, policy)
    camp3.sweep_mode(_region("qr"), "fp_add32")
    assert camp3.stats.measured == 0
    camp3.store.close()


def test_classify_campaign_does_not_heal(tmp_path, monkeypatch):
    """heal_quarantined=False (the fleet finalize path) must replay the
    stored curve as-is — classification never measures behind the gate."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_JITTER", "0.6")
    path = str(tmp_path / "nf.jsonl")
    policy = QualityPolicy(max_spread=0.15)
    camp = _quality_campaign(path, policy)
    camp.sweep_mode(_region("nh"), "fp_add32")
    assert camp.store.quarantined_ks("nh", "fp_add32")
    camp.store.close()
    camp2 = Campaign(path, Controller(reps=2, verify_payload=False),
                     quality=policy, heal_quarantined=False)
    camp2.sweep_mode(_region("nh"), "fp_add32")
    assert camp2.stats.measured == 0
    assert camp2.store.quarantined_ks("nh", "fp_add32")   # still condemned
    camp2.store.close()


def test_sentinel_quarantines_only_the_drifted_span(tmp_path, monkeypatch):
    """Mid-sweep interference: the interleaved k=0 sentinel detects a
    baseline shift and condemns the span since the previous sentinel with
    reason drift_span — earlier spans stay valid."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    # sens probe takes 2 samples; every sample after the 6th is 1.5x
    monkeypatch.setenv("REPRO_SYNTH_DRIFT", "1.5@6")
    policy = QualityPolicy(max_spread=0.15, sentinel_every=2,
                           sentinel_tol=0.25)
    camp = _quality_campaign(str(tmp_path / "d.jsonl"), policy)
    camp.sweep_mode(_region("dr"), "fp_add32")
    q = camp.store.quality[("dr", "fp_add32")]
    spans = {k for k, rec in q.items()
             if rec["verdict"] == VERDICT_QUARANTINE
             and rec["reason"] == "drift_span"}
    assert spans, "the sentinel must condemn the drifted span"
    valid = {k for k, rec in q.items() if rec["verdict"] == VERDICT_VALID}
    assert valid, "pre-drift points must stay valid"
    assert max(valid & set(q)) is not None
    # the done record carries the sentinel readings for forensics
    done = camp.store.done[("dr", "fp_add32")]
    assert any(not s["ok"] for s in done["sentinels"])
    camp.store.close()


def test_hung_kernel_becomes_timeout_quarantine_not_stuck(tmp_path,
                                                          monkeypatch):
    """The acceptance scenario: a kernel that hangs mid-sweep trips the
    watchdog, lands as a recorded timeout quarantine with the pair left
    INCOMPLETE, and a resume (hang cleared) finishes the pair."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_HANG", "8")
    path = str(tmp_path / "h.jsonl")
    policy = QualityPolicy(watchdog_floor_s=0.1)
    camp = _quality_campaign(path, policy)
    t0 = time.monotonic()
    res = camp.sweep_mode(_region("hg"), "fp_add32")
    assert time.monotonic() - t0 < 30.0        # the sweep did not hang
    assert 8 not in res.curve.ks               # no fabricated point
    q = camp.store.quality[("hg", "fp_add32")]
    assert q[8]["verdict"] == VERDICT_QUARANTINE
    assert q[8]["reason"] == "timeout"
    ps = camp.store.pair_status("hg", "fp_add32")
    assert not ps.complete and 8 in ps.missing
    camp.store.close()

    absorption_mod.release_synth_hang()
    time.sleep(0.05)                           # let the parked thread drain
    absorption_mod.reset_synth_state()
    monkeypatch.delenv("REPRO_SYNTH_HANG")
    camp2 = _quality_campaign(path, policy)
    res2 = camp2.sweep_mode(_region("hg"), "fp_add32")
    assert 8 in res2.curve.ks
    assert camp2.store.pair_status("hg", "fp_add32").complete
    assert camp2.store.quarantined_ks("hg", "fp_add32") == ()
    camp2.store.close()


def test_first_point_timeout_raises_measure_timeout(tmp_path, monkeypatch):
    """When even k=0 hangs there is no curve to return — the sweep raises
    instead of fabricating one, but the timeout quarantine is recorded."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_HANG", "0")
    path = str(tmp_path / "h0.jsonl")
    camp = _quality_campaign(path, QualityPolicy(watchdog_floor_s=0.1))
    # pre-seed the sensitivity so the guarded sweep itself reaches k=0
    camp.store.append({"kind": "sens", "region": "h0", "mode": "fp_add32",
                       "value": 1.9})
    with pytest.raises(MeasureTimeout, match="no curve"):
        camp.sweep_mode(_region("h0"), "fp_add32")
    assert camp.store.quality[("h0", "fp_add32")][0]["reason"] == "timeout"
    camp.store.close()


def test_sensitivity_probe_timeout_is_recorded_not_stuck(tmp_path,
                                                         monkeypatch):
    """A kernel that hangs on its very first call parks the SENSITIVITY
    probe, before any sweep point exists — the watchdog floor still bounds
    it, and the timeout lands as a recorded quarantine."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    monkeypatch.setenv("REPRO_SYNTH_HANG", "0")
    camp = _quality_campaign(str(tmp_path / "hs.jsonl"),
                             QualityPolicy(watchdog_floor_s=0.1))
    t0 = time.monotonic()
    with pytest.raises(MeasureTimeout):
        camp.sweep_mode(_region("hs"), "fp_add32")
    assert time.monotonic() - t0 < 30.0
    assert camp.store.quality[("hs", "fp_add32")][0]["reason"] == "timeout"
    camp.store.close()


# ---------------------------------------------------------------------------
# classifier evidence
# ---------------------------------------------------------------------------

def test_apply_quality_evidence_downgrades_then_refuses():
    rep = classify({"fp_add32": 1.0, "hbm_stream": 30.0})
    base_conf = rep.confidence
    # one quarantined point: downgrade, label kept
    down = apply_quality_evidence(rep, {
        "fp_add32": {"points": 8, "quarantined": 1,
                     "reasons": {"spread": 1}},
        "hbm_stream": {"points": 8, "quarantined": 0, "reasons": {}}})
    assert down.label == rep.label
    assert down.confidence == pytest.approx(base_conf * 0.6)
    assert down.quality is not None and len(down.quality) == 2
    # majority-quarantined: the label is refused outright
    refused = apply_quality_evidence(rep, {
        "fp_add32": {"points": 8, "quarantined": 6,
                     "reasons": {"spread": 4, "timeout": 2}}})
    assert refused.label == "unreliable"
    assert refused.confidence == 0.0
    assert "fp_add32" in refused.explanation
    assert "spread" in refused.explanation
    # str() surfaces the per-mode cleanliness tally
    assert "quality:" in str(down)


def test_apply_quality_evidence_empty_is_identity():
    rep = classify({"fp_add32": 1.0, "hbm_stream": 30.0})
    assert apply_quality_evidence(rep, {}) is rep

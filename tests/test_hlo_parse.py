"""HLO text parsing: instruction/computation extraction, trip counts,
dot-FLOP reconstruction, traffic model, collective wire bytes (SPMD
program compiled in a subprocess with 8 forced host devices)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hlo.parse import (extract_op_name, find_entry, nesting_multipliers,
                             parse_module, shape_bytes, shape_dims,
                             while_trip_counts)
from repro.roofline.terms import parsed_dot_flops


def test_shape_bytes():
    assert shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4]{0}, s32[])") == 20
    assert shape_bytes("pred[]") == 1


def test_shape_bytes_bounded_dynamic():
    """Bounded-dynamic dims (`<=N`) count their bound; unbounded (`?`)
    count 1 — neither silently drops the whole shape anymore."""
    assert shape_bytes("f32[<=128,4]") == 128 * 4 * 4
    assert shape_bytes("s32[<=16]{0}") == 16 * 4
    assert shape_bytes("f32[?,4]") == 4 * 4
    assert shape_bytes("(f32[<=8,128], s32[])") == 8 * 128 * 4 + 4
    assert shape_dims("f32[<=128,4]") == [("f32", (128, 4))]
    assert shape_dims("bf16[?,2]") == [("bf16", (1, 2))]


def test_extract_op_name_multi_attribute_metadata():
    """op_name extraction must tolerate the multi-attribute metadata={...}
    blocks newer XLA emits (op_type / source_file / source_line around the
    op_name), escaped quotes inside the value, and quoted strings in OTHER
    attributes that could shadow a whole-line search."""
    legacy = ('  %add.1 = f32[8,128]{1,0} add(%a, %b), '
              'metadata={op_name="noise_pattern/add"}')
    assert extract_op_name(legacy) == "noise_pattern/add"
    multi = ('  %add.2 = f32[8,128]{1,0} add(%a, %b), '
             'metadata={op_type="add" op_name="jit(f)/noise_pattern/add" '
             'source_file="/tmp/step.py" source_line=12}')
    assert extract_op_name(multi) == "jit(f)/noise_pattern/add"
    escaped = ('  %add.3 = f32[] add(%a, %b), '
               r'metadata={op_name="scope \"q\"/add" source_line=3}')
    assert extract_op_name(escaped) == 'scope \\"q\\"/add'
    assert extract_op_name("  %add.4 = f32[] add(%a, %b)") == ""
    # parse_module carries the multi-attribute op_name onto the Instr
    txt = "ENTRY %main (a: f32[]) -> f32[] {\n" + multi + "\n}\n"
    comps = parse_module(txt)
    (ins,) = comps["main"]
    assert ins.op_name == "jit(f)/noise_pattern/add"
    assert ins.opcode == "add"


def test_scan_trip_count_and_dot_flops():
    W = jax.random.normal(jax.random.PRNGKey(0), (64, 64))

    def f(x):
        def body(h, _):
            return jnp.tanh(h @ W), None
        h, _ = jax.lax.scan(body, x, None, length=12)
        return h

    txt = jax.jit(f).lower(jnp.ones((32, 64))).compile().as_text()
    comps = parse_module(txt)
    trips = while_trip_counts(comps)
    assert 12 in trips.values()
    entry = find_entry(comps, txt)
    mults = nesting_multipliers(comps, entry)
    flops = parsed_dot_flops(comps, mults)
    want = 12 * 2 * 32 * 64 * 64
    assert flops == pytest.approx(want, rel=0.05), (flops, want)


def test_nested_scan_multiplier():
    def f(x):
        def outer(h, _):
            def inner(g, _):
                return g * 1.0001 + x[0, 0], None
            g, _ = jax.lax.scan(inner, h, None, length=5)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    txt = jax.jit(f).lower(jnp.ones((4, 4))).compile().as_text()
    comps = parse_module(txt)
    mults = nesting_multipliers(comps, find_entry(comps, txt))
    # inner body runs 3*5 = 15 times (the condition runs 3*(5+1) = 18)
    assert 15 in mults.values()
    assert max(mults.values()) <= 18


_SPMD_SCRIPT = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    import sys
    sys.path.insert(0, "src")
    from repro import compat
    from repro.hlo.parse import parse_module, find_entry, nesting_multipliers
    from repro.roofline.terms import collective_wire_bytes

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    W = jax.random.normal(jax.random.PRNGKey(0), (256, 256))

    def f(x):
        y = x @ W                      # contracting dim sharded -> collective
        return jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P("data", "model")))

    xs = NamedSharding(mesh, P("data", "model"))
    x = jax.device_put(jnp.ones((64, 256)), xs)
    with compat.set_mesh(mesh):
        txt = jax.jit(f, in_shardings=xs).lower(x).compile().as_text()
    comps = parse_module(txt)
    mults = nesting_multipliers(comps, find_entry(comps, txt))
    wire, by_op = collective_wire_bytes(comps, mults, default_group=8)
    print(json.dumps({"wire": wire, "by_op": by_op}))
""")


def test_collective_wire_bytes_subprocess():
    out = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT],
                         capture_output=True, text=True, cwd=".",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["wire"] > 0
    assert any(op in rec["by_op"] for op in
               ("all-gather", "all-reduce", "reduce-scatter",
                "collective-permute", "all-to-all"))

"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps,
exact noise-payload accounting, and static-k vs runtime-k equivalence
(bitwise) for every kernel and noise mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_rt)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.noise_probes.ops import run_probe, run_probe_rt
from repro.kernels.noise_probes.ref import probe_ref
from repro.kernels.noise_slots import K_MAX
from repro.kernels.noisy_matmul.ops import (default_noise_operand,
                                            noisy_matmul, noisy_matmul_rt)
from repro.kernels.noisy_matmul.ref import fp_noise_ref, matmul_ref
from repro.kernels.spmv_ell.ops import spmv_ell, spmv_ell_rt
from repro.kernels.spmv_ell.ref import (fp_noise_ell_ref, make_band_ell,
                                        spmv_ell_ref, vmem_noise_ell_ref)


@pytest.mark.parametrize("M,N,K", [(256, 256, 256), (512, 256, 384),
                                   (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(M, N, K, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32).astype(dtype)
    out, _ = noisy_matmul(a, b, bm=128, bn=128, bk=128)
    ref = matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("mode,k", [("fp", 1), ("fp", 5), ("mxu", 2),
                                    ("vmem", 3)])
def test_matmul_noise_does_not_change_result(mode, k):
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    clean, _ = noisy_matmul(a, b, bm=128, bn=128, bk=128)
    noisy, nacc = noisy_matmul(a, b, mode=mode, k_noise=k,
                               bm=128, bn=128, bk=128)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(noisy))
    assert np.abs(np.asarray(nacc)).sum() > 0     # payload executed


def test_matmul_fp_noise_exact():
    a = jnp.ones((256, 256), jnp.float32)
    b = jnp.ones((256, 256), jnp.float32)
    noise = default_noise_operand()
    _, nacc = noisy_matmul(a, b, noise, mode="fp", k_noise=3,
                           bm=128, bn=128, bk=128)
    n_steps = 2 * 2 * 2
    np.testing.assert_allclose(np.asarray(nacc),
                               np.asarray(fp_noise_ref(noise, 3, n_steps)),
                               rtol=1e-5)


@pytest.mark.parametrize("H,KH,Sq,Sk,hd,causal,window", [
    (4, 4, 256, 256, 64, True, 0),
    (8, 2, 256, 256, 64, True, 0),      # GQA
    (4, 1, 128, 128, 128, True, 0),     # MQA
    (4, 4, 128, 128, 64, False, 0),     # bidirectional (encoder)
    (4, 2, 256, 256, 64, True, 64),     # sliding window
])
def test_flash_attention_sweep(H, KH, Sq, Sk, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, H, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (2, KH, Sk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (2, KH, Sk, hd), jnp.float32)
    out, _ = flash_attention(q, k, v, causal=causal, window=window,
                             bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 4, 128, 64), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 4, 128, 64), jnp.float32).astype(jnp.bfloat16)
    out, _ = flash_attention(q, k, v, bq=64, bk=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


@pytest.mark.parametrize("n,L,q", [(512, 128, 0.0), (1024, 128, 0.5),
                                   (512, 256, 1.0)])
def test_spmv_sweep(n, L, q):
    vals, cols = make_band_ell(n, L, q, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    y, _ = spmv_ell(vals, cols, x, br=128)
    ref = spmv_ell_ref(vals, cols, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("mode", ["fp", "mxu", "vmem"])
@pytest.mark.parametrize("k,n_steps", [(1, 4), (3, 16)])
def test_probe_exact(mode, k, n_steps):
    got = run_probe(mode=mode, k_noise=k, n_steps=n_steps)
    want = probe_ref(default_noise_operand(), mode=mode, k_noise=k,
                     n_steps=n_steps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# runtime-k protocol: for every kernel and mode, the scalar-prefetch path
# must be BITWISE identical to the static-k path (same pattern arithmetic in
# the same order), including k=0, so compile-once sweeps measure the same
# injected work as the paper's trace-per-k cost model.
# ---------------------------------------------------------------------------

def _assert_pair_equal(static_out, rt_out):
    for s, r in zip(static_out, rt_out):
        np.testing.assert_array_equal(np.asarray(s), np.asarray(r))


@pytest.mark.parametrize("mode", ["fp", "mxu", "vmem"])
@pytest.mark.parametrize("k", [0, 1, 5])
def test_matmul_runtime_k_matches_static(mode, k):
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    _assert_pair_equal(
        noisy_matmul(a, b, mode=mode, k_noise=k, bm=128, bn=128, bk=128),
        noisy_matmul_rt(jnp.int32(k), a, b, mode=mode,
                        bm=128, bn=128, bk=128))


@pytest.mark.parametrize("mode", ["fp", "vmem"])
@pytest.mark.parametrize("n,L,k", [(512, 16, 1), (512, 16, 5), (256, 128, 3)])
def test_spmv_runtime_k_matches_static(mode, n, L, k):
    vals, cols = make_band_ell(n, L, 0.5, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    _assert_pair_equal(
        spmv_ell(vals, cols, x, br=128, mode=mode, k_noise=k),
        spmv_ell_rt(jnp.int32(k), vals, cols, x, br=128, mode=mode))


@pytest.mark.parametrize("mode", ["fp", "mxu", "vmem"])
@pytest.mark.parametrize("k", [1, 4])
def test_attention_runtime_k_matches_static(mode, k):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    _assert_pair_equal(
        flash_attention(q, kk, v, mode=mode, k_noise=k, bq=64, bk=64),
        flash_attention_rt(jnp.int32(k), q, kk, v, mode=mode, bq=64, bk=64))


@pytest.mark.parametrize("mode", ["fp", "mxu", "vmem"])
@pytest.mark.parametrize("k", [0, 1, 3])
def test_probe_runtime_k_matches_static(mode, k):
    np.testing.assert_array_equal(
        np.asarray(run_probe(mode=mode, k_noise=k, n_steps=8)),
        np.asarray(run_probe_rt(jnp.int32(k), mode=mode, n_steps=8)))


def test_runtime_k_clamps_at_k_max():
    """The bounded fori_loop: k > K_MAX emits exactly K_MAX patterns."""
    got = run_probe_rt(jnp.int32(K_MAX + 7), mode="fp", n_steps=2)
    want = run_probe(mode="fp", k_noise=K_MAX, n_steps=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# spmv fp payload integrity: the addend derives from a RUNTIME block of
# vals (a compile-time constant could be strength-reduced to nacc += k*c,
# deleting the payload), and the exact oracle still holds.
# ---------------------------------------------------------------------------

def test_spmv_fp_noise_exact_and_data_dependent():
    vals, cols = make_band_ell(512, 16, 0.25, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(4), (512,), jnp.float32)
    k = 4
    _, nacc = spmv_ell(vals, cols, x, br=128, mode="fp", k_noise=k)
    np.testing.assert_allclose(np.asarray(nacc),
                               np.asarray(fp_noise_ell_ref(vals, k, 128)),
                               rtol=1e-5, atol=1e-6)
    # the addend is data, not a constant: scaling vals scales nacc linearly
    _, nacc2 = spmv_ell(vals * 2.0, cols, x, br=128, mode="fp", k_noise=k)
    np.testing.assert_allclose(np.asarray(nacc2), 2.0 * np.asarray(nacc),
                               rtol=1e-5, atol=1e-6)


def test_spmv_vmem_noise_exact_narrow_block():
    """vmem patterns on a narrow ELL block (L < 128) add into the first L
    lanes only; the exact oracle pins offsets and widths."""
    vals, cols = make_band_ell(512, 16, 0.0, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(6), (512,), jnp.float32)
    _, nacc = spmv_ell(vals, cols, x, br=128, mode="vmem", k_noise=3)
    nacc = np.asarray(nacc)
    np.testing.assert_allclose(nacc,
                               np.asarray(vmem_noise_ell_ref(vals, 3, 128)),
                               rtol=1e-5, atol=1e-6)
    assert np.abs(nacc[:, :16]).sum() > 0
    np.testing.assert_array_equal(nacc[:, 16:], 0.0)

"""Training substrate: AdamW numerics, schedules, microbatch equivalence,
int8 compression with error feedback, checkpoint restart, fault tolerance."""
import dataclasses
import os

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:   # property tests skip; the rest still runs
    from conftest import hypothesis_stub as hypothesis
    from conftest import strategies_stub as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.ckpt import CheckpointManager
from repro.configs import TrainConfig, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models.model import build
from repro.train.grad_compression import (compress_int8, decompress_int8,
                                          make_compressed_psum)
from repro.train.optimizer import (adamw_init, adamw_update, global_norm,
                                   lr_schedule)
from repro.train.trainer import Trainer, TrainState, make_train_step


def test_adamw_single_param_matches_reference():
    tcfg = TrainConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0,
                       grad_clip=0.0, b1=0.9, b2=0.999, eps=1e-8,
                       total_steps=10)
    p = {"w": jnp.asarray([[1.0, 2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, -0.2]], jnp.float32)}
    st8 = adamw_init(p, use_master=False)
    new_p, st2, stats = adamw_update(tcfg, p, g, st8)
    # reference: step 1 with bias correction reduces to p - lr*sign-ish
    m = 0.1 * np.asarray([[0.1, -0.2]])
    v = 0.001 * np.asarray([[0.01, 0.04]])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray([[1.0, 2.0]]) - lr_np(tcfg, 1) * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def lr_np(tcfg, step):
    return float(lr_schedule(tcfg, jnp.int32(step)))


def test_lr_schedule_shape():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert lr_np(tcfg, 0) == 0.0
    assert lr_np(tcfg, 5) == pytest.approx(5e-4)
    assert lr_np(tcfg, 10) == pytest.approx(1e-3, rel=1e-3)
    assert lr_np(tcfg, 100) == pytest.approx(1e-4, rel=1e-2)  # 10% floor


def test_grad_clip():
    tcfg = TrainConfig(grad_clip=1.0, lr=1.0, warmup_steps=0, total_steps=1,
                       weight_decay=0.0)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    st8 = adamw_init(p, use_master=False)
    _, _, stats = adamw_update(tcfg, p, g, st8)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_master_weights_bf16():
    tcfg = TrainConfig(lr=1e-4, warmup_steps=0, total_steps=100,
                       weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.full((8,), 1.0, jnp.bfloat16)}
    st8 = adamw_init(p)
    assert st8.master is not None
    g = {"w": jnp.full((8,), 1e-3, jnp.float32)}
    # 50 tiny steps: master accumulates below-bf16-resolution updates
    for _ in range(50):
        p, st8, _ = adamw_update(tcfg, p, g, st8)
    drift = 1.0 - float(np.asarray(st8.master["w"], np.float32)[0])
    assert drift > 1e-3   # master moved even though bf16 steps round


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@hypothesis.given(st.lists(st.floats(-100, 100, allow_nan=False,
                                     width=32), min_size=4, max_size=64))
@hypothesis.settings(max_examples=50, deadline=None)
def test_int8_roundtrip_error_bound(vals):
    g = jnp.asarray(vals, jnp.float32)
    q, scale, resid = compress_int8(g)
    rec = decompress_int8(q, scale)
    # quantization error bounded by scale/2 per element; residual exact
    assert float(jnp.max(jnp.abs(g - rec))) <= float(scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(g - rec), np.asarray(resid),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_accumulates():
    """A constant gradient below one quantization step still gets through
    over multiple rounds thanks to the residual."""
    g = jnp.full((8,), 0.003, jnp.float32)
    big = jnp.asarray([1.0] + [0.003] * 7, jnp.float32)  # scale set by 1.0
    resid = None
    recovered = np.zeros(8, np.float32)
    for _ in range(20):
        q, scale, resid = compress_int8(big, resid)
        recovered += np.asarray(decompress_int8(q, scale))
    # after 20 rounds the small entries sum to ~20*0.003
    np.testing.assert_allclose(recovered[1:], 0.06, rtol=0.25)


def test_compressed_psum_single_device():
    mesh = compat.make_mesh((1,), ("data",))
    cpsum = make_compressed_psum(("data",))
    g = {"a": jnp.linspace(-1, 1, 32).reshape(4, 8)}
    r = {"a": jnp.zeros((4, 8), jnp.float32)}

    out, new_r = compat.shard_map(
        cpsum, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2)(g, r)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(out["a"] - g["a"]))) <= scale * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# trainer: microbatching, restart, straggler flag
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("minitron_4b")
    api = build(cfg)
    shape = ShapeConfig("t", "train", 32, 8)
    pipe = SyntheticPipeline(cfg, shape, task="lcg")
    return api, shape, pipe


def test_microbatch_equivalence(setup):
    api, shape, pipe = setup
    batch = pipe.batch(0)
    s1 = make_train_step(api, TrainConfig(microbatches=1, lr=1e-3))
    s2 = make_train_step(api, TrainConfig(microbatches=4, lr=1e-3))
    state = TrainState(params=api.init(jax.random.PRNGKey(0)),
                       opt=adamw_init(api.init(jax.random.PRNGKey(0))))
    _, m1 = jax.jit(s1)(state, batch)
    _, m2 = jax.jit(s2)(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=5e-2)


def test_restart_replays_batches(tmp_path, setup):
    api, shape, pipe = setup
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20, ckpt_every=5,
                       ckpt_dir=str(tmp_path))
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tr = Trainer(api, tcfg, ckpt_manager=ckpt)
    state = tr.init_state()
    boom = {"armed": True}

    def fail(step):
        if step == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected failure")

    state, hist = tr.run(state, pipe, steps=15, fail_injector=fail)
    steps_seen = [h["step"] for h in hist]
    assert steps_seen.count(12) == 1          # replayed exactly once
    assert steps_seen[-1] == 14
    # deterministic pipeline: the replayed range re-used identical batches
    assert ckpt.steps()                        # checkpoints exist


def test_straggler_flag(setup):
    api, shape, pipe = setup
    tcfg = TrainConfig(lr=1e-3, total_steps=3, ckpt_every=0,
                       step_deadline_s=1e-9)   # everything is a straggler
    tr = Trainer(api, tcfg)
    state = tr.init_state()
    _, hist = tr.run(state, pipe, steps=2)
    assert all(h.get("straggler") for h in hist)


def test_checkpoint_roundtrip_bf16(tmp_path, setup):
    api, _, _ = setup
    params = api.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, blocking=True)
    restored, step = mgr.restore_latest(like=state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert str(a.dtype) == str(np.asarray(b).dtype)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc(tmp_path, setup):
    api, _, _ = setup
    params = api.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]


def test_pipeline_deterministic(setup):
    api, shape, _ = setup
    p1 = SyntheticPipeline(api.cfg, shape, task="lcg", seed=3)
    p2 = SyntheticPipeline(api.cfg, shape, task="lcg", seed=3)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # lcg labels follow the recurrence
    V = api.cfg.vocab_size
    a = (1103515245 % V) or 1
    t = np.asarray(b1["tokens"])
    lab = np.asarray(b1["labels"])
    np.testing.assert_array_equal((a * t[:, 0] + 12345) % V, lab[:, 0])

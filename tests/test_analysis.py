"""The static noise audit (repro.analysis): corruption-class detectors on
hand-built minimal HLO, and golden replay of checked-in compiled dumps.

Two layers, both compiler-free:

1. Each corruption class the audit claims to detect — DCE, constant
   folding, strength reduction, fusion-into-consumer, loop-invariant
   hoisting, partial elision — gets a minimal hand-built HLO trio (clean /
   k_lo / k_hi) exhibiting exactly that transformation, so the detector
   logic is pinned independent of what any real XLA build emits.

2. ``tests/golden/hlo/*.txt.gz`` are real optimized dumps of all four
   Pallas kernels plus a loop region; ``tests/golden/audit_expected.json``
   pins the exact AuditReport each must replay to through ``audit_texts``.
   A refactor of the census, the placement rule, or the resource tagging
   that changes any verdict FAILS HERE instead of silently re-verdicting.
   Intentional changes: regenerate with
   ``PYTHONPATH=src python tests/golden/regen.py`` and say why in the
   commit.
"""
import gzip
import json
import os

import pytest

from repro.analysis import (K_HI, K_LO, AuditReport, audit_texts,
                            take_census)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
HLO_DIR = os.path.join(GOLDEN_DIR, "hlo")

with open(os.path.join(GOLDEN_DIR, "audit_expected.json")) as f:
    AUDIT_EXPECTED = json.load(f)

PATTERNS = K_HI - K_LO
TRIP = 16          # trip count of the hand-built region loop


# ---------------------------------------------------------------------------
# Hand-built minimal HLO: one entry, one trip-16 while loop, optional noise
# placed in the loop body / the entry / a fused sub-computation.
# ---------------------------------------------------------------------------


def _mod(*, body_adds=0, body_chain=0, body_indep=0, entry_adds=0,
         entry_consts=0, entry_muls=0, fusion_adds=0) -> str:
    """One synthetic optimized-HLO module.

    ``body_adds``   chained f32 adds inside the while body (the intact shape)
    ``body_chain``  serially dependent dynamic-slices in the body (a chase)
    ``body_indep``  independent dynamic-slices in the body (bandwidth shape)
    ``entry_*``     ops in the entry computation (hoisted / folded shapes)
    ``fusion_adds`` adds inside a fused sub-computation called once
    """
    body = [
        "%wbody (bp: (s32[], f32[8])) -> (s32[], f32[8]) {",
        "  %bp = (s32[], f32[8]) parameter(0)",
        "  %iv = s32[] get-tuple-element(%bp), index=0",
        "  %acc = f32[8] get-tuple-element(%bp), index=1",
        "  %one = s32[] constant(1)",
        "  %ivn = s32[] add(%iv, %one)",
    ]
    prev = "%acc"
    for i in range(body_adds):
        body.append(f"  %na.{i} = f32[8] add({prev}, %acc)")
        prev = f"%na.{i}"
    chain = "%acc"
    for i in range(body_chain):
        body.append(f"  %nc.{i} = f32[8] dynamic-slice({chain}, %iv), "
                    "dynamic_slice_sizes={8}")
        chain = f"%nc.{i}"
    for i in range(body_indep):
        body.append(f"  %ni.{i} = f32[8] dynamic-slice(%acc, %iv), "
                    "dynamic_slice_sizes={8}")
    body += [f"  ROOT %bt = (s32[], f32[8]) tuple(%ivn, {prev})", "}"]

    cond = [
        "%wcond (cp: (s32[], f32[8])) -> pred[] {",
        "  %cp = (s32[], f32[8]) parameter(0)",
        "  %civ = s32[] get-tuple-element(%cp), index=0",
        f"  %lim = s32[] constant({TRIP})",
        "  ROOT %lt = pred[] compare(%civ, %lim), direction=LT",
        "}",
    ]

    fused = []
    if fusion_adds:
        fused = ["%fused_noise (fp0: f32[8]) -> f32[8] {",
                 "  %fp0 = f32[8] parameter(0)"]
        fprev = "%fp0"
        for i in range(fusion_adds - 1):
            fused.append(f"  %fa.{i} = f32[8] add({fprev}, %fp0)")
            fprev = f"%fa.{i}"
        fused += [f"  ROOT %fa.r = f32[8] add({fprev}, %fp0)", "}"]

    entry = [
        "ENTRY %main (a: f32[8]) -> f32[8] {",
        "  %a = f32[8] parameter(0)",
        "  %zero = s32[] constant(0)",
        "  %init = (s32[], f32[8]) tuple(%zero, %a)",
        "  %w = (s32[], f32[8]) while(%init), condition=%wcond, body=%wbody",
        "  %res = f32[8] get-tuple-element(%w), index=1",
    ]
    eprev = "%res"
    if fusion_adds:
        entry.append("  %fu = f32[8] fusion(%res), kind=kLoop, "
                     "calls=%fused_noise")
        eprev = "%fu"
    for i in range(entry_adds):
        entry.append(f"  %ea.{i} = f32[8] add({eprev}, %res)")
        eprev = f"%ea.{i}"
    for i in range(entry_consts):
        entry.append(f"  %ec.{i} = f32[8] constant({{0,0,0,0,0,0,0,0}})")
    for i in range(entry_muls):
        entry.append(f"  %em.{i} = f32[8] multiply({eprev}, %res)")
        eprev = f"%em.{i}"
    entry += [f"  ROOT %out = f32[8] copy({eprev})", "}"]

    return "\n".join(["HloModule synthetic", ""] + cond + body + fused
                     + entry) + "\n"


def _audit(clean, lo, hi, *, target="compute", hint=None):
    return audit_texts(clean, lo, hi, region="synthetic", mode="m",
                       target=target,
                       hint={"in_loop": True} if hint is None else hint)


def test_census_applies_loop_multiplier_and_skips_plumbing():
    c = take_census(_mod(body_adds=2))
    # loop-counter add + 2 noise adds, each once per trip, in a sub comp
    assert c.counts[("add", TRIP, "sub")] == 3
    assert c.loop_mult == TRIP + 1     # the while cond runs trip+1 times
    assert not any(op in ("tuple", "get-tuple-element", "parameter", "while")
                   for (op, _, _) in c.counts)


def test_intact_payload_scales_per_pattern():
    rep = _audit(_mod(), _mod(body_adds=K_LO), _mod(body_adds=K_HI))
    assert (rep.verdict, rep.corruption) == ("intact", None)
    assert rep.survival == 1.0
    assert rep.predicted == "compute" and rep.agrees is True
    assert rep.ok


def test_dce_detected_when_nothing_survives():
    clean = _mod()
    rep = _audit(clean, clean, clean)
    assert (rep.verdict, rep.corruption) == ("dead", "dce")
    assert rep.survival == 0.0 and not rep.ok


def test_constant_folding_detected_via_constant_growth():
    rep = _audit(_mod(), _mod(entry_consts=1), _mod(entry_consts=2))
    assert (rep.verdict, rep.corruption) == ("dead", "constant_folding")


def test_strength_reduction_detected_via_multiply_growth():
    # k chained adds became one a*k multiply: identical lo/hi, one extra
    # multiply vs clean
    rep = _audit(_mod(), _mod(entry_muls=1), _mod(entry_muls=1))
    assert (rep.verdict, rep.corruption) == ("dead", "strength_reduction")


def test_fusion_into_consumer_detected_by_sub_placement():
    rep = _audit(_mod(), _mod(fusion_adds=K_LO), _mod(fusion_adds=K_HI))
    assert (rep.verdict, rep.corruption) == ("degraded",
                                             "fusion_into_consumer")
    assert rep.survival == 1.0 and rep.ok      # scales — but runs once


def test_loop_invariant_hoisting_detected_by_entry_placement():
    rep = _audit(_mod(), _mod(entry_adds=K_LO), _mod(entry_adds=K_HI))
    assert (rep.verdict, rep.corruption) == ("degraded",
                                             "loop_invariant_hoisting")


def test_partial_elision_detected_below_one_op_per_pattern():
    hi = _mod(body_adds=K_LO + PATTERNS // 2)     # half the span survived
    rep = _audit(_mod(), _mod(body_adds=K_LO), hi)
    assert (rep.verdict, rep.corruption) == ("degraded", "partial_elision")
    assert rep.survival == 0.5


def test_single_step_grid_legitimately_places_at_mult_one():
    """A Pallas hint with steps=1 must NOT trip the hoisting detector —
    a one-step grid's noise lands at multiplier 1 by construction."""
    clean, lo, hi = _mod(), _mod(entry_adds=K_LO), _mod(entry_adds=K_HI)
    one = _audit(clean, lo, hi, hint={"in_loop": True, "steps": 1})
    assert (one.verdict, one.corruption) == ("intact", None)
    many = _audit(clean, lo, hi, hint={"in_loop": True, "steps": 8})
    assert many.verdict == "degraded"


def test_serial_load_chain_predicts_latency():
    rep = _audit(_mod(), _mod(body_chain=K_LO), _mod(body_chain=K_HI),
                 target="latency")
    assert rep.verdict == "intact"
    assert rep.predicted == "latency" and rep.agrees is True
    assert rep.resources["latency"] > 0


def test_independent_loads_predict_bandwidth():
    rep = _audit(_mod(), _mod(body_indep=K_LO), _mod(body_indep=K_HI),
                 target="memory")
    assert rep.verdict == "intact"
    assert rep.predicted == "bandwidth" and rep.agrees is True
    assert rep.resources["bandwidth"] > 0


def test_report_roundtrips_and_tolerates_store_kind_key():
    rep = _audit(_mod(), _mod(body_adds=K_LO), _mod(body_adds=K_HI))
    d = rep.to_dict()
    back = AuditReport.from_dict({"kind": "audit", **d})
    assert back.to_dict() == d
    assert rep.region in rep.explain() and rep.verdict in rep.explain()


# ---------------------------------------------------------------------------
# Golden replay: checked-in optimized dumps -> pinned AuditReport
# ---------------------------------------------------------------------------


def _read_gz(name: str) -> str:
    with gzip.open(os.path.join(HLO_DIR, name), "rt") as f:
        return f.read()


@pytest.mark.parametrize(
    "entry", AUDIT_EXPECTED,
    ids=[f"{e['region']}/{e['mode']}" for e in AUDIT_EXPECTED])
def test_golden_hlo_audits_identically(entry):
    clean = _read_gz(f"{entry['region']}__clean.txt.gz")
    lo = _read_gz(f"{entry['region']}__{entry['mode']}__lo.txt.gz")
    hi = _read_gz(f"{entry['region']}__{entry['mode']}__hi.txt.gz")
    rep = audit_texts(clean, lo, hi, region=entry["region"],
                      mode=entry["mode"], target=entry["target"],
                      hint=entry["hint"])
    assert rep.to_dict() == entry["report"], (
        f"{entry['region']}/{entry['mode']}: audit of the checked-in dumps "
        "changed — census / detectors / resource tagging moved; if "
        "intended, regenerate via tests/golden/regen.py")


def test_golden_audit_covers_all_kernels_and_a_loop_region():
    regions = {e["region"] for e in AUDIT_EXPECTED}
    for stem in ("pallas_probe", "pallas_matmul", "pallas_attn",
                 "pallas_spmxv"):
        assert any(r.startswith(stem) for r in regions), stem
    assert "stream_triad" in regions               # the loop-region shape
    assert all(e["report"]["verdict"] == "intact" for e in AUDIT_EXPECTED)

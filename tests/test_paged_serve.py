"""Paged KV-cache serving: dense/paged numerical equivalence, page-pool
lifecycle (refill, retire, free/reuse, stall/resume), engine bookkeeping
fixes (uid monotonicity, late submissions, declared-axis scatter), and the
fleet's "serve" target kind end to end (classify + replay)."""
import dataclasses
import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.models.model import build
from repro.serve import ServeEngine

ARCH = "deepseek_coder_33b"


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config(ARCH)
    api = build(cfg)
    return api, api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def smoke_f32():
    cfg = dataclasses.replace(get_smoke_config(ARCH),
                              param_dtype="float32",
                              compute_dtype="float32")
    api = build(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def _prompts(n, rng=None, lo=2, hi=10):
    rng = rng or np.random.default_rng(7)
    return [rng.integers(1, 64, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------------------
# dense vs paged numerical equivalence
# ---------------------------------------------------------------------------

def test_paged_decode_logits_match_dense_f32(smoke_f32):
    """Per-step decode logits agree with the dense cache path to f32
    tolerance (the paged read is the same computation re-laid-out)."""
    api, params = smoke_f32
    cfg = api.cfg
    page, max_seq = 4, 16
    maxp = max_seq // page
    B, sp = 2, 8
    toks = jnp.asarray(np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=(B, sp)), jnp.int32)

    _, cache_d = tf.lm_prefill(params, cfg, {"tokens": toks}, max_seq)
    n_pages = B * maxp
    cache_p = tf.lm_paged_decode_init(params, cfg, n_pages + 1, page)
    npp = sp // page
    # each slot's full worst case pre-assigned (the engine grows tables
    # lazily, but attention only reads positions <= pos either way)
    table = jnp.arange(B * maxp, dtype=jnp.int32).reshape(B, maxp)
    _, cache_p = tf.lm_paged_prefill(params, cfg, {"tokens": toks}, cache_p,
                                     table[:, :npp])

    pos = jnp.full((B,), sp, jnp.int32)
    cur = toks[:, -1:]
    for _ in range(4):
        lg_d, cache_d = api.decode_step(params, cache_d, cur, pos)
        lg_p, cache_p = tf.lm_paged_decode_step(params, cfg, cache_p, cur,
                                                pos, table)
        np.testing.assert_allclose(np.asarray(lg_d[:, -1]),
                                   np.asarray(lg_p[:, -1]),
                                   atol=1e-5, rtol=1e-5)
        cur = jnp.argmax(lg_d[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos = pos + 1


def test_engine_dense_paged_tokens_equal(smoke):
    """Greedy decode through the engine is token-identical across layouts,
    at the configs' default (bfloat16) dtypes."""
    api, params = smoke
    prompts = _prompts(5)
    outs = {}
    for paged in (False, True):
        eng = ServeEngine(api, params, n_slots=2, max_seq=64, paged=paged)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        outs[paged] = [r.out for r in reqs]
    assert outs[False] == outs[True]


# ---------------------------------------------------------------------------
# slot refill / retirement / page lifecycle
# ---------------------------------------------------------------------------

def test_slot_refill_matches_solo(smoke):
    """More requests than slots: refilled slots produce the same tokens as
    solo runs (no state leaks across waves), over multiple prefill waves."""
    api, params = smoke
    prompts = _prompts(5, np.random.default_rng(3))
    news = [3, 7, 4, 6, 5]
    solo = []
    for p, n in zip(prompts, news):
        eng = ServeEngine(api, params, n_slots=1, max_seq=64, paged=True)
        r = eng.submit(p, max_new=n)
        eng.run()
        solo.append(r.out)
    eng = ServeEngine(api, params, n_slots=2, max_seq=64, paged=True)
    reqs = [eng.submit(p, max_new=n) for p, n in zip(prompts, news)]
    eng.run()
    assert eng.report()["prefill_calls"] >= 2     # multiple admission waves
    for r, want in zip(reqs, solo):
        assert r.done and r.out == want, (r.out, want)


def test_eos_retirement(smoke):
    api, params = smoke
    prompt = [3, 1, 4, 1, 5]
    ref = ServeEngine(api, params, n_slots=1, max_seq=64, paged=True)
    r0 = ref.submit(prompt, max_new=8)
    ref.run()
    eos = r0.out[1]               # eos is only checked on decode ticks
    stop = next(i for i in range(1, len(r0.out)) if r0.out[i] == eos)

    eng = ServeEngine(api, params, n_slots=1, max_seq=64, paged=True,
                      eos_id=eos)
    r = eng.submit(prompt, max_new=20)
    eng.run()
    assert r.done and r.out == r0.out[:stop + 1]


def test_max_new_and_max_seq_retirement(smoke):
    api, params = smoke
    eng = ServeEngine(api, params, n_slots=2, max_seq=32, paged=True,
                      page_size=16)
    short = eng.submit([1, 2, 3], max_new=3)
    capped = eng.submit(list(range(1, 29)), max_new=100)   # hits max_seq
    eng.run()
    assert short.done and len(short.out) == 3
    assert capped.done and len(capped.out) < 100
    assert len(capped.prompt) + len(capped.out) <= 32


def test_page_free_and_reuse(smoke):
    api, params = smoke
    eng = ServeEngine(api, params, n_slots=2, max_seq=32, paged=True,
                      page_size=8)
    assert eng.n_pages == 8
    reqs = [eng.submit(p, max_new=4) for p in _prompts(2)]
    eng.step()
    first = {pid for pages in eng._slot_pages for pid in pages}
    assert first and eng._trash not in first
    assert eng.pool_occupancy() == pytest.approx(len(first) / eng.n_pages)
    eng.run()
    assert all(r.done for r in reqs)
    assert sorted(eng._free) == list(range(eng.n_pages))   # all freed
    assert (eng._table_np == eng._trash).all()

    reqs2 = [eng.submit(p, max_new=4) for p in _prompts(2)]
    eng.step()
    second = {pid for pages in eng._slot_pages for pid in pages}
    assert first & second                                  # pages reused
    eng.run()
    assert all(r.done for r in reqs2)


def test_stall_and_resume(smoke):
    """A slot that cannot grow (empty free list) stalls with its state
    intact and resumes — producing the same tokens — once pages free up."""
    api, params = smoke
    prompt = [5, 6, 7]
    ref = ServeEngine(api, params, n_slots=2, max_seq=32, paged=True,
                      page_size=4)
    r_ref = ref.submit(prompt, max_new=10)
    ref.run()

    eng = ServeEngine(api, params, n_slots=2, max_seq=32, paged=True,
                      page_size=4)
    r = eng.submit(prompt, max_new=10)
    eng.step()                                   # admit: 1 page in use
    stolen, eng._free = eng._free, []            # pool "exhausted"
    for _ in range(8):
        eng.step()
        if eng._stalled.any():
            break
    assert eng._stalled[0] and not eng.active[0] and not r.done
    eng._free = stolen
    eng.run()
    assert r.done and r.out == r_ref.out


def test_partial_resume_syncs_page_table(smoke):
    """Fewer free pages than stalled slots: the slots that DO resume must
    have their new page pushed to the device table before the next tick
    (regression: an early return skipped the sync, so the resumed slot's
    KV scattered into the trash page — silent corruption). Tokens must
    match the dense engine exactly."""
    api, params = smoke
    prompts = [[5, 6, 7], [9, 2, 4]]
    ref = ServeEngine(api, params, n_slots=2, max_seq=32, paged=False)
    refs = [ref.submit(p, max_new=8) for p in prompts]
    ref.run()

    eng = ServeEngine(api, params, n_slots=2, max_seq=32, paged=True,
                      page_size=4)
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    eng.step()                    # admit wave + tick 1 (grows to 2 pages)
    stolen, eng._free = eng._free, []            # pool "exhausted"
    for _ in range(10):                          # both outgrow page 2
        if eng._stalled.all():
            break
        eng.step()
    assert eng._stalled.all() and not any(r.done for r in reqs)
    eng._free = [stolen.pop()]                   # 1 page for 2 stalled slots
    eng.step()
    assert eng.active[0] and eng._stalled[1]     # partial resume
    # the resumed slot's new page must be on DEVICE, not just in the host
    # mirror — a stale device row scatters its KV into the trash page
    np.testing.assert_array_equal(np.asarray(eng.page_table), eng._table_np)
    eng.run()                # slot 0 retires -> its pages resume slot 1
    assert all(r.done for r in reqs)
    assert [r.out for r in reqs] == [r.out for r in refs]


def test_pool_exhaustion_raises(smoke):
    """Every in-flight request stalled with nothing retirable is a
    deadlock: the engine must fail loudly, not spin."""
    api, params = smoke
    eng = ServeEngine(api, params, n_slots=2, max_seq=16, paged=True,
                      page_size=4, n_pages=4)
    for p in _prompts(2, lo=2, hi=4):
        eng.submit(p, max_new=14)               # both need all 4 pages
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        eng.run()


def test_pool_below_single_request_rejected(smoke):
    api, params = smoke
    with pytest.raises(ValueError, match="pool smaller"):
        ServeEngine(api, params, n_slots=1, max_seq=32, paged=True,
                    page_size=4, n_pages=2)


# ---------------------------------------------------------------------------
# engine bookkeeping fixes
# ---------------------------------------------------------------------------

def test_uids_monotonic_never_reused(smoke):
    api, params = smoke
    eng = ServeEngine(api, params, n_slots=1, max_seq=64)
    a = eng.submit([1, 2], max_new=2)
    eng.run()
    b = eng.submit([3, 4], max_new=2)            # queue drained and refilled
    c = eng.submit([5, 6], max_new=2)
    assert (a.uid, b.uid, c.uid) == (a.uid, a.uid + 1, a.uid + 2)


def test_run_returns_late_and_stepped_completions(smoke):
    """run() completions cover requests finished by manual step() calls and
    requests submitted after a previous run — not a startup snapshot."""
    api, params = smoke
    eng = ServeEngine(api, params, n_slots=1, max_seq=64)
    a = eng.submit([1, 2, 3], max_new=2)
    while not a.done:
        eng.step()
    b = eng.submit([4, 5], max_new=2)
    done = eng.run()
    assert {r.uid for r in done} == {a.uid, b.uid}
    assert eng.run() == []                       # drained


def test_scatter_slot_respects_declared_axes():
    """Only leaves whose cache_spec declares a "cache_batch" axis are
    scattered, on THAT axis; shared leaves (no batch axis) pass through."""
    spec = {"kv": (None, "cache_batch", "cache_seq"), "kpos": ("cache_seq",)}
    fake = SimpleNamespace(api=SimpleNamespace(cache_spec=lambda: spec))
    big = {"kv": jnp.zeros((2, 4, 6)), "kpos": jnp.arange(6.0)}
    small = {"kv": jnp.ones((2, 1, 6)), "kpos": jnp.full((6,), 9.0)}
    out = ServeEngine._scatter_slot(fake, big, small, 2)
    kv = np.asarray(out["kv"])
    assert (kv[:, 2] == 1).all() and kv.sum() == 12      # axis 1, slot 2 only
    np.testing.assert_array_equal(np.asarray(out["kpos"]), np.arange(6.0))


# ---------------------------------------------------------------------------
# fleet "serve" target kind
# ---------------------------------------------------------------------------

def test_serve_plan_roundtrip_and_names(tmp_path):
    from repro.fleet.plan import SweepPlan, TargetSpec

    spec = TargetSpec("serve", ("fp_add32",),
                      {"arch": ARCH, "slots": 2, "prompt": 8, "max_new": 4})
    plan = SweepPlan(name="t", store=str(tmp_path / "s.jsonl"),
                     targets=[spec], reps=1)
    plan.validate()
    names = spec.region_names()
    assert len(names) == 2
    assert any("prefill" in n for n in names)
    assert any("decode" in n for n in names)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    again = SweepPlan.load(path)
    assert again.targets[0].kind == "serve"
    assert again.digest() == plan.digest()
    assert again.grid() == plan.grid()


def test_serve_plan_validation_rejects_bad_params(tmp_path):
    from repro.fleet.plan import PlanError, SweepPlan, TargetSpec

    with pytest.raises(PlanError, match="slots"):
        SweepPlan(name="t", store="s", targets=[
            TargetSpec("serve", ("fp_add32",), {"arch": ARCH, "slots": 0})
        ]).validate()
    with pytest.raises(PlanError, match="arch"):
        SweepPlan(name="t", store="s", targets=[
            TargetSpec("serve", ("fp_add32",), {})   # arch missing
        ]).validate()


def test_serve_campaign_classifies_and_replays(tmp_path):
    """The acceptance path: a fleet run over a "serve" plan classifies
    prefill and decode as separate regions into a resumable store, and a
    completed campaign replays with ZERO new measurements."""
    from repro.fleet.executor import run_worker
    from repro.fleet.plan import SweepPlan, TargetSpec

    plan = SweepPlan(
        name="serve-test", store=str(tmp_path / "serve.jsonl"),
        targets=[TargetSpec("serve", ("fp_add32",),
                            {"arch": "gemma_2b", "slots": 2, "prompt": 8,
                             "max_new": 4})],
        reps=1)
    plan.validate()
    reports, stats = run_worker(plan, fresh=True)
    assert stats.measured > 0
    names = sorted(reports)
    assert len(names) == 2
    assert any("prefill" in n for n in names)
    assert any("decode" in n for n in names)
    for rep in reports.values():
        assert rep.bottleneck.label            # classified, not empty

    reports2, stats2 = run_worker(plan, expect_no_measure=True)
    assert stats2.measured == 0 and stats2.cached > 0
    assert sorted(reports2) == names

"""Assigned-architecture configs match the assignment sheet; param counts hit
their advertised sizes; shape applicability rules."""
import pytest

from repro.configs import ARCHS, SHAPES, canonical, get_config, get_smoke_config
from repro.configs.base import shape_applicable

SPEC = {  # arch: (L, d_model, H, kv, d_ff, vocab)
    "mixtral_8x22b": (56, 6144, 48, 8, 0, 32768),
    "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 0, 151936),
    "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
    "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
    "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
    "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
    "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
    "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
    "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
    "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
}

# advertised total parameter counts (billions) and tolerance
SIZES = {
    "mixtral_8x22b": (141, 0.15),
    "qwen3_moe_30b_a3b": (30.5, 0.2),
    "mamba2_780m": (0.78, 0.25),
    "llava_next_34b": (34, 0.2),
    "minitron_4b": (4.2, 0.3),
    "deepseek_coder_33b": (33, 0.15),
    "gemma_2b": (2.5, 0.3),
    "mistral_large_123b": (123, 0.1),
    "zamba2_1p2b": (1.2, 0.35),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, H, kv, dff, vocab = SPEC[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if H:
        assert cfg.n_heads == H and cfg.n_kv_heads == kv
    assert cfg.d_ff == dff
    assert cfg.vocab_size == vocab


def test_moe_configs():
    mix = get_config("mixtral-8x22b")
    assert mix.n_experts == 8 and mix.top_k == 2 and mix.moe_d_ff == 16384
    assert mix.window == 4096  # SWA
    q = get_config("qwen3-moe-30b-a3b")
    assert q.n_experts == 128 and q.top_k == 8 and q.moe_d_ff == 768


def test_ssm_configs():
    m = get_config("mamba2-780m")
    assert m.ssm_state == 128 and m.family == "ssm"
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.family == "hybrid" and z.attn_every > 0


@pytest.mark.parametrize("arch", sorted(SIZES))
def test_param_count_matches_advertised(arch):
    cfg = get_config(arch)
    want_b, tol = SIZES[arch]
    got_b = cfg.param_count() / 1e9
    assert abs(got_b - want_b) / want_b <= tol, (arch, got_b, want_b)


def test_active_params_moe():
    mix = get_config("mixtral_8x22b")
    active = mix.active_param_count() / 1e9
    assert 30 <= active <= 50, active          # ~39B advertised
    q = get_config("qwen3_moe_30b_a3b")
    assert 2 <= q.active_param_count() / 1e9 <= 5   # ~3B active


def test_shape_applicability():
    # long_500k runs only for sub-quadratic archs
    runs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs["mamba2_780m"] and runs["zamba2_1p2b"]
    assert runs["mixtral_8x22b"]          # SWA rolling cache
    for dense in ("gemma_2b", "mistral_large_123b", "deepseek_coder_33b",
                  "llava_next_34b", "whisper_large_v3", "qwen3_moe_30b_a3b"):
        assert not runs[dense], dense
    # every other shape runs everywhere
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]


def test_canonical_aliases():
    assert canonical("mixtral-8x22b") == "mixtral_8x22b"
    assert canonical("zamba2-1.2b") == "zamba2_1p2b"
    with pytest.raises(KeyError):
        canonical("not-an-arch")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_small(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    assert cfg.d_model <= 128 and cfg.n_layers <= 4
    assert cfg.param_count() < 5e6

"""MoE dispatch invariants: capacity-bounded sort dispatch == naive per-token
routing (up to drops); slot bookkeeping; aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe
from repro.models.layers import _act


@pytest.fixture(scope="module")
def cfg():
    # high capacity factor so nothing drops in the equivalence test
    return get_smoke_config("mixtral_8x22b").scaled(capacity_factor=8.0)


def naive_moe(p, cfg, x):
    """Route every token through its top-k experts, no capacity limit."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    cd = x.dtype
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for t in range(xf.shape[0]):
        for j in range(cfg.top_k):
            e = int(eidx[t, j])
            g = jnp.einsum("d,df->f", xf[t], p["w_gate"][e].astype(cd))
            u = jnp.einsum("d,df->f", xf[t], p["w_up"][e].astype(cd))
            o = jnp.einsum("f,fd->d", _act(cfg.act, g) * u,
                           p["w_down"][e].astype(cd))
            y = y.at[t].add(gates[t, j] * o.astype(jnp.float32))
    return y.reshape(B, S, D)


def test_dispatch_matches_naive(cfg):
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
         .astype(jnp.bfloat16))
    y, aux = moe.moe_block(p, cfg, x)
    ref = naive_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
    assert np.isfinite(float(aux["moe_lb_loss"]))
    assert np.isfinite(float(aux["moe_z_loss"]))


def test_capacity_drops_zero_not_nan(cfg):
    """With capacity 1 token/expert, dropped tokens contribute zeros."""
    c = cfg.scaled(capacity_factor=1e-6)   # floor capacity (8) still applies
    p = moe.init_moe(jax.random.PRNGKey(0), c)
    x = (jax.random.normal(jax.random.PRNGKey(1), (4, 32, c.d_model))
         .astype(jnp.bfloat16))
    y, _ = moe.moe_block(p, c, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_group_dispatch_slots(cfg):
    """Slot indices are per-expert contiguous and within counts."""
    Tg, k = 16, 2
    eidx = jax.random.randint(jax.random.PRNGKey(2), (Tg, k), 0,
                              cfg.n_experts, dtype=jnp.int32)
    x = jnp.ones((Tg, 8), jnp.float32)
    buf, slots = moe._group_dispatch(x, eidx, cfg.scaled(d_model=8), 64)
    counts = np.zeros(cfg.n_experts, np.int64)
    got = np.asarray(slots)
    e = np.asarray(eidx)
    for t in range(Tg):
        for j in range(k):
            assert 0 <= got[t, j]
            counts[e[t, j]] += 1
    # total dispatched entries equal Tg*k
    assert counts.sum() == Tg * k


def test_load_balance_loss_uniform_low():
    """A uniform router gives the minimal load-balance loss (≈1)."""
    cfg = get_smoke_config("qwen3_moe_30b_a3b").scaled(capacity_factor=4.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
         .astype(jnp.bfloat16))
    _, aux = moe.moe_block(p, cfg, x)
    assert 0.9 <= float(aux["moe_lb_loss"]) <= 1.3


def test_grouped_vs_single_group(cfg):
    """n_groups=2 (per-shard dispatch) matches n_groups=1 when capacity is
    ample — the all-to-all refactoring does not change semantics."""
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg.d_model))
         .astype(jnp.bfloat16))
    y1, _ = moe.moe_block(p, cfg, x, n_groups=1)
    y2, _ = moe.moe_block(p, cfg, x, n_groups=2)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=5e-2,
                               atol=5e-2)

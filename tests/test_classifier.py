"""Classifier decision table (paper §4.2 / Table 3), pinned label by label.

The campaign/golden regression suite locks curve ASSEMBLY; this module locks
the DECISION step: every label reachable from a synthetic signature, exact
behaviour at the LOW/HIGH thresholds, confidence always a probability, and
the loop-level and graph-level mode vocabularies hitting the same labels.
"""
import pytest

from repro.core import classify, cross_check_with_decan
from repro.core.classifier import HIGH, LOW

# Signature -> expected label, in BOTH vocabularies. Values chosen from the
# paper's rows (HACCmk 0/13/-, STREAM, lat_mem_rd) scaled to the thresholds.
LABEL_CASES = [
    # compute: fp degrades immediately, L1 noise absorbed (HACCmk)
    ("compute", {"fp_add": 0.0, "l1_ld": 13.0},
                {"fp_add32": 0.0, "vmem_ld": 13.0}),
    # bandwidth: stream noise not absorbed while fp (and some l1) are
    ("bandwidth", {"fp_add": 30.0, "l1_ld": 8.0, "mem_ld": 1.0},
                  {"fp_add32": 30.0, "vmem_ld": 8.0, "hbm_stream": 1.0}),
    # latency: substantial memory noise absorbed alongside large fp noise
    ("latency", {"fp_add": 40.0, "mem_ld": 10.0},
                {"fp_add32": 40.0, "hbm_stream": 10.0}),
    # overlap: nothing absorbed anywhere (Table 3 case 3 / case 4 ambiguity)
    ("overlap", {"fp_add": 1.0, "l1_ld": 2.0, "mem_ld": 0.0},
                {"fp_add32": 1.0, "vmem_ld": 2.0, "hbm_stream": 0.0}),
    # ici: collective noise collapses while core resources have slack
    ("ici", {"ici_allreduce": 1.0, "fp_add": 15.0, "l1_ld": 12.0},
            {"ici_allreduce": 1.0, "fp_add32": 15.0, "vmem_ld": 12.0}),
    # mixed: moderate absorption everywhere (Table 3 case 4)
    ("mixed", {"fp_add": 8.0, "l1_ld": 8.0},
              {"fp_add32": 8.0, "vmem_ld": 8.0}),
]


@pytest.mark.parametrize(
    "label,loop_sig,graph_sig",
    LABEL_CASES, ids=[c[0] for c in LABEL_CASES])
def test_label_reachable_in_both_vocabularies(label, loop_sig, graph_sig):
    assert classify(loop_sig).label == label
    assert classify(graph_sig).label == label


# ---------------------------------------------------------------------------
# Exact behaviour AT the thresholds (<= LOW is saturated, >= HIGH is clear)
# ---------------------------------------------------------------------------

def test_fp_exactly_low_is_still_compute():
    # fp == LOW counts as saturated (<=), so the compute signature holds
    assert classify({"fp_add": LOW, "l1_ld": HIGH}).label == "compute"


def test_fp_just_above_low_is_not_compute():
    r = classify({"fp_add": LOW + 0.1, "l1_ld": HIGH})
    assert r.label != "compute"


def test_mem_exactly_low_with_fp_exactly_high_is_bandwidth():
    # mem == LOW saturated AND fp == HIGH clear: the STREAM signature
    sig = {"fp_add": HIGH, "l1_ld": LOW + 1.0, "mem_ld": LOW}
    assert classify(sig).label == "bandwidth"


def test_fp_below_high_breaks_the_bandwidth_signature():
    sig = {"fp_add": HIGH - 0.1, "l1_ld": LOW + 1.0, "mem_ld": LOW}
    assert classify(sig).label != "bandwidth"


def test_mem_just_above_low_flips_bandwidth_to_latency():
    base = {"fp_add": HIGH, "l1_ld": LOW + 1.0}
    assert classify({**base, "mem_ld": LOW}).label == "bandwidth"
    assert classify({**base, "mem_ld": LOW + 0.1}).label == "latency"


def test_everything_exactly_low_is_overlap():
    sig = {"fp_add": LOW, "l1_ld": LOW, "mem_ld": LOW}
    assert classify(sig).label == "overlap"


def test_ici_threshold_on_core_slack():
    # ici saturated; core modes need >= HIGH/2 slack for the ici verdict
    ok = {"ici_allreduce": LOW, "fp_add": HIGH / 2, "l1_ld": HIGH / 2}
    assert classify(ok).label == "ici"
    starved = {"ici_allreduce": LOW, "fp_add": HIGH / 2 - 0.1,
               "l1_ld": HIGH / 2}
    assert classify(starved).label != "ici"


def test_custom_thresholds_are_respected():
    # the analytic probe classifies absorbed-work FRACTIONS with scaled
    # thresholds — the decision logic must follow the arguments, not LOW/HIGH
    sig = {"fp_add": 5.0, "l1_ld": 90.0}
    assert classify(sig, low=10.0, high=60.0).label == "compute"
    assert classify(sig).label != "compute"   # 5.0 > default LOW


# ---------------------------------------------------------------------------
# Confidence is a probability, on every reachable branch
# ---------------------------------------------------------------------------

CONF_CASES = [c[1] for c in LABEL_CASES] + [c[2] for c in LABEL_CASES] + [
    {"fp_add": 0.0, "l1_ld": 10_000.0},          # huge separation: clamps to 1
    {"fp_add": 0.0, "mem_ld": HIGH},             # compute via the mem clause
    {"l1_ld": 0.0, "fp_add": LOW + 1.0},         # l1/LSU branch (Fig. 4a)
    {"ici_allreduce": 0.0},                      # ici with no core modes
    {"chase": 12.0},                             # chase-only: falls to mixed
    {},                                          # empty signature
]


@pytest.mark.parametrize("sig", CONF_CASES)
def test_confidence_always_in_unit_interval(sig):
    r = classify(sig)
    assert 0.0 <= r.confidence <= 1.0
    assert r.label in ("compute", "bandwidth", "latency", "ici", "overlap",
                       "l1", "mixed")
    assert r.absorptions == dict(sig)


# ---------------------------------------------------------------------------
# DECAN cross-check resolves the overlap ambiguity (Fig. 6)
# ---------------------------------------------------------------------------

def test_cross_check_confirms_genuine_overlap():
    r = classify({"fp_add": 1.0, "l1_ld": 1.0})
    out = cross_check_with_decan(r, sat_fp=0.95, sat_ls=0.92)
    assert out.label == "overlap" and out.decan_hint is not None


def test_cross_check_rules_out_overlap_to_frontend():
    r = classify({"fp_add": 1.0, "l1_ld": 1.0})
    out = cross_check_with_decan(r, sat_fp=0.81, sat_ls=0.12)
    assert out.label == "frontend" and "rules out" in out.decan_hint


def test_cross_check_leaves_other_labels_alone():
    r = classify({"fp_add": 0.0, "l1_ld": 13.0})
    assert cross_check_with_decan(r, 0.5, 0.5).label == "compute"

"""Per-arch smoke tests (reduced same-family configs): one forward/train step
on CPU asserting output shapes + no NaNs, one decode step, and gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models.model import build

SHAPE = ShapeConfig("smoke", "train", 64, 2)


@pytest.fixture(scope="module")
def apis():
    out = {}
    for a in ARCHS:
        cfg = get_smoke_config(a)
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        out[a] = (api, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(apis, arch):
    api, params = apis[arch]
    batch = api.dummy_batch(SHAPE)
    logits, aux = jax.jit(lambda p, b: api.forward(p, b))(params, batch)
    S = SHAPE.seq_len + (api.cfg.n_img_tokens if api.cfg.family == "vlm" else 0)
    assert logits.shape == (2, S, api.cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_finite(apis, arch):
    api, params = apis[arch]
    batch = api.dummy_batch(SHAPE)

    def loss_fn(p):
        return api.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g, np.float32)).all()
                          for g in leaves)
    # at least 99% of leaves receive nonzero gradient signal
    nonzero = sum(bool(np.abs(np.asarray(g, np.float32)).sum() > 0)
                  for g in leaves)
    assert nonzero >= int(0.9 * len(leaves)), (nonzero, len(leaves))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(apis, arch):
    api, params = apis[arch]
    B = 2
    if api.cfg.family == "encdec":
        frames = jnp.zeros((B, api.cfg.enc_frames, api.cfg.d_model),
                           jnp.dtype(api.cfg.compute_dtype))
        cache = api.decode_init(params, {"frames": frames, "max_seq": 32})
    else:
        cache = api.decode_init(params, {"tokens": jnp.zeros((B, 1), jnp.int32),
                                         "max_seq": 32})
    logits, cache2 = jax.jit(api.decode_step)(
        params, cache, jnp.ones((B, 1), jnp.int32), jnp.int32(0))
    assert logits.shape == (B, 1, api.cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure is preserved (scan-compatible)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["gemma_2b", "mixtral_8x22b", "mamba2_780m"])
def test_one_train_step_reduces_loss(apis, arch):
    """Three family representatives actually learn on the lcg task."""
    from repro.configs import TrainConfig
    from repro.data.pipeline import SyntheticPipeline
    from repro.train.trainer import Trainer

    api, _ = apis[arch]
    tcfg = TrainConfig(lr=5e-3, warmup_steps=3, total_steps=40, ckpt_every=0)
    pipe = SyntheticPipeline(api.cfg, ShapeConfig("t", "train", 32, 8),
                             task="lcg")
    tr = Trainer(api, tcfg)
    state = tr.init_state()
    state, hist = tr.run(state, pipe, steps=30)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first * 0.97, (first, last)


def test_scan_group_equivalence(apis):
    """Grouped layer scan computes the same function."""
    api, params = apis["gemma_2b"]
    batch = api.dummy_batch(SHAPE)
    l1, _ = jax.jit(lambda p, b: api.forward(p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: api.forward(p, b, scan_group=2))(params, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=2e-2,
                               atol=2e-2)

"""Threshold calibration: the fit math, the forced-regime wrappers, the
calib record's store semantics, and the end-to-end campaign.

Pinned contracts:
  * ``fit_thresholds`` places max-margin cuts between the role clusters and
    falls back to the paper defaults (fitted=False) whenever the clusters
    are missing, overlap, or the cuts invert;
  * property layer (hypothesis, optional): the fit is deterministic, LOW
    always stays strictly below HIGH when fitted, widening the separating
    gap moves the cut monotonically, and refitting a fit's own samples is
    idempotent;
  * ``forced_regime`` appends the SynthShape marker where the synthetic
    clock scans for it and strips it before the real callable runs;
  * ``calib`` records are hw-keyed, last-wins superseded, and survive both
    store layouts and merge (plain-store layer here; the hypothesis layer
    lives in test_store_merge_props.py);
  * ``run_calibration`` refuses to run without the synthetic clock, fits
    low=4.5/high=16.5 from the shipped regime shapes, classifies all four
    known regimes correctly with mean confidence strictly above the
    default-threshold run, and REPLAYS from a complete store with zero
    new measurements.
"""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:   # property tests skip; the rest still runs
    from conftest import hypothesis_stub as hypothesis
    from conftest import strategies_stub as st

import os
import tempfile

import pytest

from repro.core.calibration import (CALIB_MODES, EXPECTED, REGIMES,
                                    calibrate_targets, fit_thresholds,
                                    forced_regime, resolve_thresholds,
                                    run_calibration)
from repro.core.classifier import HIGH, LOW, classify


def _samples(sats=(), mids=(), highs=()):
    out = []
    for role, k1s in (("sat", sats), ("mid", mids), ("high", highs)):
        out.extend({"region": "r", "mode": "m", "role": role, "k1": k1}
                   for k1 in k1s)
    return out


# ---------------------------------------------------------------- fit math

def test_fit_places_max_margin_cuts():
    low, high, fitted = fit_thresholds(
        _samples(sats=(0.0, 1.0), mids=(8.0,), highs=(24.0, 25.0)))
    assert fitted
    assert low == pytest.approx((1.0 + 8.0) / 2)      # sat-max .. mid-min
    assert high == pytest.approx((8.0 + 24.0) / 2)    # mid-max .. high-min


def test_fit_without_mid_cluster_falls_back():
    # with no mids both cuts collapse onto the same sat/high midpoint;
    # LOW must stay STRICTLY below HIGH, so the fit declines and keeps
    # the paper defaults rather than emit a degenerate low == high pair
    assert fit_thresholds(_samples(sats=(1.0,), highs=(25.0,))) \
        == (LOW, HIGH, False)


def test_fit_falls_back_when_a_boundary_cluster_is_missing():
    assert fit_thresholds(_samples(mids=(8.0,), highs=(24.0,))) \
        == (LOW, HIGH, False)
    assert fit_thresholds(_samples(sats=(1.0,), mids=(8.0,))) \
        == (LOW, HIGH, False)
    assert fit_thresholds([]) == (LOW, HIGH, False)


def test_fit_falls_back_when_clusters_overlap():
    # a sat sample above the mid cluster: no separating cut exists
    assert fit_thresholds(
        _samples(sats=(9.0,), mids=(8.0,), highs=(24.0,)))[2] is False
    # a mid sample above the high cluster
    assert fit_thresholds(
        _samples(sats=(1.0,), mids=(30.0,), highs=(24.0,)))[2] is False


def test_fit_honours_custom_defaults_on_fallback():
    low, high, fitted = fit_thresholds([], default_low=3.0, default_high=9.0)
    assert (low, high, fitted) == (3.0, 9.0, False)


def _wide_gap(draw_gap):
    sats = (0.0, 1.0)
    highs = (24.0 + draw_gap, 25.0 + draw_gap)
    return _samples(sats=sats, mids=(8.0,), highs=highs)


@hypothesis.given(st.lists(st.floats(0.0, 2.0, allow_nan=False), max_size=4),
                  st.lists(st.floats(6.0, 10.0, allow_nan=False), max_size=4),
                  st.lists(st.floats(20.0, 40.0, allow_nan=False),
                           min_size=1, max_size=4))
@hypothesis.settings(max_examples=60, deadline=None)
def test_fit_deterministic_and_never_inverts(sats, mids, highs):
    """Same samples -> same fit (pure function of its input), and a fitted
    result never inverts: LOW stays strictly below HIGH, else the fit must
    have fallen back to the paper defaults."""
    sats = sats or [0.0]
    a = fit_thresholds(_samples(sats=sats, mids=mids, highs=highs))
    b = fit_thresholds(_samples(sats=sats, mids=mids, highs=highs))
    assert a == b
    low, high, fitted = a
    if fitted:
        assert low < high
    else:
        assert (low, high) == (LOW, HIGH)


@hypothesis.given(st.floats(0.0, 50.0, allow_nan=False),
                  st.floats(0.0, 50.0, allow_nan=False))
@hypothesis.settings(max_examples=60, deadline=None)
def test_fit_monotone_in_the_separating_gap(gap_a, gap_b):
    """Widening the gap between the mid and high clusters never moves HIGH
    the wrong way: a larger gap yields a cut at least as high."""
    lo_gap, hi_gap = sorted((gap_a, gap_b))
    _, high_small, f1 = fit_thresholds(_wide_gap(lo_gap))
    _, high_large, f2 = fit_thresholds(_wide_gap(hi_gap))
    assert f1 and f2
    assert high_small <= high_large


@hypothesis.given(st.lists(st.floats(0.0, 2.0, allow_nan=False),
                           min_size=1, max_size=4),
                  st.lists(st.floats(6.0, 10.0, allow_nan=False),
                           min_size=1, max_size=4),
                  st.lists(st.floats(20.0, 40.0, allow_nan=False),
                           min_size=1, max_size=4))
@hypothesis.settings(max_examples=60, deadline=None)
def test_fit_idempotent_on_replayed_campaign(sats, mids, highs):
    """A replayed campaign hands fit_thresholds the exact same samples the
    original run persisted — the refit must reproduce the stored record."""
    samples = _samples(sats=sats, mids=mids, highs=highs)
    first = fit_thresholds(samples)
    again = fit_thresholds(list(samples))
    assert first == again


# ------------------------------------------------- forced-regime wrappers

def test_forced_regime_appends_and_strips_the_marker():
    from repro.core.absorption import SynthShape

    targets = {t.name: t for t in calibrate_targets(n=256, chunk=64)}
    assert set(targets) == set(REGIMES)
    t = targets["calib_compute"]
    args = t.args_for("fp_add", 3)
    assert isinstance(args[-1], SynthShape)
    assert args[-1] == REGIMES["calib_compute"]["fp_add"][1]
    rt_args = t.args_for_rt("fp_add")
    assert isinstance(rt_args[-1], SynthShape)
    # the wrapped callable must tolerate the marker: it strips it before
    # the real kernel sees the argument tuple
    fn = t.build("fp_add", 2)
    fn(*args)  # must not raise on the extra non-array marker
    assert t.payload_check("fp_add", 2) is None


def test_forced_regime_shapes_route_role_clusters():
    # every regime shapes all swept modes, and roles only come in the
    # three cluster names the fit understands
    for name, spec in REGIMES.items():
        assert set(spec) == set(CALIB_MODES)
        assert {role for role, _ in spec.values()} <= {"sat", "mid", "high"}
        assert name in EXPECTED


# ------------------------------------------------------- store semantics

def _calib_rec(hw="cpu", low=4.5, high=16.5, fitted=True):
    return {"kind": "calib", "hw": hw, "low": low, "high": high,
            "fitted": fitted, "reps": 2, "samples": []}


def test_calib_records_supersede_by_hw_and_survive_merge():
    from repro.core import CampaignStore, merge_stores

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.jsonl")
        store = CampaignStore(path)
        store.append(_calib_rec(low=1.0, high=2.0, fitted=False))
        store.append(_calib_rec(hw="tpu", low=3.0, high=30.0))
        store.append(_calib_rec(low=4.5, high=16.5))   # supersedes cpu
        store.close()
        loaded = CampaignStore(path)
        loaded.close()
        assert set(loaded.calib) == {"cpu", "tpu"}
        assert loaded.calib["cpu"]["low"] == 4.5
        assert loaded.calib["cpu"]["fitted"] is True
        merged = os.path.join(d, "m.jsonl")
        merge_stores(merged, [path])
        re = CampaignStore(merged)
        re.close()
        assert re.calib == loaded.calib


def test_calib_records_survive_the_segmented_layout_and_compaction():
    from repro.core import CampaignStore, compact_store

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.jsonl")
        store = CampaignStore(path, segmented=True)
        store.append(_calib_rec(low=1.0, high=9.0))
        store.close()
        store = CampaignStore(path, segmented=True)
        store.append(_calib_rec(low=4.5, high=16.5))
        store.close()
        compact_store(path)
        loaded = CampaignStore(path)
        loaded.close()
        assert loaded.calib["cpu"]["low"] == 4.5


class _FakeStore:
    def __init__(self, calib):
        self.calib = calib


def test_resolve_thresholds_provenance():
    assert resolve_thresholds(_FakeStore({})) == (LOW, HIGH, "default")
    assert resolve_thresholds(_FakeStore({"tpu": _calib_rec(hw="tpu")}),
                              hw="cpu") == (LOW, HIGH, "default")
    assert resolve_thresholds(
        _FakeStore({"cpu": _calib_rec(fitted=False)}),
        hw="cpu") == (LOW, HIGH, "fallback")
    assert resolve_thresholds(_FakeStore({"cpu": _calib_rec()}),
                              hw="cpu") == (4.5, 16.5, "calibrated")


# ------------------------------------------------------------ end-to-end

def test_run_calibration_requires_the_synth_clock(monkeypatch):
    monkeypatch.delenv("REPRO_SYNTH_MEASURE", raising=False)
    with pytest.raises(RuntimeError, match="REPRO_SYNTH_MEASURE"):
        run_calibration("unused.jsonl")


def test_run_calibration_end_to_end(monkeypatch, tmp_path):
    """The acceptance gate: all four known regimes classify correctly under
    the fitted thresholds, the MEAN confidence strictly beats the
    default-threshold run with no regime losing confidence, the calib
    record persists, and a re-run replays without measuring."""
    monkeypatch.setenv("REPRO_SYNTH_MEASURE", "1e-3")
    store = str(tmp_path / "cal.jsonl")
    res = run_calibration(store, reps=2)
    assert (res.low, res.high, res.fitted) == (4.5, 16.5, True)
    assert res.correct()
    fitted_conf, default_conf = [], []
    for name, rep in res.reports.items():
        assert rep.bottleneck.label == EXPECTED[name]
        absorptions = {m: r.fit.k1 for m, r in rep.results.items()}
        base = classify(absorptions)            # paper-default thresholds
        assert base.label == EXPECTED[name]     # defaults were already right
        assert rep.bottleneck.confidence >= base.confidence
        fitted_conf.append(rep.bottleneck.confidence)
        default_conf.append(base.confidence)
    mean = lambda xs: sum(xs) / len(xs)                       # noqa: E731
    assert mean(fitted_conf) > mean(default_conf)
    # the record landed and resolves
    from repro.core import CampaignStore

    loaded = CampaignStore(store)
    loaded.close()
    low, high, prov = resolve_thresholds(loaded)
    assert (low, high, prov) == (4.5, 16.5, "calibrated")
    assert len(loaded.calib[res.hw]["samples"]) == \
        len(REGIMES) * len(CALIB_MODES)
    # replay: same fit, zero new measurements
    res2 = run_calibration(store, reps=2)
    assert (res2.low, res2.high) == (res.low, res.high)
    assert res2.stats.measured == 0
    assert res2.stats.cached > 0

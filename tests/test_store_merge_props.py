"""Property tests for campaign store merge semantics (hypothesis, optional
per the PR 1 policy: without hypothesis these skip, the module still loads).

Pinned properties:
  * merge is idempotent — re-merging a merged store is a byte-level no-op;
  * merge is order-independent for stores with disjoint keys;
  * later records supersede earlier ones for the same key (within a store
    by line order, across stores by source order);
  * when metas agree, merge(a, b)'s replay view equals the union of the
    two stores' replay views (b winning point collisions);
  * LAYOUT EQUIVALENCE — the same record stream written as a legacy single
    file and as a segmented store (arbitrary session splits, optional torn
    tail, meta conflicts included) flattens to byte-identical canonical
    output through ``merge_stores(..., incremental=False)``;
  * QUALITY EVIDENCE — measurement-quality records ride every property
    above (same (region, mode, k) last-wins supersede as points), a meta
    conflict discards them with the rest of the pair's measured evidence,
    and ``compact_store`` preserves the quality view in both layouts;
  * CALIB RECORDS — fitted-threshold records supersede last-wins by
    hardware key, survive merge and compaction in both layouts, and are
    NOT settings-scoped (a meta conflict never drops them).
"""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:   # property tests skip; the rest still runs
    from conftest import hypothesis_stub as hypothesis
    from conftest import strategies_stub as st

import os
import tempfile

from repro.core import CampaignStore, merge_stores

REGIONS = ("rA", "rB")
MODES = ("m1", "m2")

point = st.fixed_dictionaries({
    "kind": st.just("point"),
    "region": st.sampled_from(REGIONS),
    "mode": st.sampled_from(MODES),
    "k": st.integers(0, 6),
    "t": st.floats(1e-4, 1.0, allow_nan=False, allow_infinity=False),
})
sens = st.fixed_dictionaries({
    "kind": st.just("sens"),
    "region": st.sampled_from(REGIONS),
    "mode": st.sampled_from(MODES),
    "value": st.floats(0.1, 10.0, allow_nan=False, allow_infinity=False),
})
quality = st.fixed_dictionaries({
    "kind": st.just("quality"),
    "region": st.sampled_from(REGIONS),
    "mode": st.sampled_from(MODES),
    "k": st.integers(0, 6),
    "verdict": st.sampled_from(["valid", "quarantine"]),
    "reason": st.sampled_from([None, "timer_floor", "spread", "drift_span",
                               "timeout"]),
    "spread": st.one_of(st.none(), st.floats(0.0, 2.0, allow_nan=False,
                                             allow_infinity=False)),
    "reps": st.sampled_from([2, 5]),
    "detail": st.just(None),
})
calib = st.fixed_dictionaries({
    "kind": st.just("calib"),
    "hw": st.sampled_from(["cpu", "tpu"]),
    "low": st.floats(0.5, 8.0, allow_nan=False, allow_infinity=False),
    "high": st.floats(8.5, 64.0, allow_nan=False, allow_infinity=False),
    "fitted": st.booleans(),
    "reps": st.sampled_from([2, 5]),
    "samples": st.just([]),
})
records = st.lists(st.one_of(point, sens, quality, calib), max_size=24)


def _write(path, recs):
    store = CampaignStore(path)
    for rec in recs:
        store.append(rec)
    store.close()


def _load(path):
    store = CampaignStore(path)
    store.close()
    return store


@hypothesis.given(records, records)
@hypothesis.settings(max_examples=40, deadline=None)
def test_merge_idempotent(recs_a, recs_b):
    with tempfile.TemporaryDirectory() as d:
        a, b = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        _write(a, recs_a)
        _write(b, recs_b)
        m1, m2 = os.path.join(d, "m1.jsonl"), os.path.join(d, "m2.jsonl")
        merge_stores(m1, [a, b])
        merge_stores(m2, [m1])
        assert open(m1).read() == open(m2).read()
        merge_stores(m1, [m1, m1])      # self-merge in place: still a no-op
        assert open(m1).read() == open(m2).read()


@hypothesis.given(records, records)
@hypothesis.settings(max_examples=40, deadline=None)
def test_merge_order_independent_for_disjoint_stores(recs_a, recs_b):
    # force key-disjointness: each store only ever sees its own region
    # (and, for hw-keyed calib records, its own hardware)
    recs_a = [dict(r, hw="cpu") if r["kind"] == "calib"
              else dict(r, region="rA") for r in recs_a]
    recs_b = [dict(r, hw="tpu") if r["kind"] == "calib"
              else dict(r, region="rB") for r in recs_b]
    with tempfile.TemporaryDirectory() as d:
        a, b = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        _write(a, recs_a)
        _write(b, recs_b)
        ab, ba = os.path.join(d, "ab.jsonl"), os.path.join(d, "ba.jsonl")
        merge_stores(ab, [a, b])
        merge_stores(ba, [b, a])
        assert open(ab).read() == open(ba).read()


@hypothesis.given(records)
@hypothesis.settings(max_examples=40, deadline=None)
def test_later_records_supersede_within_a_store(recs):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.jsonl")
        _write(path, recs)
        store = _load(path)
        # the in-memory view must equal a left-to-right last-wins fold
        want_points, want_sens, want_quality, want_calib = {}, {}, {}, {}
        for rec in recs:
            if rec["kind"] == "calib":      # keyed by hardware, not pair
                want_calib[rec["hw"]] = rec
                continue
            key = (rec["region"], rec["mode"])
            if rec["kind"] == "point":
                want_points.setdefault(key, {})[rec["k"]] = rec["t"]
            elif rec["kind"] == "quality":
                want_quality.setdefault(key, {})[rec["k"]] = rec
            else:
                want_sens[key] = rec["value"]
        assert store.points == want_points
        assert store.sens == want_sens
        assert store.quality == want_quality
        assert store.calib == want_calib


meta = st.fixed_dictionaries({
    "kind": st.just("meta"),
    "region": st.sampled_from(REGIONS),
    "mode": st.sampled_from(MODES),
    "reps": st.sampled_from([2, 3]),      # two settings -> real conflicts
    "compile_once": st.just(True),
})
mixed_records = st.lists(st.one_of(point, sens, meta, quality, calib),
                         max_size=24)


@hypothesis.given(mixed_records, st.lists(st.integers(0, 24), max_size=3),
                  st.booleans())
@hypothesis.settings(max_examples=40, deadline=None)
def test_segmented_flatten_matches_legacy_store(recs, cuts, torn):
    """The segmented layout is INVISIBLE to merge semantics: the same
    record stream, split across writer sessions at arbitrary cut points
    (one sealed segment each) and optionally finished with the same torn
    partial tail, flattens to the byte-identical canonical store."""
    from repro.core import segments_dir

    with tempfile.TemporaryDirectory() as d:
        legacy = os.path.join(d, "legacy.jsonl")
        _write(legacy, recs)
        seg = os.path.join(d, "seg.jsonl")
        prev = 0
        for cut in sorted({min(c, len(recs)) for c in cuts} | {len(recs)}):
            store = CampaignStore(seg, segmented=True)
            for rec in recs[prev:cut]:
                store.append(rec)
            store.close()
            prev = cut
        if torn:
            partial = b'{"kind": "point", "region": "rA", "mo'
            with open(legacy, "ab") as f:
                f.write(partial)
            # the same torn bytes as an unsealed orphan segment (a sealed
            # segment is immutable; only orphans can carry a torn tail)
            with open(os.path.join(segments_dir(seg),
                                   "999999-torn.jsonl"), "wb") as f:
                f.write(partial)
        flat_l = os.path.join(d, "flat_legacy.jsonl")
        flat_s = os.path.join(d, "flat_seg.jsonl")
        merge_stores(flat_l, [legacy], incremental=False)
        merge_stores(flat_s, [seg], incremental=False)
        assert open(flat_l).read() == open(flat_s).read()


@hypothesis.given(records, records)
@hypothesis.settings(max_examples=40, deadline=None)
def test_merge_replay_is_union_when_metas_agree(recs_a, recs_b):
    with tempfile.TemporaryDirectory() as d:
        a, b = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        _write(a, recs_a)
        _write(b, recs_b)
        m = os.path.join(d, "m.jsonl")
        stats = merge_stores(m, [a, b])
        assert not stats.conflicts          # no metas at all -> no conflicts
        merged = _load(m)
        va, vb = _load(a), _load(b)
        want, want_q = {}, {}
        for src in (va, vb):                # b streams later: b wins ties
            for key, per_k in src.points.items():
                want.setdefault(key, {}).update(per_k)
            for key, per_k in src.quality.items():
                want_q.setdefault(key, {}).update(per_k)
        assert merged.points == want
        assert merged.sens == {**va.sens, **vb.sens}
        assert merged.quality == want_q
        assert merged.calib == {**va.calib, **vb.calib}


@hypothesis.given(st.lists(quality, min_size=1, max_size=12))
@hypothesis.settings(max_examples=40, deadline=None)
def test_meta_conflict_discards_quality_evidence(qrecs):
    """Quality records are settings-scoped: a meta conflict that drops a
    pair's points must drop its quality evidence too, both across stores
    (merge) and within one store's append order."""
    qrecs = [dict(r, region="rA", mode="m1") for r in qrecs]
    meta2 = {"kind": "meta", "region": "rA", "mode": "m1", "reps": 2,
             "compile_once": True}
    meta3 = dict(meta2, reps=3)
    with tempfile.TemporaryDirectory() as d:
        a, b = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        _write(a, [meta2] + qrecs)
        _write(b, [meta3])
        m = os.path.join(d, "m.jsonl")
        stats = merge_stores(m, [a, b])
        assert stats.conflicts == [("rA", "m1")]
        assert _load(m).quality == {}
        c = os.path.join(d, "c.jsonl")
        _write(c, [meta2] + qrecs + [meta3])
        assert _load(c).quality == {}


@hypothesis.given(st.lists(calib, min_size=1, max_size=8), st.booleans())
@hypothesis.settings(max_examples=40, deadline=None)
def test_calib_records_are_not_settings_scoped(crecs, conflict):
    """Calibrated thresholds are per-hardware, not per-measurement-settings:
    a meta conflict that discards a pair's points/quality must leave the
    calib view untouched, and the last record per hw wins."""
    meta2 = {"kind": "meta", "region": "rA", "mode": "m1", "reps": 2,
             "compile_once": True}
    metas = [meta2] + ([dict(meta2, reps=3)] if conflict else [])
    want = {}
    for rec in crecs:
        want[rec["hw"]] = rec
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.jsonl")
        _write(path, [metas[0]] + crecs + metas[1:])
        assert _load(path).calib == want
        m = os.path.join(d, "m.jsonl")
        merge_stores(m, [path])
        assert _load(m).calib == want


@hypothesis.given(mixed_records, st.booleans())
@hypothesis.settings(max_examples=40, deadline=None)
def test_compaction_preserves_the_quality_view(recs, segmented):
    """``compact_store`` drops superseded lines, never surviving evidence:
    the points/sens/quality views are identical before and after, in both
    the legacy and the segmented layout."""
    from repro.core import compact_store

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.jsonl")
        store = CampaignStore(path, segmented=segmented)
        for rec in recs:
            store.append(rec)
        store.close()
        before = _load(path)
        compact_store(path)
        after = _load(path)
        assert after.points == before.points
        assert after.sens == before.sens
        assert after.quality == before.quality
        assert after.calib == before.calib

"""Serving engine (continuous batching correctness) and logical-axis
sharding resolution rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_smoke_config
from repro.models.model import build
from repro.parallel.sharding import resolve
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("deepseek_coder_33b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def _greedy_reference(api, params, prompt, n_new, max_seq=64):
    """Step-by-step greedy decode, single request, no engine."""
    from repro.models import transformer as tf
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = tf.lm_prefill(params, api.cfg, {"tokens": tokens}, max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < n_new:
        lg, cache = api.decode_step(params, cache,
                                    jnp.asarray([[out[-1]]], jnp.int32),
                                    jnp.full((1,), pos, jnp.int32))
        out.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return out


def test_engine_matches_reference(dense):
    api, params = dense
    prompt = [3, 1, 4, 1, 5]
    want = _greedy_reference(api, params, prompt, 6)
    eng = ServeEngine(api, params, n_slots=1, max_seq=64)
    r = eng.submit(prompt, max_new=6)
    eng.run()
    assert r.done and r.out == want


def test_continuous_batching_isolation(dense):
    """Results are identical whether requests share the batch or not."""
    api, params = dense
    prompts = [[5, 6, 7], [1, 2], [9, 8, 7, 6], [4, 4]]
    solo = []
    for p in prompts:
        eng = ServeEngine(api, params, n_slots=1, max_seq=64)
        r = eng.submit(p, max_new=5)
        eng.run()
        solo.append(r.out)
    eng = ServeEngine(api, params, n_slots=2, max_seq=64)
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.run()
    for r, want in zip(reqs, solo):
        assert r.done and r.out == want, (r.out, want)


def test_engine_ssm_fallback():
    cfg = get_smoke_config("mamba2_780m")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, n_slots=2, max_seq=32)
    r1 = eng.submit([1, 2, 3], max_new=4)
    r2 = eng.submit([4, 5], max_new=4)
    eng.run()
    assert r1.done and r2.done and len(r1.out) == 4


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    # abstract meshes are enough for resolution tests
    return compat.abstract_mesh((16, 16), ("data", "model"))


def test_resolve_basic(mesh):
    assert resolve(("batch", None, None), (256, 4096, 2048), mesh) == \
        P("data")
    assert resolve(("fsdp", "ff"), (2048, 16384), mesh) == P("data", "model")
    assert resolve((None, "vocab"), (2048, 32768), mesh) == P(None, "model")


def test_resolve_divisibility_fallback(mesh):
    # MQA: 1 kv head cannot shard over model=16 -> replicated
    assert resolve(("fsdp", "kv_heads", None), (2048, 1, 256), mesh) == \
        P("data")
    # mixtral: 8 experts cannot shard over 16; expert_ff picks model up
    assert resolve(("experts", "fsdp", "expert_ff"), (8, 6144, 16384),
                   mesh) == P(None, "data", "model")
    # qwen3: 128 experts shard fine; expert_ff then replicated (model used)
    assert resolve(("experts", "fsdp", "expert_ff"), (128, 2048, 768),
                   mesh) == P("model", "data")


def test_resolve_batch_prefix(mesh3d=None):
    mesh3 = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    # batch 256 shards over pod*data=32
    assert resolve(("batch", None), (256, 4096), mesh3) == P(("pod", "data"))
    # batch 1 (long_500k) cannot shard -> replicated
    assert resolve(("batch", None), (1, 4096), mesh3) == P()
    # batch 2 shards over pod only (prefix)
    assert resolve(("batch", None), (2, 4096), mesh3) == P(("pod",))


def test_resolve_no_double_use(mesh):
    # one mesh axis never backs two tensor dims
    spec = resolve(("heads", "ff"), (48, 16384), mesh)
    assert spec == P("model", None) or spec == P("model")

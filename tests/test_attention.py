"""Attention equivalences: blocked vs naive, prefill vs incremental decode,
scalar vs vector positions, ring cache."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn
from repro.models import transformer as tf


def naive_attention(q, k, v, causal=True, window=0):
    """O(S^2)-materializing reference."""
    H, KH = q.shape[1], k.shape[1]
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=1)
        v = jnp.repeat(v, H // KH, axis=1)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    Sq, Sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    keep = jnp.ones((Sq, Sk), bool)
    if causal:
        keep &= qpos >= kpos
    if window:
        keep &= qpos - kpos < window
    s = jnp.where(keep[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("gemma_2b").scaled(n_kv_heads=2, window=0)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("q_block", [8, 16, 64])
def test_blocked_sdpa_matches_naive(cfg, window, q_block):
    c = cfg.scaled(window=window)
    p = attn.init_attention(jax.random.PRNGKey(1), c)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, c.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    positions = jnp.arange(64, dtype=jnp.int32)
    out = attn.attn_train(p, c, x, positions, causal=True, window=window,
                          q_block=q_block)
    # reference through the same projections
    q, k, v = attn._project_qkv(p, c, x, positions)
    ref = naive_attention(q, k, v, causal=True, window=window)
    ref = attn._out_proj(p, c, ref.astype(x.dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_prefill_matches_incremental_decode(cfg):
    """Decode one token at a time == full-sequence forward (dense LM)."""
    c = cfg
    params = tf.init_lm(jax.random.PRNGKey(0), c)
    S, B = 12, 2
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                c.vocab_size, dtype=jnp.int32)
    full_logits, _ = tf.lm_forward(params, c, {"tokens": tokens})

    cache = tf.lm_decode_init(params, c, B, max_seq=32)
    dec = []
    for t in range(S):
        lg, cache = tf.lm_decode_step(params, c, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        dec.append(lg[:, 0])
    dec = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_lm_prefill_cache_matches_decode(cfg):
    """lm_prefill's padded cache continues identically to step-by-step."""
    c = cfg
    params = tf.init_lm(jax.random.PRNGKey(0), c)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                c.vocab_size, dtype=jnp.int32)
    logits_pre, cache_pre = tf.lm_prefill(params, c, {"tokens": tokens}, 32)

    cache = tf.lm_decode_init(params, c, B, max_seq=32)
    for t in range(S):
        lg, cache = tf.lm_decode_step(params, c, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1], np.float32),
                               np.asarray(lg[:, 0], np.float32), rtol=4e-2,
                               atol=4e-2)
    nxt = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
    lg_a, _ = tf.lm_decode_step(params, c, cache, nxt, jnp.int32(S))
    lg_b, _ = tf.lm_decode_step(params, c, cache_pre, nxt, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lg_a, np.float32),
                               np.asarray(lg_b, np.float32), rtol=4e-2,
                               atol=4e-2)


def test_vector_pos_matches_scalar(cfg):
    c = cfg
    p = attn.init_attention(jax.random.PRNGKey(1), c)
    B = 3
    cache = attn.init_cache(c, B, 16)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 1, c.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out_s, cache_s = attn.attn_decode(p, c, x, cache, jnp.int32(4))
    out_v, cache_v = attn.attn_decode(p, c, x, cache,
                                      jnp.full((B,), 4, jnp.int32))
    np.testing.assert_allclose(np.asarray(out_s, np.float32),
                               np.asarray(out_v, np.float32), rtol=2e-2,
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(cache_s["k"], np.float32),
                               np.asarray(cache_v["k"], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ring_cache_sliding_window(cfg):
    """Ring cache decode == full cache decode when window masks the past."""
    W = 4
    c = cfg.scaled(window=W)
    p = attn.init_attention(jax.random.PRNGKey(1), c)
    B, S = 2, 10
    xs = jax.random.normal(jax.random.PRNGKey(6), (B, S, c.d_model),
                           jnp.float32).astype(jnp.bfloat16)
    ring = attn.init_cache(c, B, 64)             # ring of size W
    assert ring["k"].shape[2] == W and "kpos" in ring
    full = attn.init_cache(c, B, 64, window=0)   # full cache, masked by cfg
    for t in range(S):
        o_r, ring = attn.attn_decode(p, c, xs[:, t:t + 1], ring, jnp.int32(t))
        o_f, full = attn.attn_decode(p, c, xs[:, t:t + 1], full, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(o_r, np.float32),
                                   np.asarray(o_f, np.float32), rtol=3e-2,
                                   atol=3e-2, err_msg=f"t={t}")

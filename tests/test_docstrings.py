"""Public-API docstring enforcement (the paper-to-code documentation suite's
tier-1 guard): every public function/class — and every public method a
public class defines itself — in the documented API surface carries a
docstring, and every CLI option of the probe/fleet parsers has help text.

"Public" = not underscore-prefixed and actually defined in the module under
test (re-exports are checked where they are defined)."""
import argparse
import importlib
import inspect

import pytest

PUBLIC_MODULES = [
    "repro.fleet",
    "repro.fleet.plan",
    "repro.fleet.executor",
    "repro.fleet.launchers",
    "repro.fleet.cli",
    "repro.core.campaign",
    "repro.core.calibration",
    "repro.core.strategy",
    "repro.kernels.region",
    "repro.launch.probe",
]


def _public_symbols(mod):
    for name in dir(mod):
        if name.startswith("_"):
            continue
        obj = getattr(mod, name)
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue          # re-export; checked where it is defined
        yield name, obj


def _public_methods(cls):
    for name, obj in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(obj, (staticmethod, classmethod)):
            obj = obj.__func__
        if isinstance(obj, property):
            yield name, obj.fget
        elif inspect.isfunction(obj):
            yield name, obj


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_public_api_is_documented(modname):
    mod = importlib.import_module(modname)
    assert (mod.__doc__ or "").strip(), f"{modname} has no module docstring"
    missing = []
    for name, obj in _public_symbols(mod):
        if not (obj.__doc__ or "").strip():
            missing.append(f"{modname}.{name}")
        if inspect.isclass(obj):
            for mname, meth in _public_methods(obj):
                if not (getattr(meth, "__doc__", "") or "").strip():
                    missing.append(f"{modname}.{name}.{mname}")
    assert not missing, ("public symbols without a docstring: "
                         + ", ".join(sorted(missing)))


def _actions(parser):
    for action in parser._actions:
        if isinstance(action, argparse._SubParsersAction):
            for sub in action.choices.values():
                yield from _actions(sub)
        elif not isinstance(action, argparse._HelpAction):
            yield action


def test_probe_cli_help_text_is_complete():
    from repro.launch.probe import build_parser

    bare = [a.dest for a in _actions(build_parser()) if not a.help]
    assert not bare, f"probe CLI options without help text: {bare}"


def test_fleet_cli_help_text_is_complete():
    from repro.fleet.cli import build_parser

    bare = [a.dest for a in _actions(build_parser()) if not a.help]
    assert not bare, f"fleet CLI options without help text: {bare}"

#!/usr/bin/env python
"""Documentation checker — every cross-reference in docs/ and README must
resolve, and every quoted command must actually run.

Checks (over README.md + docs/*.md):

  1. relative markdown links ``[text](path)`` point at files that exist;
  2. ``path/to/file.py:123`` references name a real file with >= 123 lines;
  3. backticked repo paths (``src/...``, ``tests/...``, ``benchmarks/...``,
     ``examples/...``, ``tools/...``, ``docs/...``, ``.github/...``) exist;
  4. backticked dotted code references (``repro.fleet.launchers.SSHLauncher``,
     ``repro.kernels.noise_slots.emit_noise_rt``) resolve to a module file
     that really defines the named symbol;
  5. ``python examples/foo.py`` commands name files that byte-compile;
  6. with ``--run-commands`` (the CI docs job): every ``python -m pkg.mod``
     command quoted in a fenced block is executed in ``--help`` form — the
     entry point must exist and its argparse tree must build.

Exit 0 when everything resolves, 1 otherwise (each failure printed).
Run from the repo root:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import argparse
import glob
import os
import py_compile
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PATH_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "tools/",
                 "docs/", ".github/")
MODULE_PREFIXES = ("repro", "benchmarks")

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
FILE_LINE_RE = re.compile(
    r"\b((?:src|tests|benchmarks|examples|tools|docs)/[\w/.-]+?"
    r"\.(?:py|md|yml|json)):(\d+)\b")
BACKTICK_RE = re.compile(r"`([^`\n]+)`")
FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.DOTALL)
CMD_MODULE_RE = re.compile(r"python(?:3)?\s+-m\s+([\w.]+)")
CMD_SCRIPT_RE = re.compile(r"python(?:3)?\s+((?:examples|tools|benchmarks)/"
                           r"[\w/.-]+\.py)")
DOTTED_RE = re.compile(r"^[A-Za-z_][\w]*(?:\.[A-Za-z_][\w]*)+$")


def _exists(rel: str) -> bool:
    return os.path.exists(os.path.join(ROOT, rel))


def check_links(md_path: str, text: str, problems: list[str]) -> None:
    """Rule 1: relative markdown links resolve (anchors/URLs skipped)."""
    base = os.path.dirname(md_path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not (os.path.exists(os.path.join(base, rel)) or _exists(rel)):
            problems.append(f"{md_path}: broken link -> {target}")


def check_file_lines(md_path: str, text: str, problems: list[str]) -> None:
    """Rule 2: ``file.py:123`` references resolve to a long-enough file."""
    for rel, line in FILE_LINE_RE.findall(text):
        full = os.path.join(ROOT, rel)
        if not os.path.exists(full):
            problems.append(f"{md_path}: file:line ref to missing file "
                            f"{rel}:{line}")
            continue
        with open(full, "rb") as f:
            n = sum(1 for _ in f)
        if int(line) > n:
            problems.append(f"{md_path}: {rel}:{line} but the file has only "
                            f"{n} lines")


def _resolve_dotted(token: str) -> str | None:
    """Rule 4 resolver: map ``a.b.c.symbol...`` to a module file and check
    the first symbol after the module is defined there. Returns an error
    string, or None when the token resolves (or is not a code ref)."""
    parts = token.split(".")
    for k in range(len(parts), 0, -1):
        for prefix in ("src", os.path.join("src", "repro"), "."):
            stem = os.path.join(ROOT, prefix, *parts[:k])
            mod_file = None
            if os.path.isfile(stem + ".py"):
                mod_file = stem + ".py"
            elif os.path.isdir(stem):
                init = os.path.join(stem, "__init__.py")
                mod_file = init if os.path.isfile(init) else None
            if mod_file is None:
                continue
            rest = parts[k:]
            if not rest:
                return None                      # a module reference: exists
            sym = rest[0]
            src = open(mod_file).read()
            if re.search(rf"^\s*(?:def|class)\s+{re.escape(sym)}\b|"
                         rf"^{re.escape(sym)}\s*[:=]", src, re.MULTILINE):
                return None
            return (f"dotted ref {token!r}: {os.path.relpath(mod_file, ROOT)}"
                    f" defines no symbol {sym!r}")
    return None        # no module file at any split: not a code reference


def check_backticks(md_path: str, text: str, problems: list[str]) -> None:
    """Rules 3+4: backticked repo paths exist; dotted code refs resolve."""
    for token in BACKTICK_RE.findall(text):
        token = token.strip()
        if any(ch in token for ch in " ()[]{}<>*$\"'=,"):
            continue
        if token.startswith(PATH_PREFIXES):
            rel = token.rstrip("/").split("#")[0].split(":")[0]
            if not _exists(rel):
                problems.append(f"{md_path}: backticked path {token!r} "
                                "does not exist")
        elif DOTTED_RE.match(token) and token.startswith(MODULE_PREFIXES):
            err = _resolve_dotted(token)
            if err:
                problems.append(f"{md_path}: {err}")


def fenced_commands(text: str) -> tuple[set[str], set[str]]:
    """Collect (module commands, script paths) from fenced code blocks."""
    modules: set[str] = set()
    scripts: set[str] = set()
    for block in FENCE_RE.findall(text):
        for mod in CMD_MODULE_RE.findall(block):
            if mod.startswith(MODULE_PREFIXES):
                modules.add(mod)
        for script in CMD_SCRIPT_RE.findall(block):
            scripts.add(script)
    return modules, scripts


def check_scripts(md_path: str, scripts: set[str],
                  problems: list[str]) -> None:
    """Rule 5: quoted ``python <script>.py`` files exist and byte-compile."""
    for rel in sorted(scripts):
        full = os.path.join(ROOT, rel)
        if not os.path.exists(full):
            problems.append(f"{md_path}: quoted script {rel} does not exist")
            continue
        try:
            py_compile.compile(full, doraise=True, cfile=os.devnull)
        except py_compile.PyCompileError as e:
            problems.append(f"{md_path}: quoted script {rel} does not "
                            f"compile: {e}")


def run_commands(modules: set[str], problems: list[str]) -> None:
    """Rule 6: run every quoted ``python -m`` module with ``--help``."""
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    parts = [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join([src] + parts)
    for mod in sorted(modules):
        res = subprocess.run([sys.executable, "-m", mod, "--help"],
                             cwd=ROOT, env=env, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True,
                             timeout=300)
        status = "ok" if res.returncode == 0 else f"rc={res.returncode}"
        print(f"  python -m {mod} --help ... {status}")
        if res.returncode != 0:
            problems.append(f"quoted command `python -m {mod}` fails "
                            f"--help (rc={res.returncode}):\n"
                            + res.stdout[-2000:])


def main(argv=None) -> int:
    """Check every docs/*.md + README.md; exit 1 on any broken reference."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--run-commands", action="store_true",
                    help="also execute every quoted `python -m` command in "
                         "--help form (the CI docs job does)")
    args = ap.parse_args(argv)

    md_files = [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))
    problems: list[str] = []
    all_modules: set[str] = set()
    for path in md_files:
        rel = os.path.relpath(path, ROOT)
        text = open(path).read()
        check_links(rel, text, problems)
        check_file_lines(rel, text, problems)
        check_backticks(rel, text, problems)
        modules, scripts = fenced_commands(text)
        all_modules |= modules
        check_scripts(rel, scripts, problems)
        print(f"checked {rel}: {len(modules)} module command(s), "
              f"{len(scripts)} script(s)")
    if args.run_commands:
        run_commands(all_modules, problems)
    if problems:
        print(f"\n{len(problems)} documentation problem(s):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("\nall documentation references resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

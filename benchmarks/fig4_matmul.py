"""Paper Fig. 4: dense matmul under fp vs L1 noise, naive ("-O0") vs
optimized ("-O3") lowering.

Expected signature (the paper's): the naive version is load/store-clogged —
it absorbs fp noise but degrades immediately under L1 noise; the optimized
version uses the hardware efficiently — a single noise pattern already costs
time (near-zero absorption in every mode).

``--pallas``: additionally run the study on the REAL tiled Pallas matmul
kernel (interpret mode off-TPU) through the campaign spine, and report the
compile-once vs trace-per-k sweep cost (executables built + wall-clock).
"""
from __future__ import annotations

import argparse

from benchmarks.common import banner, characterize, pallas_sweep_ab, save
from repro.bench.kernels import matmul_region
from repro.core import Controller


def run_pallas(quick: bool = True) -> dict:
    """Fig 4's fp-vs-L1 axes on the real Pallas matmul kernel."""
    from repro.kernels.region import pallas_region

    banner("Fig 4 (pallas) — tiled matmul kernel, fp vs vmem noise")
    n = 128 if quick else 256
    ctl = Controller(reps=2 if quick else 3)
    region = pallas_region("matmul", backend="interpret", n=n)
    rep = characterize(ctl, region, ("fp", "vmem"))
    print(rep.summary())
    ks = (0, 1, 2, 4, 8, 16) if quick else (0, 1, 2, 4, 8, 16, 32, 64)
    ab = pallas_sweep_ab("matmul", "fp", ks, reps=2 if quick else 3, n=n)
    return {"region": region.name, "abs": rep.absorptions(),
            "bottleneck": rep.bottleneck.label, "sweep_cost": ab}


def run(quick: bool = True, pallas: bool = False) -> dict:
    banner("Fig 4 — matmul -O0 vs -O3 (absorption flip under optimization)")
    n = 192 if quick else 384
    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    rows = {}
    for opt in (False, True):
        region = matmul_region(n=n, optimized=opt)
        rep = characterize(ctl, region, ("fp_add", "l1_ld"))
        rows[region.name] = {
            "abs": rep.absorptions(),
            "bottleneck": rep.bottleneck.label,
        }
        print(rep.summary())
    o0, o3 = rows["matmul_O0"]["abs"], rows["matmul_O3"]["abs"]
    flip = (o0["fp_add"] > o0["l1_ld"]) and (max(o3.values()) <= 5
                                             or o3["fp_add"] < o0["fp_add"])
    print(f"-O0 absorbs fp ({o0['fp_add']:.0f}) >> l1 ({o0['l1_ld']:.0f}); "
          f"-O3 absorbs ~nothing ({o3}) -> signature flip: {flip}")
    out = {"rows": rows, "signature_flip": bool(flip)}
    if pallas:
        out["pallas"] = run_pallas(quick)
    save("fig4_matmul", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pallas", action="store_true")
    a = ap.parse_args()
    run(quick=not a.full, pallas=a.pallas)

"""Serving benchmark: paged vs dense engine throughput, probe overhead.

Drives the ``quick`` synthetic load mix (``repro.serve.load.MIXES``) through
``ServeEngine`` twice — once on the per-slot dense KV layout, once on the
paged page-pool layout — and reports tokens/sec for both plus the speedup.
The paged engine admits each wave with ONE batched prefill call and keeps
per-tick bookkeeping on-device with a single host sync. The hard gate (exit
nonzero) is on the DETERMINISTIC wins — paged completes every request the
dense engine completes in strictly fewer prefill calls — so shared CI
runners can't flake it; the wall-clock speedup is recorded and only
advisory unless ``--strict`` asks for it (local perf runs).

The probe-overhead section answers "what does wrapping the serve cells in
the noise harness cost when no noise is injected?": the engine's decode tick
is timed clean and wrapped (``repro.core.injector.inject`` at k=0), on the
same operands the ``"serve"`` fleet kind probes.

Writes ``experiments/bench/BENCH_serve.json``. Imports stay lazy so
``python -m benchmarks.bench_serve --help`` works without JAX.
"""
from __future__ import annotations

import argparse
import statistics

from benchmarks.common import banner, save, timer

DEFAULT_ARCH = "gemma_2b"


def _time_fn(fn, args, *, reps: int) -> float:
    """Median wall-clock of ``fn(*args)`` after one warmup/compile call."""
    import jax

    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        with timer() as t:
            jax.block_until_ready(fn(*args))
        ts.append(t.dt)
    return statistics.median(ts)


def _load_mix(arch: str, mix: str, *, paged: bool, slots: int,
              max_seq: int, seed: int) -> dict:
    """One load-harness run; returns the engine report + latency stats."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models.model import build
    from repro.serve import MIXES, ServeEngine
    from repro.serve.load import run_load, sample_requests

    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, n_slots=slots, max_seq=max_seq,
                      paged=paged, seed=seed)
    spec = MIXES[mix]
    rep = run_load(eng, spec)
    rep["n_requests"] = len(sample_requests(spec, cfg.vocab_size, max_seq))
    return rep


def bench_throughput(arch: str, mix: str, *, slots: int, max_seq: int,
                     seed: int) -> dict:
    """Paged vs dense tokens/sec on the same request stream."""
    out: dict = {"mix": mix, "slots": slots, "max_seq": max_seq}
    for layout, paged in (("dense", False), ("paged", True)):
        rep = _load_mix(arch, mix, paged=paged, slots=slots,
                        max_seq=max_seq, seed=seed)
        out[layout] = {
            "total_tok_s": round(rep["total_tok_s"], 1),
            "decode_tok_s": round(rep["decode_tok_s"], 1),
            "prefill_calls": rep["prefill_calls"],
            "ticks": rep["ticks"],
            "wall_s": round(rep["wall_s"], 3),
            "requests_done": rep["requests_done"],
            "latency_ticks_p50": rep["latency_ticks_p50"],
            "latency_ticks_p95": rep["latency_ticks_p95"],
        }
        if paged:
            out[layout]["mean_pool_occupancy"] = round(
                rep["mean_pool_occupancy"], 3)
        print(f"  [{layout:5s} {out[layout]['requests_done']} request(s): "
              f"{out[layout]['total_tok_s']:.1f} tok/s total, "
              f"{out[layout]['prefill_calls']} prefill call(s), "
              f"{out[layout]['ticks']} tick(s)]")
    out["speedup"] = round(out["paged"]["total_tok_s"]
                           / max(out["dense"]["total_tok_s"], 1e-9), 2)
    print(f"  paged/dense speedup: {out['speedup']:.2f}x")
    return out


def bench_probe_overhead(arch: str, *, slots: int, prompt: int,
                         reps: int) -> dict:
    """Clean vs noise-wrapped (k=0) timings of the serve prefill/tick cells
    — the fixed cost the ``"serve"`` probe harness adds before any noise."""
    import jax

    from repro.core.injector import inject
    from repro.core.noise import NoiseScale, make_modes
    from repro.serve.load import _build_engine_for_probe

    mode = make_modes(NoiseScale(hbm_mib=32, chase_len=1 << 20))["fp_add32"]
    state = mode.make_state(jax.random.PRNGKey(0))
    eng = _build_engine_for_probe(arch, slots=slots, prompt=prompt,
                                  max_new=8, page_size=16)
    prefill_fn, prefill_args, tick_fn, tick_args = eng.probe_cells()
    out: dict = {}
    for name, fn, args in (("prefill", prefill_fn, prefill_args),
                           ("decode_tick", tick_fn, tick_args)):
        t_clean = _time_fn(jax.jit(fn), args, reps=reps)
        t_wrapped = _time_fn(jax.jit(inject(fn, mode, 0)), (state, *args),
                             reps=reps)
        out[name] = {"clean_ms": round(t_clean * 1e3, 4),
                     "wrapped_k0_ms": round(t_wrapped * 1e3, 4),
                     "overhead_pct": round(
                         100.0 * (t_wrapped - t_clean) / max(t_clean, 1e-9),
                         1)}
        print(f"  [{name}: clean {out[name]['clean_ms']:.3f}ms vs wrapped "
              f"k=0 {out[name]['wrapped_k0_ms']:.3f}ms "
              f"({out[name]['overhead_pct']:+.1f}%)]")
    return out


def run(arch: str = DEFAULT_ARCH, *, quick: bool = True,
        strict: bool = False) -> dict:
    banner(f"serve benchmark — paged vs dense on {arch}")
    mix = "quick" if quick else "chat"
    slots, max_seq = (4, 64) if quick else (8, 256)
    out = {"arch": arch, "quick": quick,
           "throughput": bench_throughput(arch, mix, slots=slots,
                                          max_seq=max_seq, seed=0),
           "probe_overhead": bench_probe_overhead(
               arch, slots=2, prompt=16, reps=5 if quick else 20)}
    th = out["throughput"]
    # deterministic gate: batched admission must shrink the prefill-call
    # count without dropping requests — machine-load-independent, so it
    # can't flake on shared CI runners the way wall clock can
    if th["paged"]["requests_done"] < th["dense"]["requests_done"]:
        raise SystemExit(
            "bench_serve: paged engine completed fewer requests than dense "
            f"on the {mix!r} mix: {th['paged']['requests_done']} < "
            f"{th['dense']['requests_done']}")
    if th["paged"]["prefill_calls"] >= th["dense"]["prefill_calls"]:
        raise SystemExit(
            "bench_serve: paged admission did not batch prefills on the "
            f"{mix!r} mix: {th['paged']['prefill_calls']} call(s) vs dense "
            f"{th['dense']['prefill_calls']}")
    if th["speedup"] < 1.0:
        msg = (f"bench_serve: paged wall-clock throughput below dense on "
               f"the {mix!r} mix: {th['speedup']:.2f}x")
        if strict:
            raise SystemExit(msg)
        print(f"  WARNING (advisory): {msg}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_serve",
        description="serving-engine benchmark: paged vs dense tokens/sec on "
                    "a synthetic load mix, probe wrapper overhead at k=0 "
                    "-> experiments/bench/BENCH_serve.json")
    ap.add_argument("--arch", default=DEFAULT_ARCH)
    ap.add_argument("--quick", action="store_true",
                    help="small mix / few reps (the CI serve-smoke "
                         "configuration; also the default)")
    ap.add_argument("--full", action="store_true",
                    help="chat mix, more slots, longer sequences")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on a wall-clock speedup < 1.0 (off by "
                         "default: wall clock flakes on shared runners; the "
                         "prefill-call/requests-done gate always applies)")
    args = ap.parse_args(argv)
    out = run(args.arch, quick=not args.full, strict=args.strict)
    save("BENCH_serve", out)
    print("wrote experiments/bench/BENCH_serve.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

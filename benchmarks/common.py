"""Shared harness bits for the per-paper-table benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Any

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

# When set (benchmarks.run sets it by default), every characterization sweep
# becomes a resumable campaign: measured points persist under this directory
# and a re-run only measures what the store is missing.
CAMPAIGN_DIR_VAR = "REPRO_CAMPAIGN_DIR"


def characterize(ctl, region, modes) -> Any:
    """``Controller.characterize`` through the campaign engine when a store
    directory is configured, plain (non-persistent) otherwise."""
    campaign_dir = os.environ.get(CAMPAIGN_DIR_VAR, "")
    if not campaign_dir:
        return ctl.characterize(region, modes=modes)
    from repro.core import Campaign

    camp = Campaign(os.path.join(campaign_dir, f"{region.name}.jsonl"), ctl)
    rep = camp.characterize(region, modes)
    if camp.stats.cached:
        print(f"  [{region.name}: {camp.stats.cached} points from store, "
              f"{camp.stats.measured} measured]")
    return rep


def run_decan_stored(target, *, reps: int, inner: int = 1) -> Any:
    """``run_decan`` through the campaign store when a store directory is
    configured — DECAN variant timings land in the SAME per-region file as
    the noise sweeps, and a re-run replays them instead of remeasuring."""
    from repro.core import CampaignStats, CampaignStore
    from repro.core.decan import run_decan

    campaign_dir = os.environ.get(CAMPAIGN_DIR_VAR, "")
    if not campaign_dir:
        return run_decan(target, reps=reps, inner=inner)
    store = CampaignStore(os.path.join(campaign_dir, f"{target.name}.jsonl"))
    stats = CampaignStats()
    try:
        res = run_decan(target, reps=reps, inner=inner, store=store,
                        stats=stats)
    finally:
        store.close()
    if stats.cached:
        print(f"  [{target.name}: {stats.cached} DECAN variant(s) from "
              f"store, {stats.measured} measured]")
    return res


def save(name: str, payload: Any) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def banner(title: str) -> None:
    print(f"\n=== {title} {'=' * max(0, 66 - len(title))}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

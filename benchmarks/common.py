"""Shared harness bits for the per-paper-table benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Any

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def save(name: str, payload: Any) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def banner(title: str) -> None:
    print(f"\n=== {title} {'=' * max(0, 66 - len(title))}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

"""Shared harness bits for the per-paper-table benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Any

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

# When set (benchmarks.run sets it by default), every characterization sweep
# becomes a resumable campaign: measured points persist under this directory
# and a re-run only measures what the store is missing.
CAMPAIGN_DIR_VAR = "REPRO_CAMPAIGN_DIR"


def characterize(ctl, region, modes) -> Any:
    """``Controller.characterize`` through the fleet executor's store-backed
    spine when a store directory is configured (the same code path fleet
    finalize runs), plain (non-persistent) otherwise."""
    campaign_dir = os.environ.get(CAMPAIGN_DIR_VAR, "")
    if not campaign_dir:
        return ctl.characterize(region, modes=modes)
    from repro.fleet.executor import characterize_region

    return characterize_region(
        region, modes, controller=ctl,
        store=os.path.join(campaign_dir, f"{region.name}.jsonl"))


def run_decan_stored(target, *, reps: int, inner: int = 1) -> Any:
    """``run_decan`` through the campaign store when a store directory is
    configured — DECAN variant timings land in the SAME per-region file as
    the noise sweeps, and a re-run replays them instead of remeasuring."""
    from repro.core import CampaignStats, CampaignStore
    from repro.core.decan import run_decan

    campaign_dir = os.environ.get(CAMPAIGN_DIR_VAR, "")
    if not campaign_dir:
        return run_decan(target, reps=reps, inner=inner)
    store = CampaignStore(os.path.join(campaign_dir, f"{target.name}.jsonl"))
    stats = CampaignStats()
    try:
        res = run_decan(target, reps=reps, inner=inner, store=store,
                        stats=stats)
    finally:
        store.close()
    if stats.cached:
        print(f"  [{target.name}: {stats.cached} DECAN variant(s) from "
              f"store, {stats.measured} measured]")
    return res


def pallas_sweep_ab(kernel: str, mode: str, ks, *, reps: int = 2,
                    **sizes) -> dict:
    """Wall-clock one (kernel, mode) k-sweep on the compile-once runtime-k
    path vs the trace-per-k fallback (the paper's cost model), counting the
    Pallas executables each path builds. The acceptance numbers for the
    fig4/fig7 ``--pallas`` studies."""
    from repro.core import Controller
    from repro.kernels.region import pallas_region

    out: dict = {}
    for path, compile_once in (("compile_once", True), ("trace_per_k", False)):
        traces = {"n": 0}
        region = pallas_region(
            kernel, backend="interpret",
            trace_hook=lambda: traces.__setitem__("n", traces["n"] + 1),
            **sizes)
        ctl = Controller(reps=reps, compile_once=compile_once,
                         verify_payload=False, stop_ratio=100.0)
        with timer() as t:
            ctl.run_mode(region, mode, ks=ks)
        out[path] = {"seconds": round(t.dt, 3), "executables": traces["n"]}
    out["speedup"] = round(out["trace_per_k"]["seconds"]
                           / max(out["compile_once"]["seconds"], 1e-9), 2)
    print(f"  [{kernel}/{mode} sweep over {len(list(ks))} ks: compile-once "
          f"{out['compile_once']['executables']} executable(s) in "
          f"{out['compile_once']['seconds']:.2f}s vs trace-per-k "
          f"{out['trace_per_k']['executables']} in "
          f"{out['trace_per_k']['seconds']:.2f}s -> {out['speedup']:.1f}x]")
    return out


def save(name: str, payload: Any) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def banner(title: str) -> None:
    print(f"\n=== {title} {'=' * max(0, 66 - len(title))}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

"""Store-layer benchmark: append throughput, merge scaling, compaction.

Purely synthetic campaign records (no JAX, no kernels) drive the two store
layouts through their hot paths:

  * append — points/sec for the legacy single-JSONL layout vs the segmented
    (segment + manifest) layout;
  * incremental merge — fold ONE new worker segment into a canonical store
    already holding N segments, for growing N. Wall-clock AND the exact
    bytes/records parsed (``repro.core.segments.io_tally``) must stay flat
    in N: the O(new segment) contract. The legacy full canonical rewrite is
    measured alongside as the O(store) contrast;
  * compaction — records/bytes before vs after ``compact_store`` folds a
    supersede-heavy stream (every pair re-measured ``REMEASURES`` times).

Writes ``experiments/bench/BENCH_store.json``. Imports stay lazy so
``python -m benchmarks.bench_store --help`` works on a box without JAX;
the benchmark itself needs only the stdlib and ``repro.core.campaign``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile

from benchmarks.common import banner, save, timer

REMEASURES = 3          # meta-conflict re-measures per pair in the
                        # compaction stream (each discards the previous)


def synth_records(pair_count: int, points: int, *, rep_tag: int = 0):
    """One synthetic campaign stream: meta + ``points`` points + done per
    (region, mode) pair. ``rep_tag`` varies the meta settings, so replaying
    two tags for the same pairs exercises the meta-conflict discard path."""
    for p in range(pair_count):
        region, mode = f"r{p:03d}", "fp"
        yield {"kind": "meta", "region": region, "mode": mode,
               "reps": 2 + rep_tag, "compile_once": True}
        for k in range(points):
            yield {"kind": "point", "region": region, "mode": mode,
                   "k": k, "t": 1e-3 * (k + 1)}
        yield {"kind": "done", "region": region, "mode": mode,
               "ks": list(range(points)), "drift": None,
               "stopped_early": False, "payload": None}


def _fill(store, records) -> int:
    n = 0
    for rec in records:
        store.append(rec)
        n += 1
    return n


def bench_append(tmp: str, *, pairs: int, points: int) -> dict:
    """Append the same synthetic stream to both layouts; report points/sec."""
    from repro.core.campaign import CampaignStore

    out: dict = {"records": pairs * (points + 2)}
    for layout, seg in (("legacy", False), ("segmented", True)):
        path = os.path.join(tmp, f"append_{layout}.jsonl")
        store = CampaignStore(path, segmented=seg)
        with timer() as t:
            n = _fill(store, synth_records(pairs, points))
            store.close()
        out[layout] = {"seconds": round(t.dt, 4),
                       "records_per_s": round(n / max(t.dt, 1e-9))}
    print(f"  [append {out['records']} record(s): legacy "
          f"{out['legacy']['records_per_s']}/s vs segmented "
          f"{out['segmented']['records_per_s']}/s]")
    return out


def _grown_store(tmp: str, name: str, segments: int, *, pairs: int,
                 points: int, segmented: bool) -> str:
    """A canonical store holding ``segments`` writer sessions' worth of
    records (one sealed segment per session when ``segmented``)."""
    from repro.core.campaign import CampaignStore

    path = os.path.join(tmp, f"{name}.jsonl")
    for s in range(segments):
        store = CampaignStore(path, segmented=segmented or None)
        base = s * pairs
        _fill(store, ({**rec, "region": f"r{base + int(rec['region'][1:]):03d}"}
                      for rec in synth_records(pairs, points)))
        store.close()
    return path


def bench_merge(tmp: str, *, segment_counts, pairs: int, points: int) -> dict:
    """Merge-one-new-worker latency and I/O vs canonical store size, for the
    incremental (segment adoption) and legacy (full canonical rewrite)
    paths. The incremental rows' read_bytes/read_records must not grow with
    ``segments_before`` — that flatness IS the benchmark's headline."""
    from repro.core.campaign import CampaignStore, merge_stores
    from repro.core.segments import io_tally

    out = {"incremental": [], "full_rewrite": []}
    for n in segment_counts:
        for mode, seg in (("incremental", True), ("full_rewrite", False)):
            dest = _grown_store(tmp, f"canon_{mode}_{n}", n, pairs=pairs,
                                points=points, segmented=seg)
            worker = os.path.join(tmp, f"worker_{mode}_{n}.jsonl")
            ws = CampaignStore(worker, segmented=seg or None)
            _fill(ws, ({**rec, "region": "w" + rec["region"]}
                       for rec in synth_records(pairs, points)))
            ws.close()
            # dest rides along as its own first source (run_fleet's shape);
            # the incremental path skips it without reading a byte, the
            # legacy path re-reads and rewrites the whole canonical store
            io_tally(reset=True)
            with timer() as t:
                stats = merge_stores(dest, [dest, worker])
            tally = io_tally()
            row = {"segments_before": n,
                   "records_before": n * pairs * (points + 2),
                   "seconds": round(t.dt, 4),
                   "read_bytes": tally["bytes"],
                   "read_records": tally["records"],
                   "incremental": stats.incremental,
                   "segments_new": stats.segments_new,
                   "segments_skipped": stats.segments_skipped}
            out[mode].append(row)
            print(f"  [merge 1 worker into {n}-segment {mode} store: "
                  f"{row['seconds']}s, read {row['read_bytes']} B / "
                  f"{row['read_records']} record(s)]")
    return out


def bench_compaction(tmp: str, *, pairs: int, points: int) -> dict:
    """Compaction ratio on a supersede-heavy stream: every pair re-measured
    REMEASURES times with conflicting meta settings, then compacted."""
    from repro.core.campaign import CampaignStore, compact_store

    path = os.path.join(tmp, "compact.jsonl")
    for rep in range(REMEASURES):    # one sealed segment per re-measure
        store = CampaignStore(path, segmented=True)
        _fill(store, synth_records(pairs, points, rep_tag=rep))
        store.close()
    stats = compact_store(path)
    out = {"records_in": stats.records_in, "records_out": stats.records_out,
           "bytes_in": stats.bytes_in, "bytes_out": stats.bytes_out,
           "segments_in": stats.segments_in,
           "reclaimed_pct": round(100.0 * (1 - stats.bytes_out
                                           / max(stats.bytes_in, 1)), 1)}
    print(f"  [{stats}]")
    return out


def run(quick: bool = True) -> dict:
    banner("store benchmark — append / incremental merge / compaction")
    pairs, points = (2, 8) if quick else (8, 32)
    segment_counts = (4, 16) if quick else (4, 16, 64)
    tmp = tempfile.mkdtemp(prefix="bench_store_")
    try:
        out = {"quick": quick, "pairs_per_segment": pairs,
               "points_per_pair": points,
               "append": bench_append(tmp, pairs=pairs, points=points),
               "merge": bench_merge(tmp, segment_counts=segment_counts,
                                    pairs=pairs, points=points),
               "compaction": bench_compaction(tmp, pairs=pairs,
                                              points=points)}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    inc = out["merge"]["incremental"]
    flat = (len(inc) < 2
            or inc[-1]["read_bytes"] <= inc[0]["read_bytes"] * 1.5)
    out["incremental_read_flat"] = flat
    if not flat:
        raise SystemExit("bench_store: incremental merge read volume GREW "
                         f"with store size: {json.dumps(inc)}")
    print(f"  incremental merge read volume flat across "
          f"{list(segment_counts)} segments: {flat}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_store",
        description="campaign-store benchmark: append throughput, "
                    "incremental-merge scaling (must be O(new segment)), "
                    "compaction ratio -> experiments/bench/BENCH_store.json")
    ap.add_argument("--quick", action="store_true",
                    help="small grids (the CI store-smoke configuration)")
    ap.add_argument("--full", action="store_true",
                    help="larger grids and one more merge size")
    args = ap.parse_args(argv)
    out = run(quick=not args.full)
    save("BENCH_store", out)
    print("wrote experiments/bench/BENCH_store.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,fig7]

Writes per-table JSON to experiments/bench/ and prints the summary tables.

Characterization sweeps run as resumable campaigns by default: every measured
(region, mode, k, t) point lands in a JSONL store under --campaign-dir, and a
re-run (after a crash, a ctrl-C, or to add modes) only measures what is
missing. ``--no-campaign`` restores the old measure-everything-every-time
behaviour; delete the store directory to force fresh numbers.

``--emit-fleet-plan PATH`` turns the harness into a plan builder: instead of
measuring, it writes a ``repro.fleet`` SweepPlan spanning the fig4/fig7
Pallas size/q FAMILIES (the whole grid the ``--pallas`` studies sample), to
be fanned out across subprocess shards or hosts:

    PYTHONPATH=src python -m benchmarks.run --emit-fleet-plan plan.json
    PYTHONPATH=src python -m repro.fleet run --plan plan.json
"""
from __future__ import annotations

import argparse
import os
import time


def build_fleet_plan(quick: bool, *, store: str, shards: int = 2,
                     out: str = "fleet_plan.json") -> str:
    """The fig4/fig7 Pallas grids as one declarative SweepPlan: the matmul
    size family and the spmxv (size × q) family share one store, one fleet,
    one merged classification."""
    from repro.fleet.plan import SweepPlan, TargetSpec

    if quick:
        m_sizes, s_sizes, qs = [128, 256], [256, 512], [0.0, 1.0]
    else:
        m_sizes, s_sizes, qs = [256, 512], [512, 2048], [0.0, 0.5, 1.0]
    plan = SweepPlan(
        name=f"bench_pallas_{'quick' if quick else 'full'}",
        store=store,
        targets=[
            TargetSpec("pallas", ("fp", "vmem"),
                       {"kernel": "matmul", "sizes": m_sizes}),
            TargetSpec("pallas", ("fp", "vmem"),
                       {"kernel": "spmxv", "sizes": s_sizes, "qs": qs,
                        "nnz_per_row": 16}),
        ],
        reps=2 if quick else 3, shards=shards, backend="interpret")
    plan.save(out)
    grid = plan.grid()
    print(f"wrote fleet plan {plan.name!r} [{plan.digest()}] -> {out}")
    print(f"  {len(grid)} (region, mode) pair(s) over {shards} shard(s); "
          f"store: {store}")
    print(f"run it:   PYTHONPATH=src python -m repro.fleet run --plan {out}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger sizes / more reps (slower, steadier)")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / few reps (the default; the explicit "
                         "flag exists for scripts and CI smoke jobs)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig5,table3")
    ap.add_argument("--campaign-dir", default="experiments/campaigns/bench",
                    help="JSONL store directory for resumable sweeps")
    ap.add_argument("--no-campaign", action="store_true",
                    help="measure every point afresh (no persistence)")
    ap.add_argument("--pallas", action="store_true",
                    help="also run fig4/fig7 on the real Pallas kernels "
                         "(interpret mode off-TPU) and report the "
                         "compile-once vs trace-per-k sweep cost")
    ap.add_argument("--emit-fleet-plan", default=None, metavar="PATH",
                    help="write a repro.fleet SweepPlan covering the "
                         "fig4/fig7 Pallas size/q families to PATH and "
                         "exit (run it with python -m repro.fleet run)")
    ap.add_argument("--fleet-shards", type=int, default=2,
                    help="shard count baked into --emit-fleet-plan")
    args = ap.parse_args()
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")
    if args.emit_fleet_plan:
        build_fleet_plan(
            not args.full, out=args.emit_fleet_plan,
            shards=args.fleet_shards,
            store=os.path.join(args.campaign_dir,
                               "full" if args.full else "quick",
                               "bench_pallas_fleet.jsonl"))
        return

    from benchmarks.common import CAMPAIGN_DIR_VAR
    if args.no_campaign:
        os.environ.pop(CAMPAIGN_DIR_VAR, None)
    else:
        # quick/full use different region sizes: separate stores so a --full
        # run never replays quick-mode timings (region names don't encode n)
        os.environ[CAMPAIGN_DIR_VAR] = os.path.join(
            args.campaign_dir, "full" if args.full else "quick")

    from benchmarks import (fig4_matmul, fig5_hwchar, fig6_overlap,
                            fig7_spmxv, table1_systems, table3_decan,
                            table4_memsys)

    suite = {
        "fig4": lambda quick: fig4_matmul.run(quick=quick,
                                              pallas=args.pallas),
        "fig5": fig5_hwchar.run,
        "table1": table1_systems.run,
        "table3": table3_decan.run,
        "fig6": fig6_overlap.run,
        "fig7": lambda quick: fig7_spmxv.run(quick=quick,
                                             pallas=args.pallas),
        "table4": table4_memsys.run,
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    t_all = time.time()
    results = {}
    for name, fn in suite.items():
        if only and name not in only:
            continue
        t0 = time.time()
        results[name] = fn(quick=not args.full)
        print(f"[{name} done in {time.time()-t0:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t_all:.1f}s "
          f"-> experiments/bench/*.json")


if __name__ == "__main__":
    main()

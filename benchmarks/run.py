"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,fig7]

Writes per-table JSON to experiments/bench/ and prints the summary tables.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger sizes / more reps (slower, steadier)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig5,table3")
    args = ap.parse_args()

    from benchmarks import (fig4_matmul, fig5_hwchar, fig6_overlap,
                            fig7_spmxv, table1_systems, table3_decan,
                            table4_memsys)

    suite = {
        "fig4": fig4_matmul.run,
        "fig5": fig5_hwchar.run,
        "table1": table1_systems.run,
        "table3": table3_decan.run,
        "fig6": fig6_overlap.run,
        "fig7": fig7_spmxv.run,
        "table4": table4_memsys.run,
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    t_all = time.time()
    results = {}
    for name, fn in suite.items():
        if only and name not in only:
            continue
        t0 = time.time()
        results[name] = fn(quick=not args.full)
        print(f"[{name} done in {time.time()-t0:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t_all:.1f}s "
          f"-> experiments/bench/*.json")


if __name__ == "__main__":
    main()

"""Paper Table 3: the four FP/LS overlap scenarios, analyzed by BOTH methods
— DECAN-style decremental variants (Sat = T(VAR)/T(REF)) and incremental
noise injection (absorption).

Scenario kernels (separable FP / LS parts):

  1) compute-bound    deep nonlinear FMA chains + token L1 loads
  2) data-bound       STREAM-triad loads + shallow chains (chains fully
                      hidden under the DRAM stream)
  3) full-overlap     triad + chains balanced to equal stand-alone times
  4) limited-overlap  scattered-miss loads seeding the chains (serialized)

Microarchitectural caveat (measured, documented): on this container's
narrow core, the balanced case-3 kernel *behaves* like case 4 — once the FP
stream saturates the issue width nothing else co-issues, so REF ~= FP + LS
instead of max(FP, LS). The noise+DECAN combination diagnoses exactly that:
absorption ~0 in both modes + DECAN ruling out full overlap -> shared
upstream (issue-width/frontend) bottleneck — the same resolution the paper
demonstrates in Fig. 6. On wide server cores (the paper's hardware) the FP
ports saturate before issue width and genuine case-3 appears.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import banner, characterize, run_decan_stored, save
from repro.core import (Controller, DecanTarget, classify,
                        cross_check_with_decan)

N = 1 << 22
CHUNK = 512
N_CH = 4


def _chains(xs, depth):
    out = list(xs)
    for j in range(N_CH):
        y = out[j]
        for _ in range(depth):
            y = y + y * y * 1e-9    # nonlinear: XLA cannot fold the chain
        out[j] = y
    return out


def _kernel(kind: str, depth: int, ls: bool, fp: bool, n_iter: int,
            noise=None, k: int = 0):
    """kind: token (2 L1 loads) | stream (triad chunk) | scatter (4 misses,
    'dep' variant seeds the chains with loaded values)."""
    dependent = kind == "scatter_dep"

    def fn(a, b, c, x0, *nc):
        def body(i, st):
            cb, accs, xs = st[0], list(st[1]), list(st[2])
            ncs = st[3:]
            if ls:
                if kind == "stream":
                    off = (i * CHUNK) % (N - CHUNK)
                    av = jax.lax.dynamic_slice(a, (off,), (CHUNK,))
                    bv = jax.lax.dynamic_slice(b, (off,), (CHUNK,))
                    cb = jax.lax.dynamic_update_slice(cb, av + 3.0 * bv, (off,))
                elif kind == "token":
                    for j in range(2):
                        off = ((i * 2 + j) * 16) % 4096   # L1-resident window
                        accs[j] = accs[j] + jax.lax.dynamic_slice(a, (off,), (8,))
                else:  # scatter / scatter_dep
                    for j in range(4):
                        off = ((i * 4 + j) * 40_503) % (N - 8)
                        accs[j % N_CH] = accs[j % N_CH] + \
                            jax.lax.dynamic_slice(a, (off,), (8,))
            if fp:
                seed = [accs[j] * 1e-12 + xs[j] if (dependent and ls) else xs[j]
                        for j in range(N_CH)]
                xs = _chains(seed, depth)
            if noise is not None:
                ncs = (noise.emit(ncs[0], k, i),)
            return (cb, tuple(accs), tuple(xs), *ncs)

        accs0 = tuple(jnp.zeros((8,), jnp.float32) for _ in range(N_CH))
        xs0 = tuple(x0 + j for j in range(N_CH))
        st = jax.lax.fori_loop(0, n_iter, body, (c, accs0, xs0, *nc))
        out = jnp.sum(st[0][:8]) + sum(jnp.sum(v) for v in st[1]) \
            + sum(jnp.sum(v) for v in st[2])
        if noise is not None:
            return out, noise.finalize(st[3])
        return out

    return jax.jit(fn)


SCENARIOS = {
    # name: (kind, chain_depth, n_iter)
    "compute-bound": ("token", 24, 25_000),
    "data-bound": ("stream", 4, N // CHUNK),
    "full-overlap": ("stream", 192, N // CHUNK),
    "limited-overlap": ("scatter_dep", 24, 20_000),
}

EXPECTED = {  # paper Table 3 readouts (noise column), on this host
    "compute-bound": "fp low / l1 high",
    "data-bound": "mem low / fp high",
    "full-overlap": "both ~0 (degrades to case 4 on a narrow core)",
    "limited-overlap": "moderate/ambiguous",
}


def run(quick: bool = True) -> dict:
    banner("Table 3 — DECAN (decremental) vs noise injection (incremental)")
    a = jnp.ones((N,), jnp.float32)
    b = jnp.full((N,), 2.0, jnp.float32)
    c = jnp.zeros((N,), jnp.float32)
    x0 = jnp.linspace(0.1, 0.9, 8, dtype=jnp.float32)
    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    rows = {}
    for name, (kind, depth, n_iter) in SCENARIOS.items():
        n_it = n_iter if quick else n_iter * 2

        def build(fp, ls, kind=kind, depth=depth, n_it=n_it):
            return _kernel(kind, depth, ls, fp, n_it)

        def make(noise, k, kind=kind, depth=depth, n_it=n_it):
            return _kernel(kind, depth, True, True, n_it, noise=noise, k=k)

        # one DecanTarget carries both analyses: the decremental variants
        # (store-backed, replayed on re-runs) and — via .region()'s build_rt
        # — the compile-once noise sweeps (≤2 executables per mode, not one
        # per k). Both write to the same t3_<name>.jsonl campaign artifact.
        target = DecanTarget(f"t3_{name}", build, lambda: (a, b, c, x0),
                             build_noisy=make)
        dec = run_decan_stored(target, reps=3 if quick else 5)
        rep = characterize(ctl, target.region(), ("fp_add", "l1_ld"))
        noise_label = classify(rep.absorptions())
        combined = cross_check_with_decan(noise_label, dec.sat_fp, dec.sat_ls)
        rows[name] = {
            "sat_fp": dec.sat_fp, "sat_ls": dec.sat_ls,
            "decan_scenario": dec.scenario(),
            "abs_fp": rep.results["fp_add"].fit.k1,
            "abs_l1": rep.results["l1_ld"].fit.k1,
            "noise_label": noise_label.label,
            "combined_label": combined.label,
            "expected": EXPECTED[name],
        }
        r = rows[name]
        print(f"  {name:16s} DECAN: Sat_FP={r['sat_fp']:.2f} "
              f"Sat_LS={r['sat_ls']:.2f} -> {r['decan_scenario']:16s} | "
              f"noise: Abs_FP={r['abs_fp']:5.1f} Abs_L1={r['abs_l1']:5.1f} "
              f"-> {r['noise_label']:9s} | combined: {r['combined_label']}")
    save("table3_decan", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)

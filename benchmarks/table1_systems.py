"""Paper Table 1: cross-system comparison via the absorption metric.

Row 1 is the machine we actually have (host CPU, measured — the paper's own
protocol). The TPU rows are ANALYTIC: kernel resource terms modeled from
first principles (bytes moved / flops issued per step) and pushed through the
saturation model (core.analytic) at each HardwareConfig — the same
"absorption = slack in noise patterns" quantity the paper measures, derived
for hardware this container does not have. v5e vs v5p plays the role of the
paper's DDR-vs-HBM column pair (same compute class, different memory system).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import banner, characterize, save
from repro.bench.kernels import haccmk_region, lat_mem_rd_region, stream_region
from repro.configs.base import CXL_MEM, TPU_V5E, TPU_V5P
from repro.core import Controller, StepTerms, predict_absorption
from repro.core.noise import NoiseScale, make_modes

HWS = {"tpu_v5e": TPU_V5E, "tpu_v5p": TPU_V5P, "cxl_ddr": CXL_MEM}


def _kernel_terms(hw) -> dict[str, StepTerms]:
    """Per-kernel resource seconds on one chip of ``hw`` (modeled)."""
    # STREAM: 3 arrays x 32 MiB; flops = n adds+muls.
    n = 1 << 23
    stream = StepTerms(compute=2 * n / hw.peak_flops,
                       memory=3 * 4 * n / hw.hbm_bw)
    # lat_mem_rd: 32k dependent line loads, zero reuse.
    hops = 32768
    lat = StepTerms(compute=hops / hw.peak_flops,
                    memory=hops * 128 / hw.hbm_bw,
                    latency=hops * hw.hbm_latency_s)
    # HACCmk: n-body force poly — arithmetic intensity >> ridge point.
    flops = 2e9
    hacc = StepTerms(compute=flops / hw.peak_flops,
                     memory=flops * 0.01 / hw.hbm_bw)
    return {"stream": stream, "lat_mem_rd": lat, "haccmk": hacc}


def run(quick: bool = True) -> dict:
    banner("Table 1 — cross-system absorption (host measured; TPUs analytic)")
    rows: dict = {}

    # measured host row (the paper's protocol, for the machine we have)
    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    host = {}
    for name, region in {
        "stream": stream_region(n=1 << 22),
        "lat_mem_rd": lat_mem_rd_region(table_len=1 << 20, n_iter=2048),
        "haccmk": haccmk_region(n_iter=60_000),
    }.items():
        rep = characterize(ctl, region, ("fp_add", "l1_ld", "mem_ld"))
        a = rep.absorptions()
        host[name] = {"fp": a["fp_add"], "l1": a["l1_ld"], "mem": a["mem_ld"],
                      "t0_s": rep.results["fp_add"].fit.t0}
    rows["host_cpu(measured)"] = host

    # analytic rows
    modes = make_modes(NoiseScale())
    probe = {"fp": modes["fp_add32"], "l1": modes["vmem_ld"],
             "mem": modes["hbm_stream"]}
    for hw_name, hw in HWS.items():
        terms = _kernel_terms(hw)
        row = {}
        for kname, t in terms.items():
            entry = {"t0_s": t.bound()}
            for short, mode in probe.items():
                fit = predict_absorption(t, mode, hw, tol=0.05)
                entry[short] = min(fit.k1, 1e6)
            row[kname] = entry
        rows[f"{hw_name}(analytic)"] = row

    hdr = f"{'system':22s} | " + " | ".join(
        f"{k:>26s}" for k in ("stream fp/l1/mem", "lat_mem fp/l1/mem",
                              "haccmk fp/l1/mem"))
    print(hdr)
    for sysname, row in rows.items():
        cells = []
        for k in ("stream", "lat_mem_rd", "haccmk"):
            e = row[k]
            cells.append(f"{e['fp']:8.0f}/{e['l1']:7.0f}/{e['mem']:7.0f}")
        print(f"{sysname:22s} | " + " | ".join(f"{c:>26s}" for c in cells))

    # the paper's Table-1 inverse correlation: faster memory system (v5p)
    # -> less stream absorption headroom relative to its own noise quantum
    save("table1_systems", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)

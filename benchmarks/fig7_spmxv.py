"""Paper Fig. 7/8 + Table 4 lead-in: the SPMXV case study.

Sweep the swap probability q on a small (cache-resident at q=0) and a large
(bandwidth-bound at q=0) matrix; measure GFLOPS and FP/L1 absorption. The
paper's finding: on the large matrix, performance only decreases with q while
absorption first DROPS (bandwidth regime tightening) then RISES again
(latency regime: stalls reappear as dependency slack) — a regime transition
invisible to plain performance numbers.

``--pallas``: additionally run the q-sweep on the REAL ELL SPMV Pallas
kernel (interpret mode off-TPU) through the campaign spine, and report the
compile-once vs trace-per-k sweep cost (executables built + wall-clock).
"""
from __future__ import annotations

import argparse

from benchmarks.common import banner, characterize, pallas_sweep_ab, save
from repro.bench.kernels import spmxv_region
from repro.core import Controller, measure


def run_pallas(quick: bool = True) -> dict:
    """The q-study on the real Pallas ELL SPMV kernel."""
    from repro.kernels.region import pallas_region

    banner("Fig 7 (pallas) — ELL SPMV kernel: performance vs absorption")
    qs = (0.0, 0.5, 1.0) if quick else (0.0, 0.25, 0.5, 0.75, 1.0)
    n = 512 if quick else 2048
    nnz = 16
    ctl = Controller(reps=2 if quick else 3)
    rows = []
    for q in qs:
        region = pallas_region("spmxv", backend="interpret", n=n,
                               nnz_per_row=nnz, q=q)
        t0 = measure(region.build("", 0), region.args_for("", 0),
                     reps=2 if quick else 3)
        gflops = 2.0 * n * nnz / t0 / 1e9
        rep = characterize(ctl, region, ("fp", "vmem"))
        rows.append({"q": q, "region": region.name, "gflops": gflops,
                     "abs_fp": rep.results["fp"].fit.k1,
                     "abs_vmem": rep.results["vmem"].fit.k1,
                     "label": rep.bottleneck.label})
        r = rows[-1]
        print(f"  pallas q={q:4.2f}  {gflops:6.3f} GFLOP/s  "
              f"Abs_FP={r['abs_fp']:6.1f} Abs_VMEM={r['abs_vmem']:6.1f} "
              f"-> {r['label']}")
    ks = (0, 1, 2, 4, 8, 16) if quick else (0, 1, 2, 4, 8, 16, 32, 64)
    ab = pallas_sweep_ab("spmxv", "fp", ks, reps=2 if quick else 3,
                         n=n, nnz_per_row=nnz)
    return {"rows": rows, "sweep_cost": ab}


def run(quick: bool = True, pallas: bool = False) -> dict:
    banner("Fig 7/8 — SPMXV: performance vs absorption across q")
    qs = (0.0, 0.25, 0.5, 1.0) if quick else (0.0, 0.1, 0.25, 0.5, 0.75, 1.0)
    sizes = {"small": 1 << 17, "large": 1 << 21}
    nnz = 16
    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    out: dict = {}
    for label, n in sizes.items():
        rows = []
        for q in qs:
            region = spmxv_region(n=n, nnz_per_row=nnz, q=q,
                                  name=f"spmxv_{label}_q{q}")
            t0 = measure(region.build("", 0), region.args_for("", 0),
                         reps=3 if quick else 5)
            gflops = 2.0 * n * nnz / t0 / 1e9
            rep = characterize(ctl, region, ("fp_add", "l1_ld"))
            rows.append({"q": q, "gflops": gflops,
                         "abs_fp": rep.results["fp_add"].fit.k1,
                         "abs_l1": rep.results["l1_ld"].fit.k1,
                         "label": rep.bottleneck.label})
            r = rows[-1]
            print(f"  {label:5s} q={q:4.2f}  {gflops:6.2f} GFLOP/s  "
                  f"Abs_FP={r['abs_fp']:6.1f} Abs_L1={r['abs_l1']:6.1f} "
                  f"-> {r['label']}")
        out[label] = rows

    lg = out["large"]
    perf_monotonic = all(lg[i]["gflops"] >= lg[i + 1]["gflops"] - 0.15
                         for i in range(len(lg) - 1))
    fp_abs = [r["abs_fp"] for r in lg]
    non_monotonic = any(fp_abs[i] > min(fp_abs[:i] or [1e9])
                        for i in range(1, len(fp_abs)))
    print(f"  large: performance monotonically falls: {perf_monotonic}; "
          f"absorption non-monotonic (regime transition): {non_monotonic}")
    out["findings"] = {"perf_monotonic": perf_monotonic,
                       "absorption_non_monotonic": non_monotonic}
    if pallas:
        out["pallas"] = run_pallas(quick)
    save("fig7_spmxv", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pallas", action="store_true")
    a = ap.parse_args()
    run(quick=not a.full, pallas=a.pallas)

"""Paper Fig. 5: absorption of the three hardware-characterization benchmarks
(STREAM, lat_mem_rd, HACCmk) under fp / l1 / memory noise, measured on the
host — the differential signatures that validate the method:

  STREAM      absorbs fp & l1 noise, NOT memory noise  (bandwidth-bound)
  lat_mem_rd  absorbs substantial memory noise          (latency-bound)
  HACCmk      absorbs l1 noise, NOT fp noise            (compute-bound)
"""
from __future__ import annotations

from benchmarks.common import banner, characterize, save
from repro.bench.kernels import haccmk_region, lat_mem_rd_region, stream_region
from repro.core import Controller, classify


def run(quick: bool = True) -> dict:
    banner("Fig 5 — STREAM / lat_mem_rd / HACCmk absorption signatures")
    scale = 1 if quick else 2
    regions = {
        "stream": stream_region(n=(1 << 22) * scale),
        # chase table must exceed the LLC so every hop is a genuine DRAM
        # miss — that slack is what memory noise gets absorbed into
        "lat_mem_rd": lat_mem_rd_region(table_len=(1 << 22) * scale,
                                        n_iter=1024 * scale),
        "haccmk": haccmk_region(n_iter=60_000 * scale),
    }
    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    rows = {}
    for name, region in regions.items():
        rep = characterize(ctl, region, ("fp_add", "l1_ld", "mem_ld"))
        rows[name] = {"abs": rep.absorptions(),
                      "abs_rel": rep.absorptions(relative=True),
                      "bottleneck": rep.bottleneck.label,
                      "confidence": rep.bottleneck.confidence}
        print(rep.summary())

    sig = {
        "stream_is_bandwidth": rows["stream"]["bottleneck"] == "bandwidth",
        "latmem_absorbs_memory": rows["lat_mem_rd"]["abs"]["mem_ld"]
        > rows["stream"]["abs"]["mem_ld"],
        "haccmk_fp_lowest": rows["haccmk"]["abs"]["fp_add"]
        <= min(rows["haccmk"]["abs"]["l1_ld"],
               rows["stream"]["abs"]["fp_add"]),
    }
    print("signatures:", sig)
    out = {"rows": rows, "signatures": sig}
    save("fig5_hwchar", out)
    return out


if __name__ == "__main__":
    run(quick=True)

"""Paper Table 4: which memory system suits SPMXV as q grows?

The paper measured DDR vs HBM on Sapphire Rapids: equal at q=0, HBM collapses
for q>=0.25 because wide HBM bursts are wasted on random single-element
gathers. We answer the same *question* for the TPU target analytically:
model SPMXV's resource terms as a function of q under two memory systems —
burst-oriented high-bandwidth (HBM-class) vs narrow-line lower-latency
(DDR/CXL-class) — and push them through the saturation model. The crossover
(HBM wins at low q, DDR-class at high q) is the paper's Table-4 conclusion,
now derivable before buying either system.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import banner, save
from repro.configs.base import HardwareConfig
from repro.core import StepTerms, predict_absorption
from repro.core.noise import make_modes

N = 1 << 21          # rows
NNZ = 16             # per row
MLP = 24             # outstanding misses the memory system can overlap

MEMS = {
    # name: (bw B/s, line bytes, latency s)
    "hbm_like": (819e9, 512, 700e-9),
    "ddr_like": (256e9, 64, 90e-9),
}


def spmxv_terms(q: float, bw: float, line: int, lat: float) -> StepTerms:
    streaming = N * NNZ * 8 + N * 4          # vals+cols stream + y write
    gathers = N * NNZ
    # regular fraction: gathered lines have spatial reuse (banded columns);
    # random fraction q: one full line fetched per useful 4 bytes.
    gather_bytes = gathers * ((1 - q) * 4 + q * line)
    memory = (streaming + gather_bytes) / bw
    latency = gathers * q * lat / MLP
    compute = 2 * N * NNZ / 197e12
    return StepTerms(compute=compute, memory=memory, latency=latency)


def run(quick: bool = True) -> dict:
    banner("Table 4 — HBM-class vs DDR-class for SPMXV (analytic, per q)")
    del quick
    qs = (0.0, 0.25, 0.5)
    modes = make_modes()
    rows: dict = {}
    print(f"  {'q':>5s} | " + " | ".join(
        f"{m:>28s}" for m in MEMS) + "   (GFLOP/s-per-chip, Abs_fp)")
    for q in qs:
        row = {}
        cells = []
        for mname, (bw, line, lat) in MEMS.items():
            hw = HardwareConfig(name=mname, hbm_bw=bw, hbm_latency_s=lat)
            t = spmxv_terms(q, bw, line, lat)
            gflops = 2 * N * NNZ / t.bound() / 1e9
            fit = predict_absorption(t, modes["fp_add32"], hw)
            dom = t.dominant
            row[mname] = {"gflops": gflops, "abs_fp": min(fit.k1, 1e9),
                          "dominant": dom}
            cells.append(f"{gflops:9.1f} GF  abs={min(fit.k1,1e9):8.0f} {dom[:4]}")
        rows[q] = row
        print(f"  {q:5.2f} | " + " | ".join(f"{c:>28s}" for c in cells))

    r0, r5 = rows[0.0], rows[0.5]
    hbm_collapse = (r5["hbm_like"]["gflops"] / r0["hbm_like"]["gflops"]
                    < 0.5 * r5["ddr_like"]["gflops"] / r0["ddr_like"]["gflops"])
    print(f"  HBM-class collapses under random access (paper's finding): "
          f"{hbm_collapse}")
    out = {"rows": {str(k): v for k, v in rows.items()},
           "hbm_collapse": bool(hbm_collapse)}
    save("table4_memsys", out)
    return out


if __name__ == "__main__":
    run(quick=True)

"""Paper Fig. 6: the livermore lloops.c_1351 case — a kernel where the two
methods disagree and only their COMBINATION yields the right diagnosis.

Construction (mirrors the paper's kernel): two FP dependency channels
computing on identical loaded inputs. DECAN's FP variant stays near the
reference (suggesting FP-bound); noise injection shows near-zero absorption
in BOTH modes (suggesting full overlap, case 3) — DECAN has already ruled
case 3 out, so the combined verdict is a shared upstream (frontend-analogue)
bottleneck. core.classifier.cross_check_with_decan implements exactly this
resolution step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import banner, characterize, run_decan_stored, save
from repro.core import (Controller, DecanTarget, classify,
                        cross_check_with_decan)

N = 1 << 18
CHUNK = 64
N_CHAINS = 12    # more independent add chains in flight than the core's
                 # issue/ALU width sustains -> a shared upstream bottleneck


def _livermore(fp: bool, ls: bool, n_iter: int, noise=None, k: int = 0):
    """Issue-width saturator with light loads (arith intensity ~0.2 like the
    paper's lloops.c_1351): the FP stream alone nearly reproduces the run
    time, the LS stream alone is much faster — yet noise of EITHER kind
    degrades immediately because every extra instruction costs an issue
    slot. The frontend-bottleneck scenario."""
    def fn(buf, *nc):
        def body(i, st):
            chains = list(st[0])
            acc = st[1]
            ncs = st[2:]
            if ls:
                off = (i * CHUNK) % (N - CHUNK)
                v = jax.lax.dynamic_slice(buf, (off,), (8,))
                acc = acc + v
            if fp:
                for j in range(N_CHAINS):
                    chains[j] = chains[j] + 1e-7
            if noise is not None:
                ncs = (noise.emit(ncs[0], k, i),)
            return (tuple(chains), acc, *ncs)
        z = jnp.zeros((8,), jnp.float32)
        chains0 = tuple(z + j for j in range(N_CHAINS))
        st = jax.lax.fori_loop(0, n_iter, body, (chains0, z, *nc))
        out = sum(jnp.sum(c) for c in st[0]) + jnp.sum(st[1])
        if noise is not None:
            return out, noise.finalize(st[2])
        return out
    return jax.jit(fn)


def run(quick: bool = True) -> dict:
    banner("Fig 6 — combining noise injection with DECAN (livermore case)")
    n_iter = 60_000 if quick else 150_000
    buf = jnp.ones((N,), jnp.float32)

    target = DecanTarget(
        "livermore_1351",
        lambda fp, ls: _livermore(fp, ls, n_iter),
        lambda: (buf,),
        build_noisy=lambda noise, k: _livermore(True, True, n_iter,
                                                noise=noise, k=k))
    dec = run_decan_stored(target, reps=3 if quick else 5)

    ctl = Controller(reps=3 if quick else 5, verify_payload=False)
    rep = characterize(ctl, target.region(), ("fp_add", "l1_ld"))

    noise_only = classify(rep.absorptions())
    combined = cross_check_with_decan(noise_only, dec.sat_fp, dec.sat_ls)

    print(f"  DECAN: Sat_FP={dec.sat_fp:.2f} Sat_LS={dec.sat_ls:.2f} "
          f"-> {dec.scenario()}")
    print(f"  noise: {dict((m, round(a,1)) for m, a in rep.absorptions().items())} "
          f"-> {noise_only.label}")
    print(f"  combined verdict: {combined.label} ({combined.decan_hint})")
    out = {"sat_fp": dec.sat_fp, "sat_ls": dec.sat_ls,
           "abs": rep.absorptions(), "noise_label": noise_only.label,
           "combined_label": combined.label}
    save("fig6_overlap", out)
    return out


if __name__ == "__main__":
    run(quick=True)

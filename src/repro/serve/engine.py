"""Batched serving engine: continuous batching over a paged KV-cache pool.

Slots: a fixed decode batch of ``n_slots`` sequences with per-slot positions
(models/attention.py vector-pos path). Requests queue up; a finished slot is
immediately refilled from the queue — decode never stalls on stragglers of
the batch.

Two cache layouts:

* **paged** (dense/moe/vlm, window=0; the default for those families): one
  pool of fixed-size KV pages plus a per-slot int32 page table
  (models/attention.py paged layout). A whole admission wave prefills in ONE
  batched forward pass (``lm_paged_prefill``) scattered straight into pages;
  pages free on retire and are reused. Per-tick bookkeeping (``pos``,
  ``cur``, the active mask) lives on device — each tick is one jitted call
  plus a single host sync that fetches the sampled tokens and positions.
* **dense** (fallback for ssm/hybrid/encdec and sliding-window configs, or
  ``paged=False``): the per-slot (B, Kh, S, hd) cache with one-request-at-a-
  time prefill (full-sequence forward for attention families, sequential
  decode replay otherwise).

Dense and paged layouts are numerically identical (the paged read gathers a
slot's pages in logical order and masks exactly like the dense path); tests
pin the equivalence. Sampling: greedy or temperature. All steps are jit'd
once per shape bucket (admission pads prompts to power-of-two page
multiples, so a serving session compiles a handful of prefill shapes, not
one per prompt length).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.model import ModelApi

_PAGED_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _axes_leaf(x) -> bool:
    """A cache_spec leaf: a tuple of logical axis names / None."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


class ServeEngine:
    def __init__(self, api: ModelApi, params, *, n_slots: int = 4,
                 max_seq: int = 512, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 paged: Optional[bool] = None, page_size: int = 16,
                 n_pages: Optional[int] = None):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.eos_id = eos_id
        self._rng = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self._next_uid = 1000            # monotonic: uids never reused
        self._completed: list[Request] = []
        self.stats: dict[str, Any] = {
            "prefill_tokens": 0, "decode_tokens": 0, "prefill_calls": 0,
            "ticks": 0, "wall_s": 0.0, "occupancy_sum": 0.0,
            "occupancy_n": 0}

        pageable = self.cfg.family in _PAGED_FAMILIES and not self.cfg.window
        if paged is None:
            paged = pageable
        elif paged and not pageable:
            raise ValueError(
                f"paged serving needs an attention KV cache without a "
                f"sliding window (family={self.cfg.family!r}, "
                f"window={self.cfg.window})")
        self.paged = paged

        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.cur = jnp.zeros((n_slots, 1), jnp.int32)
        self.active = np.zeros((n_slots,), bool)
        self._active_dev = jnp.asarray(self.active)

        if paged:
            if max_seq % page_size:
                raise ValueError(f"max_seq={max_seq} must be a multiple of "
                                 f"page_size={page_size}")
            self.page_size = page_size
            self.max_pages = max_seq // page_size
            self.n_pages = (n_slots * self.max_pages if n_pages is None
                            else n_pages)
            if self.n_pages < self.max_pages:
                raise ValueError("page pool smaller than one request's "
                                 f"worst case ({self.max_pages} pages)")
            self._trash = self.n_pages   # pool page P: scatter sink, never read
            self.cache = tf.lm_paged_decode_init(
                params, self.cfg, self.n_pages + 1, page_size)
            self._table_np = np.full((n_slots, self.max_pages), self._trash,
                                     np.int32)
            self.page_table = jnp.asarray(self._table_np)
            self._free: list[int] = list(range(self.n_pages))
            self._slot_pages: list[list[int]] = [[] for _ in range(n_slots)]
            self._stalled = np.zeros((n_slots,), bool)
            self._prefill_raw, self._tick_raw = _make_paged_fns(
                self.cfg, temperature)
            self._prefill_jit = jax.jit(self._prefill_raw)
            self._tick_jit = jax.jit(self._tick_raw)
            self._last_wave = None
        else:
            self._decode = jax.jit(
                lambda p, c, t, pos: api.decode_step(p, c, t, pos))
            if self.cfg.family in _PAGED_FAMILIES:
                self._prefill1 = jax.jit(
                    lambda p, b: tf.lm_prefill(p, self.cfg, b, max_seq))
            else:
                self._prefill1 = None
            self.cache = api.decode_init(
                params, {"tokens": jnp.zeros((n_slots, 1), jnp.int32),
                         "max_seq": max_seq})

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], *, max_new: int = 32) -> Request:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) > self.max_seq - 1:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"max_seq-1={self.max_seq - 1}")
        req = Request(uid=self._next_uid, prompt=prompt, max_new=max_new)
        self._next_uid += 1
        self.queue.append(req)
        return req

    # -- paged path ----------------------------------------------------
    def _bucket(self, sp: int) -> int:
        """Pad a prompt length to a power-of-two multiple of the page size
        (capped at max_seq) — bounds the number of prefill compilations."""
        n = self.page_size
        while n < sp:
            n *= 2
        return min(n, self.max_seq)

    def _set_active(self, slot: int, value: bool) -> None:
        self.active[slot] = value
        self._active_dev = jnp.asarray(self.active)

    def _next_key(self):
        if self.temperature <= 0:
            return self._rng
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def _resume_stalled(self) -> None:
        """Re-activate slots that stalled on an empty free list once pages
        are available again (their whole state — pages, pos, cur — is
        intact, so generation just continues)."""
        resumed = False
        for slot in range(self.n_slots):
            if not self._stalled[slot]:
                continue
            if not self._free:
                break       # NOT return: already-resumed slots need the sync
            pp = len(self._slot_pages[slot])
            pid = self._free.pop()
            self._slot_pages[slot].append(pid)
            self._table_np[slot, pp] = pid
            self._stalled[slot] = False
            self._set_active(slot, True)
            resumed = True
        if resumed:
            self.page_table = jnp.asarray(self._table_np)

    def _admit_wave(self) -> bool:
        """Admit up to ``n_slots`` queued requests in ONE batched prefill:
        pad the wave's prompts to a common bucketed length, allocate the
        covering pages per member, run ``lm_paged_prefill`` (forward +
        scatter into pages) once, and sample each member's first token."""
        free_slots = [s for s in range(self.n_slots)
                      if self.slot_req[s] is None]
        wave: list[tuple[int, Request]] = []
        while free_slots and self.queue:
            cand = [r for _, r in wave] + [self.queue[0]]
            spad = self._bucket(max(len(r.prompt) for r in cand))
            if (spad // self.page_size) * len(cand) > len(self._free):
                break
            wave.append((free_slots.pop(0), self.queue.popleft()))
        if not wave:
            return False

        spad = self._bucket(max(len(r.prompt) for _, r in wave))
        npp = spad // self.page_size
        toks = np.zeros((self.n_slots, spad), np.int32)
        rows = np.full((self.n_slots, npp), self._trash, np.int32)
        lens = np.ones((self.n_slots,), np.int32)
        adm = np.zeros((self.n_slots,), bool)
        for slot, req in wave:
            sp = len(req.prompt)
            toks[slot, :sp] = req.prompt
            pages = [self._free.pop() for _ in range(npp)]
            self._slot_pages[slot] = pages
            self._table_np[slot, :] = self._trash
            self._table_np[slot, :npp] = pages
            rows[slot] = pages
            lens[slot] = sp
            adm[slot] = True
        self.page_table = jnp.asarray(self._table_np)

        wave_args = tuple(jnp.asarray(a) for a in (toks, rows, lens, adm))
        self._last_wave = wave_args
        self.cache, self.pos, self.cur, nxt = self._prefill_jit(
            self.params, self.cache, *wave_args, self.pos, self.cur,
            self._next_key())
        nxt_h = np.asarray(jax.device_get(nxt))
        for slot, req in wave:
            req.out.append(int(nxt_h[slot]))
            self.slot_req[slot] = req
            self._set_active(slot, True)
            self.stats["prefill_tokens"] += len(req.prompt)
        self.stats["prefill_calls"] += 1
        return True

    def _step_paged(self) -> None:
        self._resume_stalled()
        self._admit_wave()
        if not self.active.any():
            if any(r is not None for r in self.slot_req):
                raise RuntimeError(
                    "page pool exhausted: every in-flight request is "
                    "stalled and nothing can retire — size the pool at "
                    "n_slots * (max_seq // page_size) pages to rule this "
                    "out")
            return
        self.cache, self.cur, self.pos, nxt = self._tick_jit(
            self.params, self.cache, self.cur, self.pos, self._active_dev,
            self.page_table, self._next_key())
        # the tick's single host sync: sampled tokens + updated positions
        nxt_h, pos_h = (np.asarray(a)
                        for a in jax.device_get((nxt, self.pos)))
        self.stats["ticks"] += 1
        self.stats["decode_tokens"] += int(self.active.sum())
        self.stats["occupancy_sum"] += self.pool_occupancy()
        self.stats["occupancy_n"] += 1
        table_dirty = False
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not self.active[slot]:
                continue
            tok = int(nxt_h[slot])
            req.out.append(tok)
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out) >= req.max_new
                    or int(pos_h[slot]) >= self.max_seq - 1):
                self._retire(slot)
                table_dirty = True
                continue
            pp = int(pos_h[slot]) // self.page_size   # next write position
            if pp >= len(self._slot_pages[slot]):
                if self._free:
                    pid = self._free.pop()
                    self._slot_pages[slot].append(pid)
                    self._table_np[slot, pp] = pid
                    table_dirty = True
                else:
                    self._stalled[slot] = True
                    self._set_active(slot, False)
        if table_dirty:
            self.page_table = jnp.asarray(self._table_np)

    def pool_occupancy(self) -> float:
        """Fraction of the page pool currently assigned to slots (paged);
        fraction of cache slots active (dense)."""
        if self.paged:
            return 1.0 - len(self._free) / self.n_pages
        return float(self.active.mean())

    # -- dense path ----------------------------------------------------
    def _scatter_slot(self, big, small, slot: int):
        """Scatter a single-request cache into the batched cache along each
        leaf's DECLARED batch axis (``cache_spec`` logical names) — leaves
        without a "cache_batch" axis (e.g. a ring cache's shared ``kpos``)
        are left untouched instead of being corrupted by a positional
        guess."""
        big_leaves, treedef = jax.tree.flatten(big)
        small_leaves = jax.tree.leaves(small)
        spec_leaves = jax.tree.leaves(self.api.cache_spec(),
                                      is_leaf=_axes_leaf)
        out = []
        for b, s, axes in zip(big_leaves, small_leaves, spec_leaves):
            if _axes_leaf(axes) and "cache_batch" in axes:
                ax = axes.index("cache_batch")
                idx = tuple(slice(slot, slot + 1) if i == ax else slice(None)
                            for i in range(b.ndim))
                out.append(b.at[idx].set(s))
            else:
                out.append(b)
        return jax.tree.unflatten(treedef, out)

    def _admit(self, slot: int, req: Request) -> None:
        """Prefill ``req`` into ``slot``'s cache region (dense layout)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]     # (1, Sp)
        sp = prompt.shape[1]
        if self._prefill1 is not None:
            logits, cache1 = self._prefill1(self.params,
                                            {"tokens": prompt})
            self.cache = self._scatter_slot(self.cache, cache1, slot)
        else:
            # sequential prefill: replay prompt tokens through decode_step on
            # a fresh single-slot cache, then scatter.
            c1 = self.api.decode_init(
                self.params, {"tokens": prompt[:, :1],
                              "max_seq": self.max_seq})
            logits = None
            for i in range(sp):
                logits, c1 = self._decode(self.params, c1, prompt[:, i:i + 1],
                                          jnp.int32(i))
            self.cache = self._scatter_slot(self.cache, c1, slot)
        next_tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.pos = self.pos.at[slot].set(sp)
        self.cur = self.cur.at[slot, 0].set(next_tok)
        req.out.append(int(next_tok))
        self._set_active(slot, True)
        self.slot_req[slot] = req
        self.stats["prefill_tokens"] += sp
        self.stats["prefill_calls"] += 1

    def _step_dense(self) -> None:
        for slot in range(self.n_slots):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active.any():
            return
        logits, self.cache = self._decode(self.params, self.cache, self.cur,
                                          self.pos)
        nxt = self._sample(logits[:, -1, :])                     # (B,)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.cur = nxt[:, None]
        self.stats["ticks"] += 1
        self.stats["decode_tokens"] += int(self.active.sum())
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out.append(tok)
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out) >= req.max_new
                    or int(self.pos[slot]) >= self.max_seq - 1):
                self._retire(slot)

    # ------------------------------------------------------------------
    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            self._completed.append(req)
        self.slot_req[slot] = None
        self._set_active(slot, False)
        if self.paged:
            self._free.extend(self._slot_pages[slot])
            self._slot_pages[slot] = []
            self._table_np[slot, :] = self._trash
            self._stalled[slot] = False

    def _sample(self, logits) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine tick: admit into free slots, then one decode step."""
        if self.paged:
            self._step_paged()
        else:
            self._step_dense()

    def run(self, *, max_ticks: int = 1000) -> list[Request]:
        """Tick until the queue drains; returns every request completed
        since the last ``run`` call — including requests submitted after a
        previous tick and requests finished via manual ``step()`` calls
        (completions are derived from all requests seen, not a snapshot)."""
        t0 = time.perf_counter()
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        self.stats["wall_s"] += time.perf_counter() - t0
        done, self._completed = self._completed, []
        return done

    def report(self) -> dict:
        """Throughput / occupancy summary over the ``run`` calls so far."""
        s = self.stats
        wall = s["wall_s"] or 1e-9
        occ = (s["occupancy_sum"] / s["occupancy_n"]
               if s["occupancy_n"] else self.pool_occupancy())
        return {"paged": self.paged,
                "decode_tok_s": s["decode_tokens"] / wall,
                "total_tok_s": (s["decode_tokens"] + s["prefill_tokens"])
                / wall,
                "prefill_tokens": s["prefill_tokens"],
                "decode_tokens": s["decode_tokens"],
                "prefill_calls": s["prefill_calls"],
                "ticks": s["ticks"], "wall_s": s["wall_s"],
                "mean_pool_occupancy": occ}

    # -- probe integration ---------------------------------------------
    def probe_cells(self):
        """Snapshot the engine's prefill and decode ticks as pure,
        re-runnable cells (launch/steps.py-style: a fn plus concrete args):
        ``(prefill_fn, prefill_args, tick_fn, tick_args)``. The serve
        RegionTargets (serve/load.py) wrap these with graph-level noise —
        re-running a cell recomputes the same state transition, so sweeps
        can time it any number of times."""
        if not self.paged:
            raise RuntimeError("probe_cells needs the paged engine")
        if self._last_wave is None:
            raise RuntimeError("admit at least one wave before probing")
        pf_args = (self.params, self.cache, *self._last_wave, self.pos,
                   self.cur, self._rng)
        tk_args = (self.params, self.cache, self.cur, self.pos,
                   self._active_dev, self.page_table, self._rng)
        return self._prefill_raw, pf_args, self._tick_raw, tk_args


def _make_paged_fns(cfg, temperature: float):
    """The paged engine's two pure device programs (jitted once each).

    prefill(params, cache, toks, rows, lens, adm, pos, cur, key)
        -> (cache, pos, cur, next_tokens)
    tick(params, cache, cur, pos, active, table, key)
        -> (cache, cur, pos, next_tokens)
    """
    def sample(logits, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def prefill(p, cache, toks, rows, lens, adm, pos, cur, key):
        logits, cache = tf.lm_paged_prefill(p, cfg, {"tokens": toks}, cache,
                                            rows)
        last = jnp.take_along_axis(
            logits, (lens - 1)[:, None, None], axis=1)[:, 0]     # (B, V)
        nxt = sample(last, key)
        pos = jnp.where(adm, lens, pos)
        cur = jnp.where(adm[:, None], nxt[:, None], cur)
        return cache, pos, cur, nxt

    def tick(p, cache, cur, pos, active, table, key):
        logits, cache = tf.lm_paged_decode_step(p, cfg, cache, cur, pos,
                                                table)
        nxt = sample(logits[:, -1, :], key)
        pos = pos + active.astype(jnp.int32)
        cur = jnp.where(active[:, None], nxt[:, None], cur)
        return cache, cur, pos, nxt

    return prefill, tick

"""Batched serving engine with a KV-cache and continuous-batching-lite.

Slots: a fixed decode batch of ``n_slots`` sequences with per-slot positions
(models/attention.py vector-pos path). Requests queue up; a finished slot is
immediately refilled by prefilling the next request into that slot's cache
region (batched scatter) — decode never stalls on stragglers of the batch.

Fast prefill for dense/moe/vlm (one forward pass builds the cache);
sequential prefill fallback for ssm/hybrid/encdec families. Sampling: greedy
or temperature. All steps are jit'd once (shapes are static: cache max_seq
and slot count fixed at engine build).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.model import ModelApi


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, api: ModelApi, params, *, n_slots: int = 4,
                 max_seq: int = 512, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.eos_id = eos_id
        self._rng = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * n_slots

        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, c, t, pos))
        if self.cfg.family in ("dense", "moe", "vlm"):
            self._prefill1 = jax.jit(
                lambda p, b: tf.lm_prefill(p, self.cfg, b, max_seq))
        else:
            self._prefill1 = None

        # batched decode state
        self.cache = api.decode_init(
            params, {"tokens": jnp.zeros((n_slots, 1), jnp.int32),
                     "max_seq": max_seq})
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.cur = jnp.zeros((n_slots, 1), jnp.int32)
        self.active = np.zeros((n_slots,), bool)

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], *, max_new: int = 32) -> Request:
        req = Request(uid=len(self.queue) + 1000, prompt=list(prompt),
                      max_new=max_new)
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    def _admit(self, slot: int, req: Request) -> None:
        """Prefill ``req`` into ``slot``'s cache region."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]     # (1, Sp)
        sp = prompt.shape[1]
        if self._prefill1 is not None:
            logits, cache1 = self._prefill1(self.params,
                                            {"tokens": prompt})
            # scatter the single-request cache into the batched cache
            def put(big, small):
                return big.at[:, slot:slot + 1].set(small)
            self.cache = {"kv": jax.tree.map(put, self.cache["kv"],
                                             cache1["kv"])}
            next_tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        else:
            # sequential prefill: replay prompt tokens through decode_step on
            # a fresh single-slot cache, then scatter.
            c1 = self.api.decode_init(
                self.params, {"tokens": prompt[:, :1],
                              "max_seq": self.max_seq})
            logits = None
            for i in range(sp):
                logits, c1 = self._decode(self.params, c1, prompt[:, i:i + 1],
                                          jnp.int32(i))
            def put(big, small):
                return big.at[:, slot:slot + 1].set(small) \
                    if big.ndim >= 2 else big
            self.cache = jax.tree.map(put, self.cache, c1)
            next_tok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.pos = self.pos.at[slot].set(sp)
        self.cur = self.cur.at[slot, 0].set(next_tok)
        req.out.append(int(next_tok))
        self.active[slot] = True
        self.slot_req[slot] = req

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
        self.slot_req[slot] = None
        self.active[slot] = False

    def _sample(self, logits) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine tick: admit into free slots, then one decode step."""
        for slot in range(self.n_slots):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active.any():
            return
        logits, self.cache = self._decode(self.params, self.cache, self.cur,
                                          self.pos)
        nxt = self._sample(logits[:, -1, :])                     # (B,)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.cur = nxt[:, None]
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out.append(tok)
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out) >= req.max_new
                    or int(self.pos[slot]) >= self.max_seq - 1):
                self._retire(slot)

    def run(self, *, max_ticks: int = 1000) -> list[Request]:
        """Tick until the queue drains; returns completed requests."""
        completed: list[Request] = []
        tracked: list[Request] = list(self.queue) + [
            r for r in self.slot_req if r is not None]
        for _ in range(max_ticks):
            if not self.queue and not self.active.any():
                break
            self.step()
        completed = [r for r in tracked if r.done]
        return completed

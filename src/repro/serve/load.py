"""Synthetic-traffic load harness for the serving engine, and the probe's
serve RegionTargets.

The harness drives a ``ServeEngine`` with a reproducible request stream —
closed-loop (keep N requests outstanding) or Poisson arrivals (exponential
inter-arrival gaps measured in engine ticks, so runs are deterministic and
machine-independent) — over prompt/decode length mixes, and reports
tokens/sec plus page-pool occupancy:

    PYTHONPATH=src python -m repro.serve.load --arch gemma-2b --mix quick \
        [--dense] [--slots 4] [--json out.json]

``build_serve_regions`` turns the same engine into the fleet's ``"serve"``
TargetSpec kind: it snapshots the engine's batched prefill and decode tick
as two pure cells (``ServeEngine.probe_cells``) and wraps each as a
graph-level-noise RegionTarget — prefill and decode classify as SEPARATE
regions of one serving workload (the paper's verdict-flip payoff: prefill
is compute-bound, decode bandwidth/latency-bound).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One reproducible traffic mix: request count, arrival process, and the
    prompt/decode length distributions (sampled with ``seed``)."""
    n_requests: int = 16
    arrival: str = "closed"          # "closed" | "poisson"
    concurrency: int = 8             # closed-loop: max outstanding requests
    mean_gap_ticks: float = 2.0      # poisson: mean inter-arrival (ticks)
    prompt_lens: tuple[int, ...] = (8, 16, 32)
    max_new: tuple[int, ...] = (4, 8, 16)
    seed: int = 0


# named mixes; prompt/decode lengths are clamped to the target config's
# max_seq by sample_requests, so one mix spans the whole configs/ zoo
MIXES: dict[str, LoadSpec] = {
    "quick": LoadSpec(n_requests=8, prompt_lens=(4, 8, 12),
                      max_new=(4, 6, 8), concurrency=8),
    "chat": LoadSpec(n_requests=24, prompt_lens=(16, 32, 64),
                     max_new=(8, 16, 32), concurrency=8),
    "long": LoadSpec(n_requests=12, prompt_lens=(64, 128, 256),
                     max_new=(32, 64), concurrency=4),
    "poisson": LoadSpec(n_requests=16, arrival="poisson",
                        mean_gap_ticks=3.0, prompt_lens=(8, 16, 32),
                        max_new=(4, 8, 16)),
}


def sample_requests(spec: LoadSpec, vocab_size: int, max_seq: int
                    ) -> list[dict]:
    """The mix's deterministic request stream: ``[{prompt, max_new,
    arrival_tick}, ...]`` sorted by arrival. Lengths clamp to the config's
    ``max_seq`` so a mix written for 4k contexts still drives a smoke
    config."""
    if spec.arrival not in ("closed", "poisson"):
        raise ValueError(f"arrival {spec.arrival!r}: one of "
                         "['closed', 'poisson']")
    rng = np.random.default_rng(spec.seed)
    reqs = []
    tick = 0.0
    for _ in range(spec.n_requests):
        plen = int(min(rng.choice(spec.prompt_lens), max_seq - 1))
        new = int(rng.choice(spec.max_new))
        if spec.arrival == "poisson":
            tick += float(rng.exponential(spec.mean_gap_ticks))
        reqs.append({
            "prompt": rng.integers(1, vocab_size, size=plen).tolist(),
            "max_new": new,
            "arrival_tick": int(tick),
        })
    return reqs


def run_load(engine, spec: LoadSpec, *, max_ticks: int = 10000) -> dict:
    """Drive ``engine`` with the mix and report throughput/occupancy.

    Closed-loop keeps at most ``spec.concurrency`` requests outstanding;
    Poisson releases requests by their arrival tick. Returns the engine's
    ``report()`` extended with per-request latency (in ticks) percentiles.
    """
    stream = sample_requests(spec, engine.cfg.vocab_size, engine.max_seq)
    pending = list(stream)
    born: dict[int, int] = {}
    latency: list[int] = []
    tracked = []
    t0 = time.perf_counter()
    tick = 0
    while (pending or engine.queue
           or any(r is not None for r in engine.slot_req)):
        if tick >= max_ticks:
            break
        while pending and _admissible(spec, pending[0], engine, tick):
            item = pending.pop(0)
            req = engine.submit(item["prompt"], max_new=item["max_new"])
            born[req.uid] = tick
            tracked.append(req)
        engine.step()
        tick += 1
        for r in tracked:
            if r.done and r.uid in born:
                latency.append(tick - born.pop(r.uid))
    wall = time.perf_counter() - t0
    engine.stats["wall_s"] += wall
    rep = engine.report()
    rep.update({
        "mix": dataclasses.asdict(spec),
        "requests_done": sum(r.done for r in tracked),
        "requests_total": len(stream),
        "latency_ticks_p50": float(np.percentile(latency, 50))
        if latency else None,
        "latency_ticks_p95": float(np.percentile(latency, 95))
        if latency else None,
    })
    return rep


def _admissible(spec: LoadSpec, item: dict, engine, tick: int) -> bool:
    if spec.arrival == "poisson":
        return item["arrival_tick"] <= tick
    outstanding = len(engine.queue) + sum(
        r is not None for r in engine.slot_req)
    return outstanding < spec.concurrency


# ---------------------------------------------------------------------------
# Probe integration: the "serve" TargetSpec kind's region builder
# ---------------------------------------------------------------------------

def serve_region_names(arch: str, *, slots: int = 4, prompt: int = 32,
                       max_new: int = 8, page_size: int = 16) -> list[str]:
    """The names ``build_serve_regions`` will produce, WITHOUT building a
    model (plan grid queries must stay cheap). Every engine parameter the
    builder varies over is encoded — campaigns differing only in ``max_new``
    or ``page_size`` must NOT collide in the store."""
    from repro.configs import get_smoke_config
    base = f"{get_smoke_config(arch).name}_serve"
    tag = f"s{prompt}_n{max_new}_p{page_size}_b{slots}"
    return [f"{base}_prefill_{tag}", f"{base}_decode_{tag}"]


def _build_engine_for_probe(arch: str, *, slots: int, prompt: int,
                            max_new: int, page_size: int):
    """A paged smoke engine two ticks into a full-slot campaign — the state
    ``ServeEngine.probe_cells`` snapshots for the serve RegionTargets."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models.model import build
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    need = prompt + max_new + 2
    max_seq = page_size
    while max_seq < need:
        max_seq *= 2
    eng = ServeEngine(api, params, n_slots=slots, max_seq=max_seq,
                      paged=True, page_size=page_size)
    rng = np.random.default_rng(0)
    for _ in range(slots):
        eng.submit(rng.integers(1, cfg.vocab_size, size=prompt).tolist(),
                   max_new=max_new)
    eng.step()       # admission wave (the prefill cell's state) + tick 1
    eng.step()       # tick 2: a representative mid-decode state
    return eng


def build_serve_regions(arch: str, modes: Sequence[str], *, slots: int = 4,
                        prompt: int = 32, max_new: int = 8,
                        page_size: int = 16) -> list:
    """Build the serve workload's two RegionTargets: the paged engine's
    batched prefill and its decode tick, each snapshotted mid-campaign
    (``ServeEngine.probe_cells``) and wrapped with the graph-level noise
    registry — the same adapter (``core.injector.step_region``) the "step"
    kind uses, so both ride the compile-once runtime-k sweep path."""
    from repro.core import step_region
    from repro.core.noise import NoiseScale, make_modes

    registry = make_modes(NoiseScale(hbm_mib=32, chase_len=1 << 20))
    unknown = [m for m in modes if m not in registry]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(registry))}")

    eng = _build_engine_for_probe(arch, slots=slots, prompt=prompt,
                                  max_new=max_new, page_size=page_size)
    pf_fn, pf_args, tk_fn, tk_args = eng.probe_cells()
    pf_name, tk_name = serve_region_names(arch, slots=slots, prompt=prompt,
                                          max_new=max_new,
                                          page_size=page_size)
    reg = {m: registry[m] for m in modes}
    return [step_region(pf_name, pf_fn, pf_args, reg),
            step_region(tk_name, tk_fn, tk_args, reg)]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.load",
        description="synthetic-traffic load harness for the serving engine")
    ap.add_argument("--arch", required=True, help="model architecture")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config (default: full config)")
    ap.add_argument("--mix", default="quick", choices=sorted(MIXES),
                    help="named traffic mix")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dense", action="store_true",
                    help="force the dense (non-paged) cache layout")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the report as JSON")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> None:
    args = build_parser().parse_args(argv)
    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.models.model import build
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, n_slots=args.slots, max_seq=args.max_seq,
                      page_size=args.page_size,
                      paged=False if args.dense else None, seed=args.seed)
    spec = dataclasses.replace(MIXES[args.mix], seed=args.seed)
    rep = run_load(eng, spec)
    print(f"== serve load: {cfg.name} mix={args.mix} "
          f"({'paged' if eng.paged else 'dense'}, slots={args.slots})")
    print(f"  {rep['requests_done']}/{rep['requests_total']} requests, "
          f"{rep['decode_tokens']} decode + {rep['prefill_tokens']} prefill "
          f"tokens in {rep['wall_s']:.2f}s")
    print(f"  decode {rep['decode_tok_s']:.1f} tok/s, total "
          f"{rep['total_tok_s']:.1f} tok/s, mean pool occupancy "
          f"{rep['mean_pool_occupancy']:.2f}")
    if rep["latency_ticks_p50"] is not None:
        print(f"  latency p50={rep['latency_ticks_p50']:.0f} "
              f"p95={rep['latency_ticks_p95']:.0f} ticks")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
        print(f"  report -> {args.json}")


if __name__ == "__main__":
    main()

from repro.serve.engine import Request, ServeEngine  # noqa: F401

_LOAD_EXPORTS = ("LoadSpec", "MIXES", "run_load", "sample_requests")


def __getattr__(name):
    # lazy: `python -m repro.serve.load` warns if the package __init__ has
    # already imported the submodule eagerly
    if name in _LOAD_EXPORTS:
        from repro.serve import load
        return getattr(load, name)
    raise AttributeError(name)

"""Sharded, async, atomic checkpointing with elastic reshard-on-load.

Layout-free on purpose: leaves are stored as host numpy in logical (unsharded)
layout plus a manifest (step, tree structure fingerprint, leaf shapes/dtypes).
A restart may therefore use a different mesh or device count — the first
pjit call reshards restored arrays to the new layout (elastic scaling), and a
multi-host deployment would gather/scatter per-host shards through the same
manifest (single-process here, so save gathers to host directly).

Atomicity: write to ``step_N.tmp`` then ``os.rename`` — a crash mid-save never
corrupts the latest checkpoint. Async: saves run on a worker thread;
``wait()`` joins before restore or exit. Retention: ``keep`` newest.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _structure_fingerprint(tree) -> str:
    s = str(jax.tree_util.tree_structure(tree))
    return hashlib.sha256(s.encode()).hexdigest()[:16]


_NATIVE = {"float16", "float32", "float64", "int8", "int16", "int32", "int64",
           "uint8", "uint16", "uint32", "uint64", "bool"}
_UINT_OF = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_storable(a: np.ndarray) -> np.ndarray:
    """bf16/f8 etc. don't survive np.save — store as same-width uints."""
    if str(a.dtype) in _NATIVE:
        return a
    return a.view(_UINT_OF[a.dtype.itemsize])


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) == dtype_str:
        return a
    import ml_dtypes  # registered custom dtypes (bundled with jax)

    target = np.dtype(getattr(ml_dtypes, dtype_str, dtype_str))
    if a.dtype.itemsize == target.itemsize and str(a.dtype).startswith("uint"):
        return a.view(target)
    return a.astype(target)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = True) -> None:
        self.wait()
        # Snapshot to host synchronously (cheap vs. serialization); the disk
        # write happens on the worker thread.
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host = [np.asarray(x) for x in leaves]
        fp = _structure_fingerprint(state)

        def work():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": step, "fingerprint": fp,
                            "n_leaves": len(host),
                            "leaves": [{"shape": list(a.shape),
                                        "dtype": str(a.dtype)} for a in host]}
                for i, a in enumerate(host):
                    np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                            _to_storable(a), allow_pickle=False)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: int, *, like: Any, mesh=None) -> Any:
        """Restore into the structure of ``like``. ``mesh`` unused directly —
        restored leaves are host-resident; the caller's pjit in_shardings
        perform the (possibly different-mesh) resharding on first use."""
        del mesh
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["fingerprint"] != _structure_fingerprint(like):
            raise ValueError("checkpoint tree structure mismatch "
                             f"(ckpt step {step})")
        leaves, treedef = jax.tree_util.tree_flatten(like)
        out = []
        for i, leaf in enumerate(leaves):
            a = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            recorded = manifest["leaves"][i]["dtype"]
            a = _from_storable(a, recorded)
            dt = getattr(leaf, "dtype", None)
            if dt is not None and str(a.dtype) != str(dt):
                a = a.astype(dt)
            out.append(a)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, *, like: Any, mesh=None
                       ) -> tuple[Optional[Any], int]:
        steps = self.steps()
        if not steps:
            return None, 0
        s = steps[-1]
        return self.restore(s, like=like, mesh=mesh), s

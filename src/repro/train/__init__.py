from repro.train.optimizer import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    lr_schedule,
)
from repro.train.grad_compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    make_compressed_psum,
)
from repro.train.trainer import Trainer, TrainState, make_train_step  # noqa: F401

"""Gradient compression with error feedback — a distributed-optimization
trick for the DP all-reduce (4× wire bytes reduction at int8).

Scheme (per leaf): scale = max|g| / 127 agreed across the axis via psum-max;
q = round(g/scale) int8; the all-reduce runs on int32 partial sums (values fit
easily: |q| ≤ 127, axis ≤ 1024 → |sum| ≤ 130k « 2^31); the residual
g - q·scale is carried to the next step (error feedback keeps convergence).

Under pjit the DP reduction is implicit in the backward pass, so the
compressed variant runs the loss/grad inside ``shard_map`` over the batch
axes and performs the reduction explicitly — the collective-bytes drop is
visible in the dry-run HLO (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat


def compress_int8(g, residual=None):
    """g f32/bf16 -> (q int8, scale f32 scalar, new_residual)."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def make_compressed_psum(axis_names: Sequence[str]):
    """Returns ``cpsum(grads, residuals) -> (mean_grads, new_residuals)`` to
    run INSIDE shard_map: int8-quantized all-reduce with error feedback.

    The shared scale is the axis-max of local scales (so quantization error
    stays bounded on every shard); the wire payload is the int8 tensor
    (all-reduced as int32 partial sums).
    """
    axes = tuple(axis_names)

    def one(g, r):
        gf = g.astype(jnp.float32)
        if r is not None:
            gf = gf + r
        local_scale = jnp.max(jnp.abs(gf)) / 127.0
        scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-30), axes)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        n = 1
        for a in axes:
            n *= compat.axis_size(a)
        mean = total.astype(jnp.float32) * (scale / n)
        return mean.astype(g.dtype), new_r

    def cpsum(grads, residuals: Optional[Any]):
        if residuals is None:
            residuals = jax.tree.map(lambda _: None, grads,
                                     is_leaf=lambda x: x is None)
        flat_g, td = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residuals) if residuals is not None else \
            [None] * len(flat_g)
        if not flat_r:
            flat_r = [None] * len(flat_g)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return td.unflatten([o[0] for o in outs]), \
            td.unflatten([o[1] for o in outs])

    return cpsum


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

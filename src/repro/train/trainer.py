"""Production trainer: pjit'd step with microbatched gradient accumulation,
mixed precision, optional int8-compressed DP all-reduce, checkpoint/restart
fault tolerance, and a straggler watchdog.

Large-scale posture (DESIGN.md §4):
  - params/optimizer sharded by the logical rules (FSDP over `data`, TP over
    `model`), batch over (`pod`,`data`) — ZeRO-3-style memory scaling under
    plain pjit.
  - microbatch accumulation bounds activation memory AND gives XLA's
    latency-hiding scheduler per-microbatch reduce-scatters to overlap with
    the next microbatch's compute.
  - fault tolerance: every state mutation flows through TrainState; the loop
    checkpoints asynchronously, detects straggling steps by deadline, and on
    failure restores the last checkpoint and continues (elastic: checkpoints
    are mesh-layout-free, so the restart may use a different mesh/device
    count).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import TrainConfig
from repro.models.model import ModelApi
from repro.parallel.sharding import resolve, resolve_tree
from repro.train import grad_compression as gc
from repro.train.optimizer import (AdamWState, adamw_init, adamw_update,
                                   opt_spec_like)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    residuals: Optional[Any] = None    # error-feedback state (compression)

    def tree_flatten(self):
        return (self.params, self.opt, self.residuals), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


# ---------------------------------------------------------------------------
# Step construction
# ---------------------------------------------------------------------------

def _split_microbatches(batch, m):
    def r(x):
        b = x.shape[0]
        assert b % m == 0, (b, m)
        return x.reshape(m, b // m, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(api: ModelApi, tcfg: TrainConfig, *,
                    mesh=None, compress: Optional[str] = None) -> Callable:
    """Returns ``step(state, batch) -> (state, metrics)`` (pure; jit outside).

    compress: None | "int8" — int8 error-feedback all-reduce over the batch
    axes (runs the reduction explicitly; requires grads to be DP-identical,
    i.e. it compresses the replica-mean — see grad_compression.py).
    """
    M = tcfg.microbatches
    fwd_kw: dict = {"remat": tcfg.remat}
    if tcfg.scan_group > 1:
        fwd_kw["scan_group"] = tcfg.scan_group
    if api.cfg.n_experts and mesh is not None:
        # MoE dispatch groups = batch shards: the (E, G, C, D) dispatch
        # buffer shards over the data axes instead of replicating (G=1
        # would leave the capacity buffer unshardable -> TB-scale
        # all-gathers on the 8-expert configs).
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) \
            if hasattr(mesh, "axis_sizes") else dict(mesh.shape)
        dp = 1
        for a in ("pod", "data"):
            dp *= sizes.get(a, 1)
        fwd_kw["n_groups"] = dp

    def loss_fn(params, mb):
        loss, aux = api.loss(params, mb, **fwd_kw)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if M <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        mbs = _split_microbatches(batch, M)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, aux), g = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, b: a + b, acc, g)
            return (acc, loss_acc + loss), aux

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), auxs = jax.lax.scan(body, (zeros, jnp.float32(0)), mbs)
        grads = jax.tree.map(lambda g: g / M, gsum)
        aux = jax.tree.map(lambda a: jnp.mean(a), auxs)
        return loss_sum / M, aux, grads

    batch_axes = tuple(a for a in ("pod", "data")
                       if mesh is not None and a in mesh.axis_names)

    def step(state: TrainState, batch):
        loss, aux, grads = compute_grads(state.params, batch)
        residuals = state.residuals
        if compress == "int8" and batch_axes:
            cpsum = gc.make_compressed_psum(batch_axes)

            def reduced(g, r):
                # grads out of pjit backward are already the replica mean;
                # re-quantizing and re-reducing the mean is the single-program
                # form of the wire-compression (see module docstring).
                return cpsum(g, r)

            grads, residuals = compat.shard_map(
                reduced, mesh=mesh,
                in_specs=(P(), P()), out_specs=(P(), P()))(grads, residuals)
        params, opt, stats = adamw_update(tcfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **stats,
                   **{k: v for k, v in aux.items()}}
        return TrainState(params=params, opt=opt, residuals=residuals), metrics

    return step


def state_shardings(api: ModelApi, mesh, state: TrainState):
    """NamedSharding pytree for TrainState on ``mesh`` (logical rules)."""
    pspec = api.param_spec()
    shapes = jax.eval_shape(lambda s: s, state)

    logical = {
        "params": pspec,
        "opt": opt_spec_like(pspec, use_master=state.opt.master is not None),
        "res": pspec if state.residuals is not None else None,
    }

    def build(log_tree, shape_tree):
        spec_tree = resolve_tree(log_tree, shape_tree, mesh)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)

    params_sh = build(logical["params"], shapes.params)
    mu_sh = build(logical["opt"]["mu"], shapes.opt.mu)
    nu_sh = build(logical["opt"]["nu"], shapes.opt.nu)
    master_sh = (build(logical["opt"]["master"], shapes.opt.master)
                 if state.opt.master is not None else None)
    res_sh = (build(logical["res"], shapes.residuals)
              if state.residuals is not None else None)
    opt_sh = AdamWState(step=NamedSharding(mesh, P()), mu=mu_sh, nu=nu_sh,
                        master=master_sh)
    return TrainState(params=params_sh, opt=opt_sh, residuals=res_sh)


def batch_shardings(mesh, batch_like):
    def one(x):
        spec = resolve(("batch",) + (None,) * (x.ndim - 1), x.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_like)


# ---------------------------------------------------------------------------
# The driver loop (host side): checkpointing, watchdog, restart
# ---------------------------------------------------------------------------

class Trainer:
    def __init__(self, api: ModelApi, tcfg: TrainConfig, *, mesh=None,
                 compress: Optional[str] = None, ckpt_manager=None):
        self.api = api
        self.tcfg = tcfg
        self.mesh = mesh
        self.compress = compress
        self.ckpt = ckpt_manager
        self._step_raw = make_train_step(api, tcfg, mesh=mesh,
                                         compress=compress)
        self._step_jit: Optional[Callable] = None
        self.data_step = 0          # resumable data-pipeline cursor

    # -- state ---------------------------------------------------------------
    def init_state(self, rng=None) -> TrainState:
        rng = jax.random.PRNGKey(self.tcfg.seed) if rng is None else rng
        params = self.api.init(rng)
        opt = adamw_init(params)
        res = (gc.init_residuals(params) if self.compress else None)
        return TrainState(params=params, opt=opt, residuals=res)

    def _jit_step(self, state: TrainState, batch):
        if self._step_jit is not None:
            return self._step_jit
        if self.mesh is not None:
            ssh = state_shardings(self.api, self.mesh, state)
            bsh = batch_shardings(self.mesh, batch)
            self._step_jit = jax.jit(self._step_raw,
                                     in_shardings=(ssh, bsh),
                                     out_shardings=(ssh, None),
                                     donate_argnums=(0,))
        else:
            self._step_jit = jax.jit(self._step_raw, donate_argnums=(0,))
        return self._step_jit

    # -- fault-tolerant loop ---------------------------------------------------
    def run(self, state: TrainState, data: Iterator, *, steps: int,
            start_step: int = 0, max_restarts: int = 3,
            fail_injector: Optional[Callable[[int], None]] = None
            ) -> tuple[TrainState, list[dict]]:
        """Run ``steps`` steps with checkpoint/restart fault tolerance.

        ``fail_injector(step)`` may raise to simulate node failure (tests).
        On failure: restore the latest checkpoint (possibly on a different
        mesh — checkpoints are layout-free) and continue. The data pipeline
        is step-indexed so replayed batches are identical.
        """
        history: list[dict] = []
        step = start_step
        restarts = 0
        while step < steps:
            try:
                batch = data(step) if callable(data) else next(data)
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.perf_counter()
                fn = self._jit_step(state, batch)
                state, metrics = fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                metrics.update(step=step, wall_s=dt)
                history.append(metrics)
                if (self.tcfg.step_deadline_s
                        and dt > self.tcfg.step_deadline_s):
                    log.warning("straggler: step %d took %.3fs > deadline %.3fs"
                                " — flagged for re-dispatch", step, dt,
                                self.tcfg.step_deadline_s)
                    history[-1]["straggler"] = True
                if self.ckpt is not None and self.tcfg.ckpt_every \
                        and (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, state, blocking=False)
                step += 1
            except KeyboardInterrupt:
                raise
            except Exception as e:  # node failure / preemption analogue
                restarts += 1
                if self.ckpt is None or restarts > max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring last checkpoint "
                            "(restart %d/%d)", step, e, restarts, max_restarts)
                self.ckpt.wait()
                restored, ckpt_step = self.ckpt.restore_latest(
                    like=state, mesh=self.mesh)
                if restored is None:      # no checkpoint yet: restart clean
                    state = self.init_state()
                    step = start_step
                else:
                    state = restored
                    step = ckpt_step
                self._step_jit = None     # mesh/layout may have changed
        if self.ckpt is not None:
            self.ckpt.wait()
        return state, history

"""AdamW with fp32 moments and optional fp32 master copies for bf16 params.

Hand-rolled (no optax dependency): the optimizer is part of the substrate the
assignment asks us to build. Moments are sharded exactly like their params
(the spec tree is reused leaf-for-leaf), so FSDP sharding of params gives
ZeRO-style sharded optimizer state for free under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@dataclasses.dataclass
class AdamWState:
    step: jax.Array            # int32 scalar
    mu: Any                    # pytree like params, f32
    nu: Any                    # pytree like params, f32
    master: Optional[Any]      # f32 master weights (None if params are f32)

    def tree_flatten(self):
        return (self.step, self.mu, self.nu, self.master), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState, AdamWState.tree_flatten, AdamWState.tree_unflatten)


def adamw_init(params, *, use_master: bool = True) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    needs_master = use_master and any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params))
    # copy=True: an f32 param would otherwise ALIAS its master copy and the
    # donated train step would donate the same buffer twice.
    master = (jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                           params)
              if needs_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def lr_schedule(cfg: TrainConfig, step) -> jax.Array:
    """Linear warmup then cosine decay to 10%."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: TrainConfig, params, grads, state: AdamWState):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = lr_schedule(cfg, step)
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    ref = state.master if state.master is not None else params

    def upd(p32, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        # no weight decay on 1-D leaves (norms/biases) — standard practice
        decay = wd if p32.ndim >= 2 else 0.0
        new_p = p32 - lr * (mhat / (jnp.sqrt(nhat) + eps) + decay * p32)
        return new_p, mu, nu

    flat_ref, treedef = jax.tree.flatten(ref)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    outs = [upd(p.astype(jnp.float32), g, m, n)
            for p, g, m, n in zip(flat_ref, flat_g, flat_mu, flat_nu)]
    new_ref = treedef.unflatten([o[0] for o in outs])
    new_mu = treedef.unflatten([o[1] for o in outs])
    new_nu = treedef.unflatten([o[2] for o in outs])

    if state.master is not None:
        new_params = jax.tree.map(lambda p, r: r.astype(p.dtype), params, new_ref)
        new_master = new_ref
    else:
        new_params = new_ref
        new_master = None
    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_spec_like(param_spec, *, use_master: bool = True):
    """Logical-axis spec tree for AdamWState mirroring the param spec."""
    return {
        "step": (),
        "mu": param_spec,
        "nu": param_spec,
        "master": param_spec if use_master else None,
    }

"""Minimal HLO-text parser.

Used by (1) core.payload — counting surviving noise ops (the paper's §2.3
static payload/overhead verification), and (2) roofline — summing collective
operand bytes and dot FLOPs with while-loop trip-count multipliers (XLA's
HloCostAnalysis counts loop bodies once; scanned-layer models need the
multiplier to report honest roofline terms).

The parser is deliberately text-based: it works on both ``lowered.as_text()``
(stable HLO -> HLO) and ``compiled.as_text()`` (optimized HLO), needs no XLA
internals, and is trivially portable across jax versions.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# Dims may be static (`128`), bounded-dynamic (`<=128`), or unbounded-
# dynamic (`?`) — all three print in XLA shape strings.
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,?<=]*)\]")
# `  %name = SHAPE opcode(...)` where SHAPE is a token or a (tuple, ...)
# possibly containing /*index=N*/ comments; lazy-match up to ` opcode(`.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(.+?)\s+"                        # shape (token or tuple, incl. comments)
    r"([a-z][\w\-]*)\(")               # opcode
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=")
# op_name extraction: scoped to the metadata={...} block when one is present
# (newer XLA emits multi-attribute blocks whose other values may themselves
# contain quoted strings), with escaped-quote tolerance in the value.
_METADATA_BLOCK_RE = re.compile(r"metadata=\{([^}]*)\}")
_METADATA_RE = re.compile(r'op_name="((?:[^"\\]|\\.)*)"')


def _dim_count(d: str) -> int:
    """One dim token -> element count: `<=N` uses the bound, `?` counts 1."""
    if d.startswith("<="):
        d = d[2:]
    return 1 if d == "?" else int(d)


def shape_bytes(shape: str) -> int:
    """Total bytes of an HLO shape string (tuples summed; bounded-dynamic
    dims ``<=N`` count their bound, unbounded ``?`` dims count 1)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= _dim_count(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dims(shape: str) -> list[tuple[str, tuple[int, ...]]]:
    """[(dtype, dims), ...] for each array in the shape string (dynamic
    dims resolved as in ``shape_bytes``)."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        tuple(_dim_count(d) for d in dims.split(",") if d)
                        if dims else ()))
    return out


def extract_op_name(line: str) -> str:
    """The metadata op_name of one instruction line ("" when absent).

    Searches inside the ``metadata={...}`` block when the line has one —
    multi-attribute blocks (``op_type=... op_name=... source_file=...``)
    from newer XLA otherwise risk matching an op_name-shaped substring in
    another attribute's value."""
    m = _METADATA_BLOCK_RE.search(line)
    md = _METADATA_RE.search(m.group(1) if m else line)
    return md.group(1) if md else ""


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shape: str                # result shape string
    line: str                 # raw line (operands, attrs, metadata)
    op_name: str = ""         # metadata op_name (named_scope path)
    shape_map: Optional[dict] = None   # module-wide name -> shape (shared)

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.shape)

    def _operand_span(self) -> str:
        """Text between the opcode's '(' and its matching ')'."""
        key = self.opcode + "("
        i = self.line.find(key)
        if i < 0:
            return ""
        j = i + len(key)
        depth = 1
        k = j
        while k < len(self.line) and depth:
            c = self.line[k]
            depth += (c == "(") - (c == ")")
            k += 1
        return self.line[j:k - 1]

    def operand_names(self) -> list[str]:
        return _OPERAND_NAME_RE.findall(self._operand_span())

    def operand_shapes(self) -> list[str]:
        """Operand shape strings. Optimized dumps print bare names
        (``dot(%a, %b)``) — resolved through the module shape map; lowered
        dumps print shapes inline — parsed directly."""
        span = self._operand_span()
        inline = [f"{d}[{dims}]" for d, dims in _SHAPE_RE.findall(span)]
        if inline:
            return inline
        if self.shape_map:
            return [self.shape_map[n] for n in self.operand_names()
                    if n in self.shape_map]
        return []


def parse_module(text: str) -> dict[str, list[Instr]]:
    """Split an HLO module dump into {computation_name: [Instr, ...]}."""
    comps: dict[str, list[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            # a header is `name (sig) -> ... {` and NOT an assignment — the
            # sig may contain `=` inside /*index=N*/ comments, so test for
            # the assignment form rather than for a bare `=`.
            if m and not _ASSIGN_RE.match(line):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode = m.groups()
            comps[cur].append(Instr(name=name, opcode=opcode, shape=shape,
                                    line=line, op_name=extract_op_name(line)))
    # module-wide name -> result shape map (operands print without shapes in
    # optimized dumps); parameters keep their declared shapes via their defs.
    shape_map: dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shape_map[ins.name] = ins.shape
    for instrs in comps.values():
        for ins in instrs:
            ins.shape_map = shape_map
    return comps


# ---------------------------------------------------------------------------
# While-loop trip counts
# ---------------------------------------------------------------------------

_CONST_RE = re.compile(r"constant\((\-?\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')


def _called_comp(instr: Instr, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", instr.line)
    return m.group(1) if m else None


def while_trip_counts(comps: dict[str, list[Instr]]) -> dict[str, int]:
    """Trip count per `while` instruction name.

    Primary source: XLA's own ``backend_config={"known_trip_count":{"n":N}}``
    (present on optimized scan/fori loops). Fallback: the canonical jax
    pattern — condition ``compare(iv, limit), direction=LT`` with a constant
    limit. Unrecognized loops map to 1 (conservative).
    """
    out: dict[str, int] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode != "while":
                continue
            trip = 1
            m = _TRIP_RE.search(ins.line)
            if m:
                trip = int(m.group(1))
            else:
                cond = _called_comp(ins, "condition")
                if cond and cond in comps:
                    consts = [int(x) for i in comps[cond]
                              for x in _CONST_RE.findall(i.line)]
                    cmp_ok = any(i.opcode == "compare" and "LT" in i.line
                                 for i in comps[cond])
                    if consts and cmp_ok:
                        trip = max(consts)
            out[ins.name] = max(trip, 1)
    return out


def nesting_multipliers(comps: dict[str, list[Instr]],
                        entry: str) -> dict[str, int]:
    """Execution-count multiplier for every computation, walking calls from
    ``entry``: while bodies multiply by trip count, fusions/calls by 1.
    """
    trips = while_trip_counts(comps)
    mult: dict[str, int] = {}

    def visit(cname: str, m: int):
        if cname not in comps:
            return
        mult[cname] = mult.get(cname, 0) + m
        for ins in comps[cname]:
            if ins.opcode == "while":
                t = trips.get(ins.name, 1)
                body = _called_comp(ins, "body")
                cond = _called_comp(ins, "condition")
                if body:
                    visit(body, m * t)
                if cond:
                    visit(cond, m * (t + 1))
            elif ins.opcode in ("fusion", "call", "map", "reduce",
                                "reduce-window", "scatter", "sort",
                                "conditional", "custom-call", "all-reduce",
                                "reduce-scatter", "select-and-scatter"):
                for key in ("calls", "to_apply", "body", "branch_computations",
                            "called_computations"):
                    sub = _called_comp(ins, key)
                    if sub:
                        visit(sub, m)
                # conditional: parse brace list {%a, %b}
                if ins.opcode == "conditional":
                    for mm in re.finditer(r"branch_computations=\{([^}]*)\}",
                                          ins.line):
                        for name in re.findall(r"%?([\w.\-]+)", mm.group(1)):
                            visit(name, m)

    visit(entry, 1)
    return mult


def find_entry(comps: dict[str, list[Instr]], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        return m.group(1)
    # fall back: computation that is not called anywhere
    called = set()
    for instrs in comps.values():
        for ins in instrs:
            for key in ("calls", "to_apply", "body", "condition"):
                c = _called_comp(ins, key)
                if c:
                    called.add(c)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))

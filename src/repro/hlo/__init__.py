from repro.hlo.parse import (  # noqa: F401
    Instr,
    extract_op_name,
    parse_module,
    shape_bytes,
    while_trip_counts,
)

from repro.hlo.parse import (  # noqa: F401
    Instr,
    parse_module,
    shape_bytes,
    while_trip_counts,
)

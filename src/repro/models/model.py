"""Unified model API: every architecture family exposes the same surface.

``build(cfg)`` returns a :class:`ModelApi` with

  init(rng)                      -> params
  forward(params, batch, **kw)   -> (logits, aux)          train / prefill
  loss(params, batch, **kw)      -> (scalar, aux)
  decode_init(params, batch|B,S) -> cache
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  param_spec()                   -> pytree of logical-axis tuples
  cache_spec(batch, max_seq)     -> logical spec for the decode cache
  input_specs(shape, mesh=None)  -> {name: ShapeDtypeStruct} (dry-run stand-ins)

The same object drives the trainer, the serving engine, the multi-pod dry-run
and the noise-injection probe, so the paper's technique applies uniformly to
every assigned architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as attn
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import layers as L
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    forward: Callable             # (params, batch, **kw) -> (logits, aux)
    decode_init: Callable         # (params, batch) -> cache
    decode_step: Callable         # (params, cache, tokens, pos) -> (logits, cache)
    param_spec: Callable          # () -> logical spec tree
    cache_spec: Callable          # () -> logical spec tree (mirrors decode cache)

    # ------------------------------------------------------------------
    def loss(self, params, batch, **kw):
        """Mean next-token NLL (+ MoE aux losses). Labels = batch['labels']."""
        logits, aux = self.forward(params, batch, **kw)
        # For VLM the image tokens are prepended; only score the text tail.
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, -labels.shape[1]:]
        nll = L.softmax_xent(logits, labels, batch.get("mask"))
        total = nll
        if aux:
            total = total + self.cfg.router_aux_coef * aux.get("moe_lb_loss", 0.0) \
                + 1e-3 * aux.get("moe_z_loss", 0.0)
        return total, dict(aux, nll=nll)

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, *, for_decode: Optional[bool] = None,
                    batch_override: Optional[int] = None) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for a (shape) cell — no allocation."""
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = shape.seq_len
        decode = shape.is_decode if for_decode is None else for_decode
        i32, bf16 = jnp.int32, jnp.dtype(cfg.compute_dtype)
        sd = jax.ShapeDtypeStruct
        if decode:
            return {"tokens": sd((B, 1), i32)}
        specs: dict[str, Any] = {
            "tokens": sd((B, S), i32),
            "labels": sd((B, S), i32),
        }
        if cfg.family == "encdec":
            specs["frames"] = sd((B, cfg.enc_frames, cfg.d_model), bf16)
        if cfg.family == "vlm":
            specs["img_embeds"] = sd((B, cfg.n_img_tokens, cfg.d_model), bf16)
        return specs

    def dummy_batch(self, shape: ShapeConfig, rng=None, **kw) -> dict[str, Any]:
        """Concrete random batch matching input_specs (CPU smoke / examples)."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        out = {}
        for k, sds in self.input_specs(shape, **kw).items():
            rng, sub = jax.random.split(rng)
            if jnp.issubdtype(sds.dtype, jnp.integer):
                out[k] = jax.random.randint(sub, sds.shape, 0, self.cfg.vocab_size,
                                            dtype=sds.dtype)
            else:
                out[k] = jax.random.normal(sub, sds.shape, jnp.float32).astype(sds.dtype)
        return out


# ---------------------------------------------------------------------------
# Family adapters
# ---------------------------------------------------------------------------

def _build_lm(cfg: ModelConfig) -> ModelApi:       # dense / moe / vlm
    def decode_init(params, batch):
        B = batch["tokens"].shape[0] if isinstance(batch, dict) else batch
        max_seq = batch.get("max_seq", cfg.window or 32768) if isinstance(batch, dict) \
            else (cfg.window or 32768)
        return tf.lm_decode_init(params, cfg, B, max_seq)

    return ModelApi(
        cfg=cfg,
        init=lambda rng: tf.init_lm(rng, cfg),
        forward=lambda p, b, **kw: tf.lm_forward(p, cfg, b, **kw),
        decode_init=decode_init,
        decode_step=lambda p, c, t, pos: tf.lm_decode_step(p, cfg, c, t, pos),
        param_spec=lambda: tf.spec_lm(cfg),
        cache_spec=lambda: tf.lm_cache_logical(cfg),
    )


def _build_ssm(cfg: ModelConfig) -> ModelApi:
    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        keys = jax.random.split(k2, cfg.n_layers)
        return {
            "embed": L.init_embedding(k1, cfg),
            "blocks": jax.vmap(lambda k: {
                "ln": L.init_rmsnorm(k, cfg.d_model, cfg),
                "ssm": ssm_mod.init_ssm(k, cfg)})(keys),
            "final_norm": L.init_rmsnorm(k3, cfg.d_model, cfg),
        }

    def param_spec():
        leaf = lambda x: isinstance(x, tuple) and all(  # noqa: E731
            isinstance(e, (str, type(None))) for e in x)
        blocks = jax.tree.map(lambda lg: (None,) + lg,
                              {"ln": L.spec_rmsnorm(), "ssm": ssm_mod.spec_ssm()},
                              is_leaf=leaf)
        return {"embed": L.spec_embedding(cfg), "blocks": blocks,
                "final_norm": L.spec_rmsnorm()}

    def forward(params, batch, *, remat="nothing", **_):
        h = L.embed(params["embed"], batch["tokens"], cfg)

        def body(hh, lp):
            hh = hh + ssm_mod.ssm_block(lp["ssm"], cfg,
                                        L.rmsnorm(lp["ln"], hh, cfg.norm_eps))
            return hh, None

        body_ck = jax.checkpoint(body, policy=tf.REMAT_POLICIES[remat],
                                 prevent_cse=False)
        h, _ = jax.lax.scan(body_ck, h, params["blocks"])
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["embed"], h, cfg), {}

    def decode_init(params, batch):
        B = batch["tokens"].shape[0] if isinstance(batch, dict) else batch
        sc = ssm_mod.init_ssm_cache(cfg, B)
        return {"ssm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), sc)}

    def cache_spec():
        leaf = lambda x: isinstance(x, tuple) and all(  # noqa: E731
            isinstance(e, (str, type(None))) for e in x)
        return {"ssm": jax.tree.map(lambda lg: (None,) + lg,
                                    ssm_mod.ssm_cache_logical(), is_leaf=leaf)}

    def decode_step(params, cache, tokens, pos):
        del pos  # SSM state is position-free
        h = L.embed(params["embed"], tokens, cfg)

        def body(hh, xs):
            lp, sc = xs
            out, new_sc = ssm_mod.ssm_decode_step(
                lp["ssm"], cfg, L.rmsnorm(lp["ln"], hh, cfg.norm_eps), sc)
            return hh + out, new_sc

        h, new_ssm = jax.lax.scan(body, h, (params["blocks"], cache["ssm"]))
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["embed"], h, cfg), {"ssm": new_ssm}

    return ModelApi(cfg=cfg, init=init, forward=forward, decode_init=decode_init,
                    decode_step=decode_step, param_spec=param_spec,
                    cache_spec=cache_spec)


def _build_hybrid(cfg: ModelConfig) -> ModelApi:
    def decode_init(params, batch):
        B = batch["tokens"].shape[0] if isinstance(batch, dict) else batch
        max_seq = batch.get("max_seq", 4096) if isinstance(batch, dict) else 4096
        return hybrid_mod.hybrid_decode_init(params, cfg, B, max_seq)

    return ModelApi(
        cfg=cfg,
        init=lambda rng: hybrid_mod.init_hybrid(rng, cfg),
        forward=lambda p, b, **kw: hybrid_mod.hybrid_forward(p, cfg, b, **kw),
        decode_init=decode_init,
        decode_step=lambda p, c, t, pos: hybrid_mod.hybrid_decode_step(p, cfg, c, t, pos),
        param_spec=lambda: hybrid_mod.spec_hybrid(cfg),
        cache_spec=lambda: hybrid_mod.hybrid_cache_logical(cfg),
    )


def _build_encdec(cfg: ModelConfig) -> ModelApi:
    def decode_init(params, batch):
        return encdec_mod.encdec_decode_init(params, cfg, batch)

    return ModelApi(
        cfg=cfg,
        init=lambda rng: encdec_mod.init_encdec(rng, cfg),
        forward=lambda p, b, **kw: encdec_mod.encdec_forward(p, cfg, b, **kw),
        decode_init=decode_init,
        decode_step=lambda p, c, t, pos: encdec_mod.encdec_decode_step(p, cfg, c, t, pos),
        param_spec=lambda: encdec_mod.spec_encdec(cfg),
        cache_spec=lambda: encdec_mod.encdec_cache_logical(cfg),
    )


_BUILDERS = {
    "dense": _build_lm,
    "moe": _build_lm,
    "vlm": _build_lm,
    "ssm": _build_ssm,
    "hybrid": _build_hybrid,
    "encdec": _build_encdec,
}


def build(cfg: ModelConfig) -> ModelApi:
    try:
        return _BUILDERS[cfg.family](cfg)
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None

"""Zamba2-style hybrid: a stack of Mamba2 blocks with ONE shared
attention+MLP block applied every ``attn_every`` layers (weight sharing).

The scan carries (h, attn-cache-stack); the shared block runs under lax.cond
inside the scan body so the HLO stays O(1) in depth with a single copy of the
attention graph. The per-invocation attention cache lives in a stacked buffer
(n_invocations, ...) indexed by layer//attn_every.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import REMAT_POLICIES

_SPEC_LEAF = lambda x: isinstance(x, tuple) and all(  # noqa: E731
    isinstance(e, (str, type(None))) for e in x)


def n_invocations(cfg):
    return -(-cfg.n_layers // cfg.attn_every)


def init_hybrid(rng, cfg):
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    mamba_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": L.init_embedding(k1, cfg),
        "mamba": jax.vmap(lambda k: {
            "ln": L.init_rmsnorm(k, cfg.d_model, cfg),
            "ssm": ssm.init_ssm(k, cfg)})(mamba_keys),
        "shared": {
            "ln1": L.init_rmsnorm(k3, cfg.d_model, cfg),
            "attn": attn.init_attention(k4, cfg),
            "ln2": L.init_rmsnorm(k5, cfg.d_model, cfg),
            "mlp": L.init_mlp(k5, cfg),
        },
        "final_norm": L.init_rmsnorm(k6, cfg.d_model, cfg),
    }


def spec_hybrid(cfg):
    mamba = jax.tree.map(lambda lg: (None,) + lg,
                         {"ln": L.spec_rmsnorm(), "ssm": ssm.spec_ssm()},
                         is_leaf=_SPEC_LEAF)
    return {
        "embed": L.spec_embedding(cfg),
        "mamba": mamba,
        "shared": {"ln1": L.spec_rmsnorm(), "attn": attn.spec_attention(),
                   "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp()},
        "final_norm": L.spec_rmsnorm(),
    }


def _shared_block(sp, cfg, h, positions):
    a = attn.attn_train(sp["attn"], cfg, L.rmsnorm(sp["ln1"], h, cfg.norm_eps),
                        positions, causal=True)
    h = h + a
    h = h + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], h, cfg.norm_eps), cfg)
    return h


def hybrid_forward(params, cfg, batch, *, remat="nothing", **_):
    h = L.embed(params["embed"], batch["tokens"], cfg)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    sp = params["shared"]

    def body(carry, xs):
        hh = carry
        idx, lp = xs
        hh = jax.lax.cond(idx % cfg.attn_every == 0,
                          lambda x: _shared_block(sp, cfg, x, positions),
                          lambda x: x, hh)
        hh = hh + ssm.ssm_block(lp["ssm"], cfg,
                                L.rmsnorm(lp["ln"], hh, cfg.norm_eps))
        return hh, None

    body_ck = jax.checkpoint(body, policy=REMAT_POLICIES[remat], prevent_cse=False)
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    h, _ = jax.lax.scan(body_ck, h, (idxs, params["mamba"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.unembed(params["embed"], h, cfg), {}


def hybrid_decode_init(params, cfg, batch_size, max_seq):
    del params
    sc = ssm.init_ssm_cache(cfg, batch_size)
    states = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), sc)
    ac = attn.init_cache(cfg, batch_size, max_seq)
    kv = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_invocations(cfg),) + x.shape).copy(), ac)
    return {"ssm": states, "kv": kv}


def hybrid_cache_logical(cfg):
    del cfg
    stack = lambda s: jax.tree.map(lambda lg: (None,) + lg, s, is_leaf=_SPEC_LEAF)
    return {"ssm": stack(ssm.ssm_cache_logical()),
            "kv": stack(attn.cache_logical())}


def hybrid_decode_step(params, cfg, cache, tokens, pos):
    h = L.embed(params["embed"], tokens, cfg)
    sp = params["shared"]

    def body(carry, xs):
        hh, kv_stack = carry
        idx, lp, sc = xs

        def with_attn(args):
            x, kvs = args
            inv = idx // cfg.attn_every
            c = jax.tree.map(lambda b: jax.lax.dynamic_index_in_dim(
                b, inv, axis=0, keepdims=False), kvs)
            a, c = attn.attn_decode(sp["attn"], cfg,
                                    L.rmsnorm(sp["ln1"], x, cfg.norm_eps), c, pos)
            x = x + a
            x = x + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps), cfg)
            kvs = jax.tree.map(
                lambda b, u: jax.lax.dynamic_update_index_in_dim(b, u, inv, axis=0),
                kvs, c)
            return x, kvs

        hh, kv_stack = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                    lambda args: args, (hh, kv_stack))
        out, new_sc = ssm.ssm_decode_step(lp["ssm"], cfg,
                                          L.rmsnorm(lp["ln"], hh, cfg.norm_eps), sc)
        return (hh + out, kv_stack), new_sc

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (h, kv), new_ssm = jax.lax.scan(body, (h, cache["kv"]),
                                    (idxs, params["mamba"], cache["ssm"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.unembed(params["embed"], h, cfg), {"ssm": new_ssm, "kv": kv}

"""Decoder-only LM assembly (dense / MoE / VLM) with jax.lax.scan over layers
(O(1) HLO in depth — required to compile 88-layer configs) and remat policies.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.parallel.sharding import constrain

REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "full": jax.checkpoint_policies.everything_saveable,
}


def _is_moe(cfg):
    return cfg.n_experts > 0


def init_layer(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(k1, cfg.d_model, cfg),
        "attn": attn.init_attention(k2, cfg),
        "ln2": L.init_rmsnorm(k3, cfg.d_model, cfg),
    }
    if _is_moe(cfg):
        p["moe"] = moe_mod.init_moe(k4, cfg)
    else:
        p["mlp"] = L.init_mlp(k4, cfg)
    return p


def spec_layer(cfg):
    s = {
        "ln1": L.spec_rmsnorm(),
        "attn": attn.spec_attention(),
        "ln2": L.spec_rmsnorm(),
    }
    if _is_moe(cfg):
        s["moe"] = moe_mod.spec_moe()
    else:
        s["mlp"] = L.spec_mlp()
    return s


def layer_fwd(p, cfg, h, positions, *, n_groups=1):
    """One transformer block (train/prefill). Returns (h, aux)."""
    a = attn.attn_train(p["attn"], cfg, L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                        positions, causal=True, window=cfg.window)
    h = h + a
    x = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if _is_moe(cfg):
        y, aux = moe_mod.moe_block(p["moe"], cfg, x, n_groups=n_groups)
    else:
        y, aux = L.mlp(p["mlp"], x, cfg), {}
    return h + y, aux


def layer_decode(p, cfg, h, cache, pos, *, page_table=None):
    a, cache = attn.attn_decode(p["attn"], cfg,
                                L.rmsnorm(p["ln1"], h, cfg.norm_eps), cache, pos,
                                page_table=page_table)
    h = h + a
    x = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if _is_moe(cfg):
        y, _ = moe_mod.moe_block(p["moe"], cfg, x, n_groups=1)
    else:
        y = L.mlp(p["mlp"], x, cfg)
    return h + y, cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------

def init_lm(rng, cfg):
    k_emb, k_layers, k_fn = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": L.init_rmsnorm(k_fn, cfg.d_model, cfg),
    }


def spec_lm(cfg):
    layer = spec_layer(cfg)
    stacked = jax.tree.map(
        lambda lg: (None,) + lg, layer,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return {
        "embed": L.spec_embedding(cfg),
        "layers": stacked,
        "final_norm": L.spec_rmsnorm(),
    }


def _embed_inputs(params, cfg, batch):
    """tokens (+img_embeds for VLM) -> h (B,S,D), positions (S,), loss offset."""
    h = L.embed(params["embed"], batch["tokens"], cfg)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(h.dtype)
        img = constrain(img, "batch", "seq", "d_model")
        h = jnp.concatenate([img, h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    return h, positions


def lm_forward(params, cfg, batch, *, remat="nothing", n_groups=1,
               return_cache=False, scan_group=1):
    """-> (logits (B,S,V), aux). aux holds MoE losses (mean over layers).

    With return_cache: also returns per-layer KV caches stacked (L, ...) laid
    out for decode (prefill path).

    scan_group=g > 1 scans over L/g groups of g layers per checkpointed body:
    saved residual carries drop g× (recompute grows g×) — the activation-
    memory knob for the deepest configs (mistral-large-123b)."""
    h, positions, = _embed_inputs(params, cfg, batch)

    if scan_group > 1 and not return_cache:
        assert cfg.n_layers % scan_group == 0, (cfg.n_layers, scan_group)
        grouped = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers // scan_group, scan_group)
                                + x.shape[1:]), params["layers"])

        def gbody(carry, lp_group):
            hh = carry
            auxs = []
            for j in range(scan_group):
                lp = jax.tree.map(lambda x: x[j], lp_group)
                hh, aux = layer_fwd(lp, cfg, hh, positions, n_groups=n_groups)
                auxs.append(aux)
            aux = ({k: sum(a[k] for a in auxs) / scan_group
                    for k in auxs[0]} if auxs[0] else {})
            return hh, aux

        gbody_ck = jax.checkpoint(gbody, policy=REMAT_POLICIES[remat],
                                  prevent_cse=False)
        h, ys = jax.lax.scan(gbody_ck, h, grouped)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["embed"], h, cfg)
        aux = {k: jnp.mean(v) for k, v in ys.items()} if ys else {}
        return logits, aux

    def body(carry, lp):
        hh = carry
        if return_cache:
            out, kv = attn.attn_train(
                lp["attn"], cfg, L.rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                positions, causal=True, window=cfg.window, return_cache=True)
            hh = hh + out
            x = L.rmsnorm(lp["ln2"], hh, cfg.norm_eps)
            if _is_moe(cfg):
                y, aux = moe_mod.moe_block(lp["moe"], cfg, x, n_groups=n_groups)
            else:
                y, aux = L.mlp(lp["mlp"], x, cfg), {}
            return hh + y, (aux, kv)
        hh, aux = layer_fwd(lp, cfg, hh, positions, n_groups=n_groups)
        return hh, aux

    policy = REMAT_POLICIES[remat]
    body_ck = jax.checkpoint(body, policy=policy, prevent_cse=False)
    h, ys = jax.lax.scan(body_ck, h, params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h, cfg)
    if return_cache:
        aux_l, kv = ys
        aux = {k: jnp.mean(v) for k, v in aux_l.items()} if aux_l else {}
        return logits, aux, kv
    aux = {k: jnp.mean(v) for k, v in ys.items()} if ys else {}
    return logits, aux


def lm_decode_init(params, cfg, batch_size, max_seq):
    del params
    cache = attn.init_cache(cfg, batch_size, max_seq)
    return {
        "kv": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), cache),
    }


def lm_cache_logical(cfg):
    kv = jax.tree.map(
        lambda lg: (None,) + lg, attn.cache_logical(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    if cfg.window:  # ring cache has kpos (S,) per layer
        kv = dict(kv, kpos=(None, "cache_seq"))
    return {"kv": kv}


def lm_prefill(params, cfg, batch, max_seq):
    """Full-sequence prefill -> (logits (B,S,V), decode cache padded to
    ``max_seq``). Serving fast path for dense/moe/vlm families."""
    logits, _aux, kv = lm_forward(params, cfg, batch, return_cache=True)
    B = kv["k"].shape[1]
    kh, hd = cfg.n_kv_heads, cfg.head_dim

    def pad(x):  # (L,B,Kh,S,hd) -> (L,B,Kh,max_seq,hd)
        L, b, h, S, d = x.shape
        buf = jnp.zeros((L, b, h, max_seq, d), x.dtype)
        return jax.lax.dynamic_update_slice(buf, x, (0, 0, 0, 0, 0))

    del kh, hd
    cache = {"kv": {"k": pad(kv["k"]), "v": pad(kv["v"])}}
    return logits, cache


def lm_decode_step(params, cfg, cache, tokens, pos):
    """tokens (B,1) -> (logits (B,1,V), new cache). pos: scalar int32."""
    h = L.embed(params["embed"], tokens, cfg)

    def body(carry, xs):
        hh = carry
        lp, c = xs
        hh, c = layer_decode(lp, cfg, hh, c, pos)
        return hh, c

    h, new_kv = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h, cfg)
    return logits, {"kv": new_kv}


# ---------------------------------------------------------------------------
# Paged serving path (models/attention.py paged layout)
# ---------------------------------------------------------------------------

def lm_paged_decode_init(params, cfg, n_pages, page_size):
    """Per-layer page pools stacked (L, P, Kh, page, hd). The page table is
    NOT part of the cache: slot->page assignment is a host (engine) decision
    and is passed into each decode step as a plain operand."""
    del params
    pool = attn.init_paged_cache(cfg, n_pages, page_size)
    return {"kv": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), pool)}


def lm_paged_cache_logical(cfg):
    if cfg.window:
        raise NotImplementedError("paged KV cache needs window=0")
    kv = jax.tree.map(
        lambda lg: (None,) + lg, attn.cache_logical(paged=True),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return {"kv": kv}


def lm_paged_prefill(params, cfg, batch, cache, page_rows):
    """Batched prefill of a whole admission wave, scattered into the pool.

    batch {"tokens": (B, Sp)} — B admitted prompts right-padded to a common
    Sp (a multiple of the page size); page_rows (B, Sp // page) pool page
    ids covering each prompt's padded extent (padding garbage lands on pages
    the slot owns at positions beyond its length, masked until decode
    overwrites them — non-admitted rows point every entry at a trash page).
    Returns (logits (B, Sp, V), new cache).
    """
    logits, _aux, kv = lm_forward(params, cfg, batch, return_cache=True)

    def scat(c, k, v):
        return attn.paged_prefill_scatter(c, {"k": k, "v": v}, page_rows)

    # one vmapped scatter over the layer axis: kv (L,B,Kh,Sp,hd) -> pool
    new_kv = jax.vmap(scat)(cache["kv"], kv["k"], kv["v"])
    return logits, {"kv": new_kv}


def lm_paged_decode_step(params, cfg, cache, tokens, pos, page_table):
    """tokens (B,1), pos (B,), page_table (B, max_pages) ->
    (logits (B,1,V), new cache). The table is scan-invariant: every layer
    reads the same slot->page mapping."""
    h = L.embed(params["embed"], tokens, cfg)

    def body(carry, xs):
        hh = carry
        lp, c = xs
        hh, c = layer_decode(lp, cfg, hh, c, pos, page_table=page_table)
        return hh, c

    h, new_kv = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h, cfg)
    return logits, {"kv": new_kv}

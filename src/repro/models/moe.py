"""Mixture-of-Experts: top-k routing with sort-based, capacity-bounded dispatch.

Dispatch is *group-local* (one group per data shard), then the dispatched
buffer is resharded from group-parallel to expert-parallel — GSPMD turns that
constraint flip into the canonical MoE all-to-all. Expert FFNs run as batched
einsums with experts sharded over `model` when the expert count divides it
(qwen3: 128/16), and tensor-parallel inside experts otherwise (mixtral: 8
experts, shard d_ff). No one-hot dispatch einsums: dispatch is gather/scatter,
so HLO FLOPs ≈ active-expert FLOPs (honest roofline accounting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _act, _normal, cdtype_of, dtype_of
from repro.parallel.sharding import constrain


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "router": _normal(k1, (d, e), d ** -0.5, jnp.float32),
        "w_gate": _normal(k2, (e, d, f), d ** -0.5, dt),
        "w_up": _normal(k3, (e, d, f), d ** -0.5, dt),
        "w_down": _normal(k4, (e, f, d), f ** -0.5, dt),
    }


def spec_moe():
    return {
        "router": (None, None),
        "w_gate": ("experts", "fsdp", "expert_ff"),
        "w_up": ("experts", "fsdp", "expert_ff"),
        "w_down": ("experts", "expert_ff", "fsdp"),
    }


def _capacity(tokens_per_group, cfg):
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def _group_dispatch(x_g, eidx_g, cfg, capacity):
    """x_g (Tg,D); eidx_g (Tg,k) -> buf (E,C,D), slots (Tg,k) slot-in-expert."""
    Tg, k = eidx_g.shape
    flat_e = eidx_g.reshape(-1)                      # (Tg*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=cfg.n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(Tg * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    # slot for original (t, j): invert the permutation
    slots = jnp.zeros((Tg * k,), jnp.int32).at[order].set(pos_sorted).reshape(Tg, k)
    tok_of = order // k                              # token index per sorted entry
    buf = jnp.zeros((cfg.n_experts, capacity, x_g.shape[-1]), x_g.dtype)
    buf = buf.at[sorted_e, pos_sorted].set(x_g[tok_of], mode="drop")
    return buf, slots


def _group_combine(out_buf, eidx_g, slots, gates_g, capacity):
    """out_buf (E,C,D) -> y (Tg,D) weighted by gates; dropped slots -> 0."""
    dropped = slots >= capacity
    gathered = out_buf[eidx_g, jnp.minimum(slots, capacity - 1)]  # (Tg,k,D)
    w = jnp.where(dropped, 0.0, gates_g).astype(gathered.dtype)
    return jnp.einsum("tkd,tk->td", gathered, w)


def moe_block(p, cfg, x, n_groups=1):
    """x (B,S,D) -> (y (B,S,D), aux_losses dict)."""
    B, S, D = x.shape
    cd = cdtype_of(cfg)
    T = B * S
    G = n_groups if T % n_groups == 0 else 1
    Tg = T // G
    C = _capacity(Tg, cfg)

    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)                        # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux losses: load balance (Switch) + router z-loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
    ce = jax.lax.stop_gradient(ce / (T * cfg.top_k))
    lb_loss = cfg.n_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    xg = constrain(xf.reshape(G, Tg, D), "batch", None, None)
    eg = eidx.reshape(G, Tg, cfg.top_k)
    buf, slots = jax.vmap(lambda a, b: _group_dispatch(a, b, cfg, C))(xg, eg)
    # (G,E,C,D) group-parallel -> expert-parallel: the MoE all-to-all
    buf = constrain(buf.transpose(1, 0, 2, 3), "experts", "batch", None, None)

    def ffn(w_gate, w_up, w_down, h):
        # Pre-gather the FSDP-sharded weights (d_model dim) BEFORE the
        # contraction: the alternative GSPMD schedule — all-reducing the
        # (E,G,C,ff) activation partial sums over the data axis — costs
        # ~300x more wire (measured: 10-14 TB/chip/step on the MoE train
        # cells; EXPERIMENTS.md §Perf). Weight shards are tiny; activations
        # are not.
        w_gate = constrain(w_gate.astype(cd), "experts", None, "expert_ff")
        w_up = constrain(w_up.astype(cd), "experts", None, "expert_ff")
        w_down = constrain(w_down.astype(cd), "experts", "expert_ff", None)
        g = jnp.einsum("egcd,edf->egcf", h, w_gate)
        u = jnp.einsum("egcd,edf->egcf", h, w_up)
        a = constrain(_act(cfg.act, g) * u, "experts", "batch", None, "expert_ff")
        return jnp.einsum("egcf,efd->egcd", a, w_down)

    out = ffn(p["w_gate"], p["w_up"], p["w_down"], buf)
    out = constrain(out.transpose(1, 0, 2, 3), "batch", "experts", None, None)  # back
    yg = jax.vmap(lambda ob, e, s, g: _group_combine(ob, e, s, g, C))(
        out, eg, slots, gates.reshape(G, Tg, cfg.top_k))
    y = constrain(yg.reshape(B, S, D), "batch", "seq", "d_model")
    return y, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}

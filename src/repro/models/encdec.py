"""Whisper-style encoder-decoder. The conv/log-mel frontend is a STUB per the
assignment: inputs are precomputed frame embeddings (B, F, d_model).

Encoder: bidirectional self-attention blocks (scanned).
Decoder: causal self-attention + cross-attention + MLP (scanned).
Decode state: per-layer self KV cache + precomputed cross K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models.transformer import REMAT_POLICIES

_SPEC_LEAF = lambda x: isinstance(x, tuple) and all(  # noqa: E731
    isinstance(e, (str, type(None))) for e in x)


def _stack_spec(spec):
    return jax.tree.map(lambda lg: (None,) + lg, spec, is_leaf=_SPEC_LEAF)


def init_enc_layer(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"ln1": L.init_rmsnorm(k1, cfg.d_model, cfg),
            "attn": attn.init_attention(k2, cfg),
            "ln2": L.init_rmsnorm(k3, cfg.d_model, cfg),
            "mlp": L.init_mlp(k4, cfg)}


def spec_enc_layer():
    return {"ln1": L.spec_rmsnorm(), "attn": attn.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp()}


def init_dec_layer(key, cfg):
    ks = jax.random.split(key, 6)
    return {"ln1": L.init_rmsnorm(ks[0], cfg.d_model, cfg),
            "attn": attn.init_attention(ks[1], cfg),
            "lnx": L.init_rmsnorm(ks[2], cfg.d_model, cfg),
            "xattn": attn.init_cross_attention(ks[3], cfg),
            "ln2": L.init_rmsnorm(ks[4], cfg.d_model, cfg),
            "mlp": L.init_mlp(ks[5], cfg)}


def spec_dec_layer():
    return {"ln1": L.spec_rmsnorm(), "attn": attn.spec_attention(),
            "lnx": L.spec_rmsnorm(), "xattn": attn.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp()}


def init_encdec(rng, cfg):
    ke, kd, k1, k2, k3 = jax.random.split(rng, 5)
    return {
        "embed": L.init_embedding(k1, cfg),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(
            jax.random.split(ke, cfg.enc_layers)),
        "enc_norm": L.init_rmsnorm(k2, cfg.d_model, cfg),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(
            jax.random.split(kd, cfg.n_layers)),
        "final_norm": L.init_rmsnorm(k3, cfg.d_model, cfg),
    }


def spec_encdec(cfg):
    return {
        "embed": L.spec_embedding(cfg),
        "enc_layers": _stack_spec(spec_enc_layer()),
        "enc_norm": L.spec_rmsnorm(),
        "dec_layers": _stack_spec(spec_dec_layer()),
        "final_norm": L.spec_rmsnorm(),
    }


def encode(params, cfg, frames, *, remat="nothing"):
    """frames (B,F,D) stub embeddings -> encoder states (B,F,D)."""
    h = frames.astype(L.cdtype_of(cfg))
    F = h.shape[1]
    positions = jnp.arange(F, dtype=jnp.int32)

    def body(hh, lp):
        a = attn.attn_train(lp["attn"], cfg, L.rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                            positions, causal=False)
        hh = hh + a
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], hh, cfg.norm_eps), cfg)
        return hh, None

    body_ck = jax.checkpoint(body, policy=REMAT_POLICIES[remat], prevent_cse=False)
    h, _ = jax.lax.scan(body_ck, h, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def decoder_forward(params, cfg, tokens, enc_out, *, remat="nothing"):
    h = L.embed(params["embed"], tokens, cfg)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(hh, lp):
        a = attn.attn_train(lp["attn"], cfg, L.rmsnorm(lp["ln1"], hh, cfg.norm_eps),
                            positions, causal=True)
        hh = hh + a
        ckv = attn.cross_kv(lp["xattn"], cfg, enc_out)
        x = attn.attn_cross(lp["xattn"], cfg,
                            L.rmsnorm(lp["lnx"], hh, cfg.norm_eps), ckv)
        hh = hh + x
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], hh, cfg.norm_eps), cfg)
        return hh, None

    body_ck = jax.checkpoint(body, policy=REMAT_POLICIES[remat], prevent_cse=False)
    h, _ = jax.lax.scan(body_ck, h, params["dec_layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.unembed(params["embed"], h, cfg)


def encdec_forward(params, cfg, batch, *, remat="nothing", **_):
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    logits = decoder_forward(params, cfg, batch["tokens"], enc_out, remat=remat)
    return logits, {}


def encdec_decode_init(params, cfg, batch):
    """Runs the encoder; precomputes cross K/V; allocates self caches.

    batch: {"frames": (B,F,D)}; max_seq passed via batch["max_seq"] int."""
    frames = batch["frames"]
    max_seq = batch["max_seq"]
    enc_out = encode(params, cfg, frames)
    ckv = jax.vmap(lambda lp: attn.cross_kv(lp, cfg, enc_out))(params["dec_layers"]["xattn"])
    self_cache = attn.init_cache(cfg, frames.shape[0], max_seq)
    kv = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), self_cache)
    return {"kv": kv, "cross": ckv}


def encdec_cache_logical(cfg):
    del cfg
    kv = _stack_spec(attn.cache_logical())
    cross = _stack_spec({"ck": ("cache_batch", "cache_kv_heads", None, None),
                         "cv": ("cache_batch", "cache_kv_heads", None, None)})
    return {"kv": kv, "cross": cross}


def encdec_decode_step(params, cfg, cache, tokens, pos):
    h = L.embed(params["embed"], tokens, cfg)

    def body(hh, xs):
        lp, c, ckv = xs
        a, c = attn.attn_decode(lp["attn"], cfg,
                                L.rmsnorm(lp["ln1"], hh, cfg.norm_eps), c, pos)
        hh = hh + a
        x = attn.attn_cross(lp["xattn"], cfg,
                            L.rmsnorm(lp["lnx"], hh, cfg.norm_eps), ckv)
        hh = hh + x
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], hh, cfg.norm_eps), cfg)
        return hh, c

    h, new_kv = jax.lax.scan(body, h, (params["dec_layers"], cache["kv"], cache["cross"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.unembed(params["embed"], h, cfg), {"kv": new_kv, "cross": cache["cross"]}

from repro.models.model import ModelApi, build  # noqa: F401

"""Shared building blocks: norms, rope, embeddings, gated MLPs.

Every ``init_*`` has a paired ``spec_*`` returning the SAME tree structure with
logical-axis tuples as leaves (resolved by repro.parallel.sharding.resolve).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain


def dtype_of(cfg):
    return jnp.dtype(cfg.param_dtype)


def cdtype_of(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------------

def init_rmsnorm(key, dim, cfg):
    del key
    return {"scale": jnp.ones((dim,), dtype_of(cfg))}


def spec_rmsnorm():
    return {"scale": (None,)}


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope_angles(positions, head_dim, theta):
    """positions: int array (...,) -> (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (S, D//2), (B, S, D//2) (per-example
    positions, continuous batching) or broadcastable (..., S, 1, D//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim in (x1.ndim - 2, x1.ndim - 1):  # insert the head axis
        cos, sin = cos[..., None, :], sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def init_embedding(key, cfg):
    p = {"table": _normal(key, (cfg.vocab_size, cfg.d_model), 0.02, dtype_of(cfg))}
    if not cfg.tie_embeddings:
        p["head"] = _normal(jax.random.fold_in(key, 1),
                            (cfg.d_model, cfg.vocab_size),
                            cfg.d_model ** -0.5, dtype_of(cfg))
    return p


def spec_embedding(cfg):
    s = {"table": ("vocab", "fsdp")}
    if not cfg.tie_embeddings:
        s["head"] = ("fsdp", "vocab")
    return s


def embed(p, tokens, cfg):
    h = jnp.take(p["table"], tokens, axis=0).astype(cdtype_of(cfg))
    return constrain(h, "batch", "seq", "d_model")


def unembed(p, h, cfg):
    table = p["head"] if "head" in p else p["table"].T
    logits = jnp.einsum("bsd,dv->bsv", h, table.astype(cdtype_of(cfg)))
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, "batch", "seq", "vocab")


# ----------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ----------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _normal(k1, (d, f), d ** -0.5, dtype_of(cfg)),
        "w_up": _normal(k2, (d, f), d ** -0.5, dtype_of(cfg)),
        "w_down": _normal(k3, (f, d), f ** -0.5, dtype_of(cfg)),
    }


def spec_mlp():
    return {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"), "w_down": ("ff", "fsdp")}


def _act(name, x):
    if name == "geglu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)  # swiglu


def mlp(p, x, cfg):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    hidden = _act(cfg.act, g) * u
    hidden = constrain(hidden, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", hidden, p["w_down"])
    return constrain(out, "batch", "seq", "d_model")


# ----------------------------------------------------------------------------
# Cross-entropy (fp32, vocab-sharded safe)
# ----------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """logits (B,S,V), labels (B,S) int32, mask (B,S) 1=count. Returns mean nll."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)

"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD for train/prefill: intra-chunk quadratic attention-like term +
inter-chunk state recurrence (associative scan). O(1)-state decode step.
All recurrence math in fp32. A reference sequential-recurrence oracle lives in
tests (and kernels/ref.py) — the chunked form must match it.

Layout: x heads (B, S, nh, hp); B/C (B, S, ng, N); state (B, nh, hp, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, cdtype_of, dtype_of, rmsnorm
from repro.parallel.sharding import constrain


def init_ssm(key, cfg):
    d, di = cfg.d_model, cfg.d_inner
    nh, N, ng = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_ngroups
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    conv_ch = di + 2 * ng * N
    return {
        "w_x": _normal(ks[0], (d, di), d ** -0.5, dt),
        "w_z": _normal(ks[1], (d, di), d ** -0.5, dt),
        "w_B": _normal(ks[2], (d, ng * N), d ** -0.5, dt),
        "w_C": _normal(ks[3], (d, ng * N), d ** -0.5, dt),
        "w_dt": _normal(ks[4], (d, nh), d ** -0.5, dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": _normal(ks[5], (cfg.ssm_conv, conv_ch), 0.5, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "norm": jnp.ones((di,), dt),
        "w_out": _normal(ks[6], (di, d), di ** -0.5, dt),
    }


def spec_ssm():
    return {
        "w_x": ("fsdp", "ssm_inner"), "w_z": ("fsdp", "ssm_inner"),
        "w_B": ("fsdp", None), "w_C": ("fsdp", None),
        "w_dt": ("fsdp", "ssm_heads"),
        "dt_bias": ("ssm_heads",), "A_log": ("ssm_heads",), "D_skip": ("ssm_heads",),
        "conv_w": (None, None), "conv_b": (None,),
        "norm": ("ssm_inner",), "w_out": ("ssm_inner", "fsdp"),
    }


def _causal_conv(xbc, conv_w, conv_b, buf=None):
    """Depthwise causal conv, width K. xbc (B,S,Ch). buf (B,K-1,Ch) history for
    decode; returns (y, new_buf)."""
    K = conv_w.shape[0]
    if buf is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = buf.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, Ch)
    y = sum(full[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :]
            for i in range(K))
    y = y + conv_b[None, None, :]
    new_buf = full[:, -(K - 1):, :]
    return jax.nn.silu(y), new_buf


def _split_heads(cfg, xc, Bc, Cc):
    B, S = xc.shape[:2]
    nh, hp, ng, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    x = xc.reshape(B, S, nh, hp)
    Bm = Bc.reshape(B, S, ng, N)
    Cm = Cc.reshape(B, S, ng, N)
    return x, Bm, Cm


def _proj_inputs(p, cfg, h, conv_buf=None):
    cd = cdtype_of(cfg)
    z = jnp.einsum("bsd,de->bse", h, p["w_z"].astype(cd))
    xc = jnp.einsum("bsd,de->bse", h, p["w_x"].astype(cd))
    Bc = jnp.einsum("bsd,de->bse", h, p["w_B"].astype(cd))
    Cc = jnp.einsum("bsd,de->bse", h, p["w_C"].astype(cd))
    dt = jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(cd))
    xbc = jnp.concatenate([xc, Bc, Cc], axis=-1)
    xbc, new_buf = _causal_conv(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                                conv_buf)
    di, ngN = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    xc, Bc, Cc = xbc[..., :di], xbc[..., di:di + ngN], xbc[..., di + ngN:]
    x, Bm, Cm = _split_heads(cfg, xc, Bc, Cc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    x = constrain(x, "batch", "seq", "ssm_heads", None)
    dt = constrain(dt, "batch", "seq", "ssm_heads")
    z = constrain(z, "batch", "seq", "ssm_inner")
    return x, Bm, Cm, dt, z, new_buf


def _gated_out(p, cfg, y, z):
    """y (B,S,nh,hp) -> out (B,S,D): gated RMSNorm then out-proj."""
    B, S = y.shape[:2]
    yf = y.reshape(B, S, cfg.d_inner)
    yf = yf * jax.nn.silu(z.astype(yf.dtype))
    yf = rmsnorm({"scale": p["norm"]}, yf.astype(cdtype_of(cfg)), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", yf, p["w_out"].astype(cdtype_of(cfg)))
    return constrain(out, "batch", "seq", "d_model")


def ssd_chunked(cfg, x, Bm, Cm, dt, A, init_state=None):
    """Chunked SSD. x (B,S,nh,hp) f32-castable; returns (y, final_state).

    Recurrence (per head h, state S_t of shape (hp,N)):
      S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t ⊗ B_t ;  y_t = S_t · C_t + D x_t
    (the D-skip is applied by the caller).
    """
    Bb, S, nh, hp = x.shape
    ng, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32
    xc = x.astype(f32).reshape(Bb, nc, Q, nh, hp)
    Bc = Bm.astype(f32).reshape(Bb, nc, Q, ng, N)
    Cc = Cm.astype(f32).reshape(Bb, nc, Q, ng, N)
    dtc = dt.astype(f32).reshape(Bb, nc, Q, nh)

    dA = dtc * A[None, None, None, :]               # (B,nc,Q,nh) (negative)
    cum = jnp.cumsum(dA, axis=2)                    # inclusive cumsum within chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,nh) = cum_i - cum_j
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # Clamp BEFORE exp: masked (i<j) entries have seg>0 and would overflow to
    # inf, which where() hides in the primal but NaNs the gradient.
    seg = jnp.where(tri, seg, -jnp.inf)
    L = jnp.exp(seg)

    # heads per group (ng groups broadcast over nh heads)
    hpg = nh // ng
    Bh = jnp.repeat(Bc, hpg, axis=3) if ng != nh else Bc    # (B,nc,Q,nh,N)
    Ch = jnp.repeat(Cc, hpg, axis=3) if ng != nh else Cc

    cb = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)           # (B,nc,nh,Q,Q)
    M = cb * L.transpose(0, 1, 4, 2, 3)                     # mask+decay
    xdt = xc * dtc[..., None]                               # (B,nc,Q,nh,hp)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xdt)

    # chunk states: S_c = sum_q exp(cum_last - cum_q) dt_q x_q ⊗ B_q
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,nh)
    Sc = jnp.einsum("bcqhn,bcqhp->bchpn", Bh, xdt * decay_end[..., None])

    # inter-chunk recurrence (associative scan over chunks)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,nh)
    if init_state is None:
        init_state = jnp.zeros((Bb, nh, hp, N), f32)

    def combine(a, b):
        (d1, s1), (d2, s2) = a, b
        return d1 * d2, s1 * d2[..., None, None] + s2

    ds, ss = jax.lax.associative_scan(combine, (chunk_decay, Sc), axis=1)
    # states AFTER each chunk, including initial state contribution
    states = ss + init_state[:, None] * ds[..., None, None]
    prev = jnp.concatenate([init_state[:, None], states[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * jnp.exp(cum)[..., None], prev)
    y = (y_intra + y_inter).reshape(Bb, S, nh, hp)
    return y, states[:, -1]


def ssm_block(p, cfg, h, init_state=None, return_state=False):
    """Full Mamba2 block: proj -> conv -> SSD -> gated norm -> out proj."""
    x, Bm, Cm, dt, z, _ = _proj_inputs(p, cfg, h)
    A = -jnp.exp(p["A_log"])
    x = constrain(x, "batch", "seq", "ssm_heads", None)
    y, state = ssd_chunked(cfg, x, Bm, Cm, dt, A, init_state)
    y = y + x.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    out = _gated_out(p, cfg, y.astype(cdtype_of(cfg)), z)
    if return_state:
        return out, state
    return out


def init_ssm_cache(cfg, batch):
    nh, hp, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, nh, hp, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cdtype_of(cfg)),
    }


def ssm_cache_logical():
    return {"state": ("cache_batch", "ssm_heads", None, None),
            "conv": ("cache_batch", None, None)}


def ssm_decode_step(p, cfg, h, cache):
    """h (B,1,D) one token; cache {'state','conv'}; O(1) update."""
    x, Bm, Cm, dt, z, new_conv = _proj_inputs(p, cfg, h, conv_buf=cache["conv"])
    A = -jnp.exp(p["A_log"])
    f32 = jnp.float32
    x1 = x[:, 0].astype(f32)                                # (B,nh,hp)
    B1 = Bm[:, 0].astype(f32)                               # (B,ng,N)
    C1 = Cm[:, 0].astype(f32)
    dt1 = dt[:, 0]                                          # (B,nh)
    hpg = cfg.ssm_nheads // cfg.ssm_ngroups
    Bh = jnp.repeat(B1, hpg, axis=1) if cfg.ssm_ngroups != cfg.ssm_nheads else B1
    Ch = jnp.repeat(C1, hpg, axis=1) if cfg.ssm_ngroups != cfg.ssm_nheads else C1
    decay = jnp.exp(dt1 * A[None, :])                       # (B,nh)
    upd = (dt1[..., None] * x1)[..., None] * Bh[:, :, None, :]   # (B,nh,hp,N)
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + x1 * p["D_skip"][None, :, None]
    out = _gated_out(p, cfg, y[:, None].astype(cdtype_of(cfg)), z)
    return out, {"state": state, "conv": new_conv}

"""Attention: MHA/GQA/MQA with RoPE, sliding windows, KV caches (full + ring),
cross-attention, and q-block-chunked scores (bounded memory at 32k context —
the XLA-level analogue of flash attention; the Pallas kernel in
repro.kernels.flash_attention is the TPU-optimized path).

Cache layout: k, v are (B, Kh, S, hd). Ring caches (sliding window) add
``kpos`` (S,) holding the absolute position stored in each slot (-1 = empty).

Paged layout (serving): one pool of fixed-size KV pages shared by every slot
— ``kp``/``vp`` are (P, Kh, page, hd) — plus a per-slot int32 page table
(B, max_pages) mapping logical page j of slot b to a pool page id. Logical
position t of slot b lives at pool[table[b, t // page], :, t % page]. Every
table entry must be a valid pool index; the serving engine points unassigned
entries at a dedicated trash page, so the attention code needs no sentinel
handling. Writes land on pages owned by exactly one slot (or the trash
page, which is never read), and reads gather a slot's pages in logical
order — so the paged softmax sees the same keys, in the same order, as the
dense (B, Kh, S, hd) layout and the two are numerically identical.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, apply_rope, cdtype_of, dtype_of, rope_angles
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def init_attention(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "wq": _normal(k1, (d, h, hd), d ** -0.5, dt),
        "wk": _normal(k2, (d, kh, hd), d ** -0.5, dt),
        "wv": _normal(k3, (d, kh, hd), d ** -0.5, dt),
        "wo": _normal(k4, (h, hd, d), (h * hd) ** -0.5, dt),
    }


def spec_attention():
    return {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }


def _project_qkv(p, cfg, x, positions):
    """x (B,S,D) -> q (B,H,S,hd) roped, k/v (B,Kh,S,hd) roped."""
    cd = cdtype_of(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q.transpose(0, 2, 1, 3), "batch", "heads", "seq", None)
    k = constrain(k.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", None)
    v = constrain(v.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", None)
    return q, k, v


def _repeat_kv(cfg, k):
    if cfg.n_heads == cfg.n_kv_heads:
        return k
    return jnp.repeat(k, cfg.n_heads // cfg.n_kv_heads, axis=1)


def _sdpa_blocked(cfg, q, k, v, mask_fn, q_positions, q_block):
    """Blocked-over-queries softmax attention.

    q (B,H,Sq,hd); k,v (B,H,Sk,hd); mask_fn(qpos (Qb,), kidx (Sk,)) -> (Qb,Sk)
    bool keep-mask. Memory peak is O(Qb * Sk) scores instead of O(Sq * Sk).
    """
    B, H, Sq, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    kidx = jnp.arange(k.shape[2], dtype=jnp.int32)

    def block(carry, inp):
        qb, qpos = inp  # (B,H,Qb,hd), (Qb,)
        s = jnp.einsum("bhqk,bhtk->bhqt", qb.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        keep = mask_fn(qpos, kidx)  # (Qb, Sk)
        s = jnp.where(keep[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        ob = jnp.einsum("bhqt,bhtk->bhqk", w, v.astype(jnp.float32))
        return carry, ob.astype(q.dtype)

    if Sq <= q_block:
        _, out = block(None, (q, q_positions))
        return out
    if Sq % q_block:  # non-divisible (e.g. VLM img+text): largest divisor
        q_block = next(d for d in range(q_block, 0, -1) if Sq % d == 0)
    nb = Sq // q_block
    qs = q.reshape(B, H, nb, q_block, hd).transpose(2, 0, 1, 3, 4)
    ps = q_positions.reshape(nb, q_block)
    _, out = jax.lax.scan(block, None, (qs, ps))
    return out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, hd)


def _flash_blocks(S, q_block, kv_block, causal, window):
    """Static per-q-block kv ranges (the triangular/window pruning)."""
    q_block = min(q_block, S)
    if S % q_block:
        q_block = next(d for d in range(q_block, 0, -1) if S % d == 0)
    kv_block = min(kv_block, S)
    if S % kv_block:
        kv_block = next(d for d in range(kv_block, 0, -1) if S % d == 0)
    ranges = []
    for qi in range(S // q_block):
        q0 = qi * q_block
        lo = max(0, (q0 - window + 1)) // kv_block if window else 0
        hi = ((q0 + q_block - 1) // kv_block + 1) if causal \
            else S // kv_block
        ranges.append((q0, lo, hi))
    return q_block, kv_block, ranges


def _tile_mask(q0, k0, q_block, kv_block, causal, window):
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    keep = jnp.ones((q_block, kv_block), bool)
    if causal:
        keep &= qpos >= kpos
    if window:
        keep &= qpos - kpos < window
    return keep


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _sdpa_flash_core(q, k, v, causal, window, q_block, kv_block):
    out, _, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block):
    """Online-softmax forward with STATIC triangular / window pruning.

    Per q block, only kv blocks inside the causal prefix (and window) are
    visited via a lax.scan with a static trip count — the pruning shows up
    in compiled FLOPs, not just at run time. Peak score memory is one
    (q_block, kv_block) tile. Returns (out, m, l) for the flash backward.
    """
    B, H, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    q_block, kv_block, ranges = _flash_blocks(S, q_block, kv_block, causal,
                                              window)
    kv_all = k.shape[2] // kv_block
    kb = k.reshape(B, H, kv_all, kv_block, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, kv_all, kv_block, hd).transpose(2, 0, 1, 3, 4)

    outs, ms, ls = [], [], []
    for q0, lo, hi in ranges:
        qb = q[:, :, q0:q0 + q_block].astype(jnp.float32) * scale

        def body(carry, kv, q0=q0, lo=lo, qb=qb):
            m, l, acc, ki = carry
            kt, vt = kv                                   # (B,H,bk,hd)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kt.astype(jnp.float32))
            keep = _tile_mask(q0, (lo + ki) * kv_block, q_block, kv_block,
                              causal, window)
            s = jnp.where(keep[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p,
                                          vt.astype(jnp.float32))
            return (m_new, l, acc, ki + 1), None

        m0 = jnp.full((B, H, q_block, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block, 1), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            body, (m0, l0, a0, jnp.int32(0)), (kb[lo:hi], vb[lo:hi]),
            length=hi - lo)
        outs.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
        ms.append(m)
        ls.append(l)
    return (jnp.concatenate(outs, axis=2), jnp.concatenate(ms, axis=2),
            jnp.concatenate(ls, axis=2))


def _flash_fwd(q, k, v, causal, window, q_block, kv_block):
    out, m, l = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    return out, (q, k, v, out, m, l)


def _flash_bwd(causal, window, q_block, kv_block, res, do):
    """Flash backward: recompute each tile from the saved (m, l) row stats —
    no per-tile residuals survive the forward, so train-time activation
    memory stays O(S·hd) instead of O(S²) (llava temp: 102 GiB -> see
    EXPERIMENTS.md §Perf)."""
    q, k, v, out, m, l = res
    B, H, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    q_block, kv_block, ranges = _flash_blocks(S, q_block, kv_block, causal,
                                              window)
    kv_all = k.shape[2] // kv_block
    kb = k.reshape(B, H, kv_all, kv_block, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, kv_all, kv_block, hd).transpose(2, 0, 1, 3, 4)

    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1, keepdims=True)

    dq_blocks = []
    dk = jnp.zeros((B, H, k.shape[2], hd), jnp.float32)
    dv = jnp.zeros_like(dk)
    for q0, lo, hi in ranges:
        qb = q[:, :, q0:q0 + q_block].astype(jnp.float32) * scale
        mb = m[:, :, q0:q0 + q_block]
        lb = jnp.maximum(l[:, :, q0:q0 + q_block], 1e-30)
        dob = dof[:, :, q0:q0 + q_block]
        db = delta[:, :, q0:q0 + q_block]

        def body(carry, kv, q0=q0, lo=lo, qb=qb, mb=mb, lb=lb, dob=dob,
                 db=db):
            dqb, dk, dv, ki = carry
            kt, vt = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kt.astype(jnp.float32))
            keep = _tile_mask(q0, (lo + ki) * kv_block, q_block, kv_block,
                              causal, window)
            s = jnp.where(keep[None, None], s, NEG_INF)
            p = jnp.exp(s - mb) / lb                       # (B,H,bq,bk)
            dv_t = jnp.einsum("bhqk,bhqd->bhkd", p, dob)
            dp = jnp.einsum("bhqd,bhkd->bhqk", dob, vt.astype(jnp.float32))
            ds = p * (dp - db)                             # d(scaled scores)
            dqb = dqb + jnp.einsum("bhqk,bhkd->bhqd", ds,
                                   kt.astype(jnp.float32)) * scale
            dk_t = jnp.einsum("bhqk,bhqd->bhkd", ds, qb) * 1.0
            off = (lo + ki) * kv_block
            dk = jax.lax.dynamic_update_slice(
                dk, jax.lax.dynamic_slice(
                    dk, (0, 0, off, 0), (B, H, kv_block, hd)) + dk_t,
                (0, 0, off, 0))
            dv = jax.lax.dynamic_update_slice(
                dv, jax.lax.dynamic_slice(
                    dv, (0, 0, off, 0), (B, H, kv_block, hd)) + dv_t,
                (0, 0, off, 0))
            return (dqb, dk, dv, ki + 1), None

        dq0 = jnp.zeros((B, H, q_block, hd), jnp.float32)
        (dqb, dk, dv, _), _ = jax.lax.scan(
            body, (dq0, dk, dv, jnp.int32(0)), (kb[lo:hi], vb[lo:hi]),
            length=hi - lo)
        dq_blocks.append(dqb)
    dq = jnp.concatenate(dq_blocks, axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_sdpa_flash_core.defvjp(_flash_fwd, _flash_bwd)


def _sdpa_flash(cfg, q, k, v, positions, *, causal, window, q_block=1024,
                kv_block=1024):
    """XLA-level flash attention (custom VJP) — §Perf beyond-paper lever."""
    del cfg, positions  # positions are arange(S) on the train/prefill path
    return _sdpa_flash_core(q, k, v, causal, window, q_block, kv_block)


def _out_proj(p, cfg, attn_out):
    """attn_out (B,H,S,hd) -> (B,S,D)."""
    cd = cdtype_of(cfg)
    y = jnp.einsum("bhsk,hkd->bsd", attn_out, p["wo"].astype(cd))
    return constrain(y, "batch", "seq", "d_model")


def attn_train(p, cfg, x, positions, *, causal=True, window=0,
               return_cache=False, q_block=1024):
    """Full-sequence self-attention (train / prefill).

    positions: (S,) int32 absolute positions. window>0 = sliding window.
    cfg.attn_impl selects the score path: "blocked" (q-chunked, materializes
    (q_block, Sk) scores) or "flash" (online softmax + static pruning).
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    kf, vf = _repeat_kv(cfg, k), _repeat_kv(cfg, v)

    if getattr(cfg, "attn_impl", "blocked") == "flash":
        out = _sdpa_flash(cfg, q, kf, vf, positions, causal=causal,
                          window=window, q_block=q_block)
    else:
        def mask_fn(qpos, kidx):
            kpos = positions[kidx]
            keep = jnp.ones((qpos.shape[0], kidx.shape[0]), bool)
            if causal:
                keep &= qpos[:, None] >= kpos[None, :]
            if window:
                keep &= qpos[:, None] - kpos[None, :] < window
            return keep

        out = _sdpa_blocked(cfg, q, kf, vf, mask_fn, positions, q_block)
    y = _out_proj(p, cfg, out)
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def init_cache(cfg, batch, max_seq, *, window=None):
    """Allocate a decode cache. For SWA the cache is a ring of size window."""
    w = cfg.window if window is None else window
    S = min(max_seq, w) if w else max_seq
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((batch, kh, S, hd), cdtype_of(cfg))
    cache = {"k": z, "v": z}
    if w:
        cache["kpos"] = jnp.full((S,), -1, jnp.int32)
    return cache


def cache_logical(*, paged=False):
    if paged:
        return {"kp": ("cache_pages", "cache_kv_heads", None, None),
                "vp": ("cache_pages", "cache_kv_heads", None, None)}
    return {"k": ("cache_batch", "cache_kv_heads", "cache_seq", None),
            "v": ("cache_batch", "cache_kv_heads", "cache_seq", None)}


def init_paged_cache(cfg, n_pages, page_size):
    """Allocate the shared KV page pool: {"kp","vp"} (P, Kh, page, hd).

    No batch dimension — slots share the pool through a page table (see the
    module docstring). Paged caches support full attention only (window=0);
    a ring would need per-slot wrap bookkeeping the table doesn't carry.
    """
    if cfg.window:
        raise NotImplementedError("paged KV cache needs window=0")
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((n_pages, kh, page_size, hd), cdtype_of(cfg))
    return {"kp": z, "vp": z}


def paged_prefill_scatter(cache, kv, page_rows):
    """Scatter a batched-prefill KV into the page pool.

    kv: {"k","v"} (B, Kh, Sp, hd) from ``attn_train(return_cache=True)``;
    Sp must be a multiple of the page size. page_rows (B, Sp // page) int32
    pool page ids; duplicate ids are only legal for trash pages (rows of a
    padded, non-admitted batch entry) since the scatter order is undefined.
    """
    kp = cache["kp"]
    _, kh, page, hd = kp.shape
    B, _, Sp, _ = kv["k"].shape
    assert Sp % page == 0, (Sp, page)
    npp = Sp // page
    flat = page_rows.reshape(B * npp)

    def scat(pool, x):  # x (B,Kh,Sp,hd) -> pages (B*npp,Kh,page,hd)
        xb = x.reshape(B, kh, npp, page, hd).transpose(0, 2, 1, 3, 4)
        return pool.at[flat].set(xb.reshape(B * npp, kh, page, hd))

    return dict(cache, kp=scat(kp, kv["k"]), vp=scat(cache["vp"], kv["v"]))


def attn_decode(p, cfg, x, cache, pos, *, page_table=None):
    """One-token decode. x (B,1,D).

    pos: scalar int32 (all slots aligned) or (B,) int32 per-slot positions
    (continuous batching; full cache only). Full cache: write at slot
    ``pos``. Ring cache (has "kpos"): write at ``pos % S`` and mask by
    stored positions. Paged cache (has "kp"): per-slot positions plus a
    (B, max_pages) ``page_table`` are required.
    """
    if "kp" in cache:
        if pos.ndim != 1 or page_table is None:
            raise ValueError("paged decode needs pos (B,) and a page_table")
        return _attn_decode_paged(p, cfg, x, cache, pos, page_table)
    is_ring = "kpos" in cache
    S = cache["k"].shape[2]
    if pos.ndim == 1:
        if is_ring:
            raise NotImplementedError("per-slot positions need a full cache")
        return _attn_decode_vec(p, cfg, x, cache, pos)
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, cfg, x, positions.astype(jnp.int32))
    slot = pos % S if is_ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)
    new_cache = dict(cache, k=ck, v=cv)
    if is_ring:
        new_cache["kpos"] = jax.lax.dynamic_update_slice(
            cache["kpos"], positions.astype(jnp.int32), (slot,))
        kpos = new_cache["kpos"]
        keep = (kpos >= 0) & (pos - kpos < (cfg.window or S)) & (kpos <= pos)
    else:
        kidx = jnp.arange(S, dtype=jnp.int32)
        keep = kidx <= pos
        if cfg.window:
            keep &= pos - kidx < cfg.window

    kf, vf = _repeat_kv(cfg, ck), _repeat_kv(cfg, cv)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bhqk,bhtk->bhqt", q.astype(jnp.float32) * scale,
                   kf.astype(jnp.float32))
    s = jnp.where(keep[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bhtk->bhqk", w, vf.astype(jnp.float32)).astype(x.dtype)
    return _out_proj(p, cfg, out), new_cache


def _attn_decode_vec(p, cfg, x, cache, pos):
    """Per-slot-position decode (pos (B,)): cache writes become a batched
    scatter (vmapped dynamic update); masking is per-example."""
    positions = pos[:, None].astype(jnp.int32)                 # (B,1)
    q, k, v = _project_qkv(p, cfg, x, positions)
    S = cache["k"].shape[2]

    upd = jax.vmap(lambda c, kk, s: jax.lax.dynamic_update_slice_in_dim(
        c, kk, s, axis=1))
    ck = upd(cache["k"], k, pos)
    cv = upd(cache["v"], v, pos)
    new_cache = dict(cache, k=ck, v=cv)

    kidx = jnp.arange(S, dtype=jnp.int32)
    keep = kidx[None, :] <= pos[:, None]                       # (B,S)
    if cfg.window:
        keep &= pos[:, None] - kidx[None, :] < cfg.window

    kf, vf = _repeat_kv(cfg, ck), _repeat_kv(cfg, cv)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bhqk,bhtk->bhqt", q.astype(jnp.float32) * scale,
                   kf.astype(jnp.float32))
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bhtk->bhqk", w, vf.astype(jnp.float32)).astype(x.dtype)
    return _out_proj(p, cfg, out), new_cache


def _attn_decode_paged(p, cfg, x, cache, pos, page_table):
    """Paged per-slot decode: cache {"kp","vp"} (P,Kh,page,hd) pool;
    page_table (B, max_pages) int32 pool page ids; pos (B,) positions.

    Write: slot b's token lands at pool[table[b, pos//page], :, pos%page]
    (a batched scatter — active slots own disjoint pages). Read: gather the
    slot's pages in logical order into (B, Kh, max_pages*page, hd) and mask
    exactly like ``_attn_decode_vec`` — same keys, same order, so the two
    layouts agree numerically.
    """
    kp, vp = cache["kp"], cache["vp"]
    _, kh, page, hd = kp.shape
    maxp = page_table.shape[1]
    positions = pos[:, None].astype(jnp.int32)                 # (B,1)
    q, k, v = _project_qkv(p, cfg, x, positions)

    pids = jnp.take_along_axis(page_table, (pos // page)[:, None], axis=1)
    pids = pids[:, 0]                                          # (B,)
    offs = pos % page
    ck = kp.at[pids, :, offs].set(k[:, :, 0, :])
    cv = vp.at[pids, :, offs].set(v[:, :, 0, :])
    new_cache = dict(cache, kp=ck, vp=cv)

    B = pos.shape[0]
    S = maxp * page
    ks = ck[page_table].transpose(0, 2, 1, 3, 4).reshape(B, kh, S, hd)
    vs = cv[page_table].transpose(0, 2, 1, 3, 4).reshape(B, kh, S, hd)

    kidx = jnp.arange(S, dtype=jnp.int32)
    keep = kidx[None, :] <= pos[:, None]                       # (B,S)

    kf, vf = _repeat_kv(cfg, ks), _repeat_kv(cfg, vs)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bhqk,bhtk->bhqt", q.astype(jnp.float32) * scale,
                   kf.astype(jnp.float32))
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bhtk->bhqk", w, vf.astype(jnp.float32)).astype(x.dtype)
    return _out_proj(p, cfg, out), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg):
    return init_attention(key, cfg)


def cross_kv(p, cfg, enc_out):
    """Precompute cross K/V from encoder output (B,F,D) -> (B,Kh,F,hd)."""
    cd = cdtype_of(cfg)
    k = jnp.einsum("bfd,dhk->bhfk", enc_out, p["wk"].astype(cd))
    v = jnp.einsum("bfd,dhk->bhfk", enc_out, p["wv"].astype(cd))
    return {"ck": constrain(k, "cache_batch", "cache_kv_heads", None, None),
            "cv": constrain(v, "cache_batch", "cache_kv_heads", None, None)}


def attn_cross(p, cfg, x, ckv):
    """x (B,Sq,D) attends over precomputed cross K/V (no mask, no rope on q
    per our whisper variant — see DESIGN.md)."""
    cd = cdtype_of(cfg)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(cd))
    kf, vf = _repeat_kv(cfg, ckv["ck"]), _repeat_kv(cfg, ckv["cv"])
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bhqk,bhtk->bhqt", q.astype(jnp.float32) * scale,
                   kf.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bhtk->bhqk", w, vf.astype(jnp.float32)).astype(x.dtype)
    return _out_proj(p, cfg, out)

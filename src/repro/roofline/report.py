"""Render EXPERIMENTS.md §Roofline from the dry-run records.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun/16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES

_ADVICE = {
    ("memory", "train"): "fuse attention (online softmax) — stop "
                         "materializing S x S score tensors through HBM",
    ("memory", "prefill"): "fuse attention (online softmax) + causal block "
                           "skipping",
    ("memory", "decode"): "cache is streamed once per token (bandwidth "
                          "floor) — shrink it: GQA is in place, add KV "
                          "quantization",
    ("compute", "train"): "causal block skipping halves attention flops; "
                          "remat=dots avoids recompute",
    ("compute", "prefill"): "causal block skipping halves attention flops",
    ("compute", "decode"): "decode flops are already minimal — batch more "
                           "requests per step",
    ("ici", "train"): "reduce-scatter instead of all-reduce for grads; bf16 "
                      "or int8-compressed gradient reduction",
    ("ici", "prefill"): "shard the sequence dim instead of gathering "
                        "activations",
    ("ici", "decode"): "keep the cache model-sharded; all-gather logits "
                       "hierarchically (pod-local first)",
}


def load_records(d: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_ms(s: float) -> str:
    return f"{s*1e3:9.1f}"


def render(d: str, *, only_tag: str = "") -> str:
    recs = load_records(d)
    order = {a: i for i, a in enumerate(ARCHS)}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             sorder.get(r["shape"], 9)))
    lines = [
        "| arch | shape | Tc (ms) | Tm (ms) | Ti (ms) | dominant | "
        "useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    fails = []
    for r in recs:
        if (r.get("tag") or "") != only_tag:
            continue
        if r["status"] == "skip":
            skips.append(f"- `{r['arch']} x {r['shape']}`: {r['reason']}")
            continue
        if r["status"] != "ok":
            fails.append(f"- `{r['arch']} x {r['shape']}`: {r.get('error')}")
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        kind = r.get("kind", "train")
        bound = max(rf["t_compute"], rf["t_memory"], rf["t_ici"])
        useful_t = (rf["model_flops_total"] / rf["n_chips"]) / 197e12
        frac = useful_t / bound if bound else 0.0
        frac_s = f"{frac:.1%}"
        if kind == "decode":
            # decode is bandwidth-bound by nature: its roofline metric is
            # the bandwidth fraction — params+cache read once vs modeled
            # traffic (MFU is ~0 by construction for 1-token steps).
            args = r.get("memory", {}).get("argument_size_in_bytes") or 0
            bw = args / rf["hbm_bytes_per_chip"] if rf["hbm_bytes_per_chip"] \
                else 0.0
            frac_s = f"bw {bw:.0%}"
        advice = _ADVICE.get((dom, kind), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(rf['t_compute'])} | "
            f"{fmt_ms(rf['t_memory'])} | {fmt_ms(rf['t_ici'])} | {dom} | "
            f"{rf['useful_ratio']:.2f} | {frac_s} | {advice} |")
    out = "\n".join(lines)
    if skips:
        out += "\n\nSkipped cells (per assignment rules):\n" + "\n".join(skips)
    if fails:
        out += "\n\nFAILED cells:\n" + "\n".join(fails)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(render(args.dir, only_tag=args.tag))


if __name__ == "__main__":
    main()

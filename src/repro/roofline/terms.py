"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

Under SPMD the compiled module is the per-device program, so every quantity
parsed from it is already per-chip (dividing cluster totals by chip count, as
in the assignment formulas, gives the same numbers).

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (measured: a 16-step
scan reports 1/16 of the unrolled FLOPs), and scan-over-layers is mandatory
for compiling 88-layer models — so FLOPs and bytes are re-derived from the
optimized HLO text with loop trip-count multipliers (repro.hlo.parse):

  - FLOPs: every ``dot``/``convolution`` instruction, 2·prod(out)·prod(contract),
    × its computation's execution multiplier. (Elementwise FLOPs are ignored:
    ≪1% for these models and invisible at MXU granularity.)
  - HBM bytes: a traffic model — each top-level instruction (fusion, dot,
    collective, copy, dynamic-update...) reads its operands and writes its
    result through HBM once; instructions *inside* fusion computations are
    VMEM-resident and free. This matches the TPU execution model of fused
    streaming kernels.
  - wire bytes: ring-algorithm models per collective (all-reduce 2(g-1)/g·B,
    all-gather/reduce-scatter/all-to-all (g-1)/g·B, permute 1·B), group size
    parsed from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.configs.base import HardwareConfig, ModelConfig, ShapeConfig
from repro.hlo.parse import (Instr, find_entry, nesting_multipliers,
                             parse_module, shape_bytes, shape_dims)

_COLLECTIVES = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                "collective-permute")
_SKIP_TRAFFIC = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "custom-call",
    "broadcast", "reshape", "partition-id", "replica-id",
})

_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------

def _dot_flops(ins: Instr) -> float:
    """2 · prod(result dims) · prod(lhs contracting dims)."""
    res = shape_dims(ins.shape)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    ops = ins.operand_shapes()
    if not ops:
        return 0.0
    lhs = shape_dims(ops[0])
    if not lhs:
        return 0.0
    lhs_dims = lhs[0][1]
    m = _CONTRACT_RE.search(ins.line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def parsed_dot_flops(comps: dict[str, list[Instr]], mults: dict[str, int]
                     ) -> float:
    total = 0.0
    for cname, instrs in comps.items():
        m = mults.get(cname, 0)
        if m == 0:
            continue
        for ins in instrs:
            if ins.opcode in ("dot", "convolution"):
                total += m * _dot_flops(ins)
    return total


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------

def traffic_bytes(comps: dict[str, list[Instr]], mults: dict[str, int],
                  fusion_comps: set[str]) -> float:
    total = 0.0
    for cname, instrs in comps.items():
        m = mults.get(cname, 0)
        if m == 0 or cname in fusion_comps:
            continue
        for ins in instrs:
            if ins.opcode in _SKIP_TRAFFIC or ins.opcode in _COLLECTIVES:
                continue
            ops = [shape_bytes(s) for s in ins.operand_shapes()]
            res = ins.result_bytes
            # In-place cache updates: a dynamic-update-slice (or a fusion
            # rooted in one) aliases its big operand — XLA updates the
            # buffer in place, so only the written slice moves, not the
            # whole KV cache per token (decode cells were overcharged
            # ~100x before this correction).
            if ("dynamic-update-slice" in ins.name
                    or ins.opcode == "dynamic-update-slice"):
                big = max(ops, default=0)
                if big and abs(big - res) <= 0.01 * res:
                    total += m * (res + sum(ops) - 2 * big)
                    continue
            total += m * (res + sum(ops))
    return total


def _fusion_computations(comps: dict[str, list[Instr]]) -> set[str]:
    out = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m:
                    out.add(m.group(1))
    # fused computations call no-one else that matters, but be safe and also
    # mark nested "fused_computation" names
    for name in comps:
        if name.startswith("fused_computation") or ".fused" in name:
            out.add(name)
    return out


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------

def _group_size(ins: Instr, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(ins.line)   # iota form: [n_groups,group_size]<=
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(ins.line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return default


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def collective_wire_bytes(comps: dict[str, list[Instr]],
                          mults: dict[str, int], *, default_group: int
                          ) -> tuple[float, dict[str, float]]:
    """Per-chip wire bytes (ring models) and a per-opcode breakdown."""
    total = 0.0
    by_op: dict[str, float] = {}
    for cname, instrs in comps.items():
        m = mults.get(cname, 0)
        if m == 0:
            continue
        for ins in instrs:
            if ins.opcode not in _COLLECTIVES:
                continue
            g = _group_size(ins, default_group)
            # payload: result bytes for all-gather (shard grows), operand
            # bytes otherwise (start instruction variants included)
            if ins.opcode == "all-gather":
                payload = ins.result_bytes
            else:
                payload = max(sum(shape_bytes(s)
                                  for s in ins.operand_shapes()),
                              ins.result_bytes)
            wire = m * payload * _WIRE_FACTOR[ins.opcode](g)
            total += wire
            by_op[ins.opcode] = by_op.get(ins.opcode, 0.0) + wire
    return total, by_op


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful" flops)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D for training, 2·N_active·tokens for inference-only steps."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    t_compute: float
    t_memory: float
    t_ici: float
    dominant: str
    model_flops_total: float
    useful_ratio: float            # MODEL_FLOPS / (chips · flops_per_chip)
    collective_breakdown: dict[str, float]
    xla_flops: Optional[float] = None      # raw cost_analysis (body-once)
    xla_bytes: Optional[float] = None
    memory_stats: Optional[dict] = None

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_ici)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute utilization at the modeled bound: the MFU the step
        would achieve if it runs exactly at max(term)s."""
        if self.bound_time <= 0:
            return 0.0
        t_useful = (self.model_flops_total / self.n_chips) / _PEAK
        return t_useful / self.bound_time

    def row(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:6s} "
                f"Tc={self.t_compute*1e3:9.3f}ms Tm={self.t_memory*1e3:9.3f}ms "
                f"Ti={self.t_ici*1e3:9.3f}ms -> {self.dominant:8s} "
                f"useful={self.useful_ratio:6.1%} "
                f"roofline_frac={self.roofline_fraction:6.1%}")


_PEAK = 197e12  # set properly via analyze_compiled_text(hw=...)


def analyze_compiled_text(text: str, *, arch: str, shape: ShapeConfig,
                          mesh_name: str, n_chips: int, hw: HardwareConfig,
                          cfg: ModelConfig, cost: Optional[dict] = None,
                          memory_stats: Optional[dict] = None
                          ) -> RooflineReport:
    global _PEAK
    _PEAK = hw.peak_flops
    comps = parse_module(text)
    entry = find_entry(comps, text)
    mults = nesting_multipliers(comps, entry)
    fused = _fusion_computations(comps)

    flops = parsed_dot_flops(comps, mults)
    hbm = traffic_bytes(comps, mults, fused)
    wire, by_op = collective_wire_bytes(comps, mults, default_group=n_chips)

    t_c = flops / hw.peak_flops
    t_m = hbm / hw.hbm_bw
    t_i = wire / hw.ici_bw
    dom = max(("compute", t_c), ("memory", t_m), ("ici", t_i),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = mf / (n_chips * flops) if flops else 0.0
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        wire_bytes_per_chip=wire, t_compute=t_c, t_memory=t_m, t_ici=t_i,
        dominant=dom, model_flops_total=mf, useful_ratio=useful,
        collective_breakdown=by_op,
        xla_flops=(cost or {}).get("flops"),
        xla_bytes=(cost or {}).get("bytes accessed"),
        memory_stats=memory_stats)

from repro.roofline.terms import (  # noqa: F401
    RooflineReport,
    analyze_compiled_text,
    collective_wire_bytes,
    model_flops,
    parsed_dot_flops,
)

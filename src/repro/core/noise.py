"""Noise modes — the TPU/JAX vocabulary of the paper's noise language N.

The paper injects assembly patterns (fp_add64, l1_ld64, memory_ld64) into loop
bodies. On TPU the unit of overlap is not an OoO window but XLA's static
schedule of MXU / VPU / DMA / ICI; the noise quantum is one HLO op group
("pattern") rather than one instruction (DESIGN.md §2/§6). Each mode is:

  make_state(rng)        allocate DISJOINT noise buffers (semantics preserving
                         by construction — the paper's R_n ∩ R_s = ∅ argument)
  apply(state, k)        emit k patterns (k a static python int — the trace
                         baked, trace-per-k path); returns (aux, new_state).
                         ``aux`` is returned from the jitted step so XLA
                         cannot DCE the noise (the `volatile` analogue).
  apply_rt(state, k)     same patterns with k a RUNTIME operand (traced int32
                         scalar, bounded ``lax.fori_loop``) — one jitted
                         executable serves a whole k-sweep (compile-once).
                         For k >= 1 the emitted arithmetic matches ``apply``
                         pattern-for-pattern, so both paths measure the same
                         noise; only the k=0 aux differs (sum of carried
                         accumulators instead of literal 0).
  pattern_cost(hw)       per-pattern resource cost (FLOPs / HBM bytes / ICI
                         bytes / serial latency) — drives the analytic
                         saturation model in core/analytic.py.

Every pattern is emitted inside ``jax.named_scope(NOISE_SCOPE)`` so the HLO
metadata carries the tag; core/payload.py re-parses optimized HLO and counts
surviving payload ops (the paper's §2.3 static payload/overhead verification).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

NOISE_SCOPE = "noise_pattern"

# Independent accumulator chains, like the paper's fadd d31/d30/d29/d28 round
# robin — keeps noise throughput-bound instead of latency-bound.
N_CHAINS = 4


@dataclasses.dataclass(frozen=True)
class PatternCost:
    """Per-pattern resource footprint on the target hardware."""
    flops: float = 0.0          # FLOPs issued per pattern
    hbm_bytes: float = 0.0      # HBM traffic per pattern
    ici_bytes: float = 0.0      # per-chip ICI traffic per pattern
    serial_s: float = 0.0       # unavoidable serial latency per pattern
    vmem_bytes: float = 0.0     # VMEM-local traffic (not an HBM cost)

    def time_on(self, hw) -> dict[str, float]:
        """Seconds this pattern adds to each resource timeline of one chip."""
        return {
            "compute": self.flops / hw.peak_flops,
            "memory": self.hbm_bytes / hw.hbm_bw,
            "ici": self.ici_bytes / hw.ici_bw,
            "latency": self.serial_s,
        }


@dataclasses.dataclass(frozen=True)
class NoiseMode:
    name: str
    target: str                              # compute | memory | latency | ici | vmem
    make_state: Callable[[jax.Array], Any]   # rng -> state pytree
    apply: Callable[[Any, int], tuple[jax.Array, Any]]
    pattern_cost: Callable[[Any], PatternCost]
    # runtime-k variant (compile-once sweeps); None = trace-per-k only
    apply_rt: Optional[Callable[[Any, jax.Array], tuple[jax.Array, Any]]] = None
    description: str = ""


@dataclasses.dataclass(frozen=True)
class NoiseScale:
    """Buffer sizing. Tests shrink these; benchmarks enlarge them."""
    vpu_rows: int = 8              # VPU tile (rows, 128) ~ one vreg row group
    mxu_dim: int = 128             # MXU-aligned square matmul
    vmem_rows: int = 64            # small resident buffer (stays in VMEM/L1)
    hbm_mib: int = 64              # dedicated streaming buffer (>> LLC)
    hbm_tile_rows: int = 256       # rows of 128 f32 per streaming pattern
    chase_len: int = 1 << 22       # pointer-chase table entries (16 MiB)
    ici_kib: int = 256             # collective noise buffer per pattern


# ---------------------------------------------------------------------------
# Compute noise
# ---------------------------------------------------------------------------

def _fp_add_state(rng, sc: NoiseScale):
    c = jax.random.normal(rng, (sc.vpu_rows, 128), jnp.float32) * 1e-3
    accs = tuple(jnp.zeros((sc.vpu_rows, 128), jnp.float32) for _ in range(N_CHAINS))
    return {"c": c, "accs": accs}


def _fp_add_apply(state, k: int):
    accs = list(state["accs"])
    c = state["c"]
    with jax.named_scope(NOISE_SCOPE):
        for i in range(k):
            j = i % N_CHAINS
            accs[j] = accs[j] + c
    aux = sum(jnp.sum(a) for a in accs) if k else jnp.float32(0)
    return aux, dict(state, accs=tuple(accs))


def _fp_add_apply_rt(state, k):
    """Runtime-k twin of ``_fp_add_apply``: identical add order via a bounded
    fori_loop over a stacked accumulator (chain i % N_CHAINS gets pattern i)."""
    c = state["c"]
    accs = jnp.stack(state["accs"])
    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(
            0, k, lambda i, a: a.at[i % N_CHAINS].add(c), accs)
    aux = jnp.sum(accs)
    return aux, dict(state, accs=tuple(accs[j] for j in range(N_CHAINS)))


def _mxu_state(rng, sc: NoiseScale):
    d = sc.mxu_dim
    # c = identity: the chained product stays exactly bounded; XLA cannot
    # simplify (c is a runtime buffer, not a constant).
    return {"m": jax.random.normal(rng, (d, d), jnp.bfloat16),
            "c": jnp.eye(d, dtype=jnp.bfloat16)}


def _mxu_apply(state, k: int):
    m, c = state["m"], state["c"]
    with jax.named_scope(NOISE_SCOPE):
        for _ in range(k):
            m = jax.lax.dot(m, c, precision=jax.lax.Precision.DEFAULT,
                            preferred_element_type=jnp.bfloat16)
    return jnp.sum(m.astype(jnp.float32)), dict(state, m=m)


def _mxu_apply_rt(state, k):
    m, c = state["m"], state["c"]
    with jax.named_scope(NOISE_SCOPE):
        m = jax.lax.fori_loop(
            0, k,
            lambda i, mm: jax.lax.dot(mm, c, precision=jax.lax.Precision.DEFAULT,
                                      preferred_element_type=jnp.bfloat16),
            m)
    return jnp.sum(m.astype(jnp.float32)), dict(state, m=m)


# ---------------------------------------------------------------------------
# Data-access noise
# ---------------------------------------------------------------------------

def _vmem_state(rng, sc: NoiseScale):
    return {"buf": jax.random.normal(rng, (sc.vmem_rows, 128), jnp.float32),
            "accs": tuple(jnp.zeros((8, 128), jnp.float32) for _ in range(N_CHAINS))}


def _vmem_apply(state, k: int):
    """l1_ld analogue: k re-reads of a small resident buffer at rotating
    offsets (distinct slices defeat CSE; buffer never leaves VMEM/L1)."""
    buf = state["buf"]
    accs = list(state["accs"])
    rows = buf.shape[0]
    with jax.named_scope(NOISE_SCOPE):
        for i in range(k):
            off = (i * 13) % max(rows - 8, 1)
            accs[i % N_CHAINS] = accs[i % N_CHAINS] + jax.lax.dynamic_slice(
                buf, (off, 0), (8, 128))
    aux = sum(jnp.sum(a) for a in accs) if k else jnp.float32(0)
    return aux, dict(state, accs=tuple(accs))


def _vmem_apply_rt(state, k):
    buf = state["buf"]
    accs = jnp.stack(state["accs"])
    rows = buf.shape[0]
    mod = max(rows - 8, 1)

    def body(i, a):
        off = (i * 13) % mod
        return a.at[i % N_CHAINS].add(jax.lax.dynamic_slice(buf, (off, 0),
                                                            (8, 128)))

    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(0, k, body, accs)
    aux = jnp.sum(accs)
    return aux, dict(state, accs=tuple(accs[j] for j in range(N_CHAINS)))


def _hbm_stream_state(rng, sc: NoiseScale):
    n_f32 = sc.hbm_mib * (1 << 20) // 4
    rows = n_f32 // 128
    return {"buf": jax.random.normal(rng, (rows, 128), jnp.float32),
            "acc": jnp.zeros((sc.hbm_tile_rows, 128), jnp.float32)}


def _hbm_stream_apply(state, k: int, tile_rows: int):
    """memory_ld (bandwidth flavour): k streaming reads of a TILE from a
    dedicated HBM buffer at stride-scattered offsets (defeats reuse)."""
    buf, acc = state["buf"], state["acc"]
    rows = buf.shape[0]
    n_tiles = max(rows // tile_rows, 1)
    with jax.named_scope(NOISE_SCOPE):
        for i in range(k):
            t = (i * 197) % n_tiles          # large co-prime stride: no reuse
            acc = acc + jax.lax.dynamic_slice(buf, (t * tile_rows, 0),
                                              (tile_rows, 128))
    return jnp.sum(acc), dict(state, acc=acc)


def _hbm_stream_apply_rt(state, k, tile_rows: int):
    buf, acc = state["buf"], state["acc"]
    rows = buf.shape[0]
    n_tiles = max(rows // tile_rows, 1)

    def body(i, a):
        t = (i * 197) % n_tiles
        return a + jax.lax.dynamic_slice(buf, (t * tile_rows, 0),
                                         (tile_rows, 128))

    with jax.named_scope(NOISE_SCOPE):
        acc = jax.lax.fori_loop(0, k, body, acc)
    return jnp.sum(acc), dict(state, acc=acc)


def _chase_state(rng, sc: NoiseScale):
    # A random single-cycle permutation: idx -> table[idx] visits every entry.
    n = sc.chase_len
    perm = np.random.RandomState(np.asarray(jax.random.key_data(rng))[-1] % (2**31)
                                 ).permutation(n).astype(np.int32)
    table = np.empty(n, np.int32)
    table[perm[:-1]] = perm[1:]
    table[perm[-1]] = perm[0]
    return {"table": jnp.asarray(table), "idx": jnp.int32(perm[0]),
            "acc": jnp.int32(0)}


def _chase_apply(state, k: int):
    """memory_ld (latency flavour): k serially dependent 1-element gathers —
    the paper's chaotic pointer chase. Dependency chain is the point."""
    table, idx, acc = state["table"], state["idx"], state["acc"]
    with jax.named_scope(NOISE_SCOPE):
        for _ in range(k):
            idx = table[idx]
            acc = acc + idx
    return acc, dict(state, idx=idx, acc=acc)


def _chase_apply_rt(state, k):
    table = state["table"]

    def body(_, carry):
        idx, acc = carry
        idx = table[idx]
        return idx, acc + idx

    with jax.named_scope(NOISE_SCOPE):
        idx, acc = jax.lax.fori_loop(0, k, body,
                                     (state["idx"], state["acc"]))
    return acc, dict(state, idx=idx, acc=acc)


# ---------------------------------------------------------------------------
# ICI collective noise (per mesh axis)
# ---------------------------------------------------------------------------

_shard_map = compat.shard_map


def _ici_state(rng, sc: NoiseScale):
    n = sc.ici_kib * 1024 // 4
    return {"v": jax.random.normal(rng, (n,), jnp.float32)}


def _mesh_for_collectives(mesh: Optional[Any]):
    m = mesh if mesh is not None else compat.get_abstract_mesh()
    if m is None or not m.axis_names:
        return None
    return m


def _ici_fallback_state(v):
    return {"c": v[:128].reshape(1, 128) * 1e-3,
            "accs": (jnp.zeros((1, 128), jnp.float32),) * N_CHAINS}


def _ici_allreduce_apply(state, k: int, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:   # no mesh: degrade to vpu work
        return _fp_add_apply(_ici_fallback_state(v), k)[0], state
    size = compat.mesh_axis_sizes(m)[axis]

    def body(x):
        with jax.named_scope(NOISE_SCOPE):
            for _ in range(k):
                x = jax.lax.psum(x, axis) * (1.0 / size)
        return x

    from jax.sharding import PartitionSpec as P
    out = _shard_map(body, m, P(), P())(v)
    return jnp.sum(out), dict(state, v=out)


def _ici_allreduce_apply_rt(state, k, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:
        return _fp_add_apply_rt(_ici_fallback_state(v), k)[0], state
    size = compat.mesh_axis_sizes(m)[axis]

    def body(x, kk):   # kk replicated: runtime trip count inside the shard
        with jax.named_scope(NOISE_SCOPE):
            return jax.lax.fori_loop(
                0, kk, lambda _, xx: jax.lax.psum(xx, axis) * (1.0 / size), x)

    from jax.sharding import PartitionSpec as P
    out = _shard_map(body, m, (P(), P()), P())(v, jnp.asarray(k, jnp.int32))
    return jnp.sum(out), dict(state, v=out)


def _ici_allgather_apply(state, k: int, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:
        return jnp.sum(v), state
    from jax.sharding import PartitionSpec as P

    def body(x):  # x: local shard (n/size,)
        with jax.named_scope(NOISE_SCOPE):
            for _ in range(k):
                g = jax.lax.all_gather(x, axis)       # (size, n/size)
                x = jnp.mean(g, axis=0)
        return x

    out = _shard_map(body, m, P(axis), P(axis))(v)
    return jnp.sum(out), dict(state, v=out)


def _ici_allgather_apply_rt(state, k, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:
        return jnp.sum(v), state
    from jax.sharding import PartitionSpec as P

    def body(x, kk):

        def one(_, xx):
            g = jax.lax.all_gather(xx, axis)
            return jnp.mean(g, axis=0)

        with jax.named_scope(NOISE_SCOPE):
            return jax.lax.fori_loop(0, kk, one, x)

    out = _shard_map(body, m, (P(axis), P()), P(axis))(
        v, jnp.asarray(k, jnp.int32))
    return jnp.sum(out), dict(state, v=out)


def _ici_a2a_apply(state, k: int, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:
        return jnp.sum(v), state
    size = compat.mesh_axis_sizes(m)[axis]
    from jax.sharding import PartitionSpec as P

    def body(x):  # local shard (n/size,) -> reshape (size, chunk)
        chunk = x.shape[0] // size
        y = x[: size * chunk].reshape(size, chunk)
        with jax.named_scope(NOISE_SCOPE):
            for _ in range(k):
                y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0,
                                       tiled=False)
        return x.at[: size * chunk].set(y.reshape(-1))

    out = _shard_map(body, m, P(axis), P(axis))(v)
    return jnp.sum(out), dict(state, v=out)


def _ici_a2a_apply_rt(state, k, axis: str, mesh=None):
    v = state["v"]
    m = _mesh_for_collectives(mesh)
    if m is None or axis not in m.axis_names:
        return jnp.sum(v), state
    size = compat.mesh_axis_sizes(m)[axis]
    from jax.sharding import PartitionSpec as P

    def body(x, kk):
        chunk = x.shape[0] // size
        y = x[: size * chunk].reshape(size, chunk)

        def one(_, yy):
            return jax.lax.all_to_all(yy, axis, split_axis=0, concat_axis=0,
                                      tiled=False)

        with jax.named_scope(NOISE_SCOPE):
            y = jax.lax.fori_loop(0, kk, one, y)
        return x.at[: size * chunk].set(y.reshape(-1))

    out = _shard_map(body, m, (P(axis), P()), P(axis))(
        v, jnp.asarray(k, jnp.int32))
    return jnp.sum(out), dict(state, v=out)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def make_modes(scale: NoiseScale = NoiseScale(), *, mesh=None,
               ici_axis: str = "model") -> dict[str, NoiseMode]:
    """Instantiate the standard noise-mode registry at a given scale."""
    sc = scale

    def _c(**kw):
        return lambda hw: PatternCost(**kw)

    vpu_flops = sc.vpu_rows * 128
    mxu_flops = 2 * sc.mxu_dim ** 3
    tile_bytes = sc.hbm_tile_rows * 128 * 4
    ici_bytes = sc.ici_kib * 1024

    modes = {
        "fp_add32": NoiseMode(
            "fp_add32", "compute", partial(_fp_add_state, sc=sc), _fp_add_apply,
            _c(flops=vpu_flops), apply_rt=_fp_add_apply_rt,
            description="chained VPU vector adds on disjoint f32 tiles "
                        "(paper: fp_add64)"),
        "mxu_fma128": NoiseMode(
            "mxu_fma128", "compute", partial(_mxu_state, sc=sc), _mxu_apply,
            _c(flops=mxu_flops, vmem_bytes=2 * sc.mxu_dim ** 2),
            apply_rt=_mxu_apply_rt,
            description="chained 128x128 bf16 matmuls — stresses the MXU "
                        "systolic array"),
        "vmem_ld": NoiseMode(
            "vmem_ld", "vmem", partial(_vmem_state, sc=sc), _vmem_apply,
            _c(flops=8 * 128, vmem_bytes=8 * 128 * 4),
            apply_rt=_vmem_apply_rt,
            description="re-reads of a VMEM-resident tile (paper: l1_ld64)"),
        "hbm_stream": NoiseMode(
            "hbm_stream", "memory", partial(_hbm_stream_state, sc=sc),
            lambda s, k: _hbm_stream_apply(s, k, sc.hbm_tile_rows),
            _c(flops=tile_bytes / 4, hbm_bytes=tile_bytes),
            apply_rt=lambda s, k: _hbm_stream_apply_rt(s, k, sc.hbm_tile_rows),
            description="streaming tile reads from a dedicated HBM buffer "
                        "(bandwidth)"),
        "hbm_latency": NoiseMode(
            "hbm_latency", "latency", partial(_chase_state, sc=sc), _chase_apply,
            lambda hw: PatternCost(hbm_bytes=4.0, serial_s=hw.hbm_latency_s),
            apply_rt=_chase_apply_rt,
            description="serially dependent pointer chase (paper: memory_ld64 "
                        "chaotic)"),
        "ici_allreduce": NoiseMode(
            "ici_allreduce", "ici", partial(_ici_state, sc=sc),
            partial(_ici_allreduce_apply, axis=ici_axis, mesh=mesh),
            _c(ici_bytes=2 * ici_bytes),   # ring all-reduce ≈ 2(n-1)/n·B
            apply_rt=partial(_ici_allreduce_apply_rt, axis=ici_axis, mesh=mesh),
            description=f"chained psum over mesh axis {ici_axis!r} on a "
                        "disjoint buffer"),
        "ici_allgather": NoiseMode(
            "ici_allgather", "ici", partial(_ici_state, sc=sc),
            partial(_ici_allgather_apply, axis=ici_axis, mesh=mesh),
            _c(ici_bytes=ici_bytes),
            apply_rt=partial(_ici_allgather_apply_rt, axis=ici_axis, mesh=mesh),
            description=f"chained all-gather over mesh axis {ici_axis!r}"),
        "ici_a2a": NoiseMode(
            "ici_a2a", "ici", partial(_ici_state, sc=sc),
            partial(_ici_a2a_apply, axis=ici_axis, mesh=mesh),
            _c(ici_bytes=ici_bytes),
            apply_rt=partial(_ici_a2a_apply_rt, axis=ici_axis, mesh=mesh),
            description=f"chained all-to-all over mesh axis {ici_axis!r}"),
    }
    return modes


# Paper-facing aliases (AArch64 names -> TPU analogues), for the benchmarks.
PAPER_ALIASES = {
    "fp_add64": "fp_add32",
    "l1_ld64": "vmem_ld",
    "memory_ld64": "hbm_stream",
    "memory_chase": "hbm_latency",
}

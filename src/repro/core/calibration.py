"""Threshold calibration campaigns: fit per-hardware LOW/HIGH from
known-regime synthetic sweeps.

The classifier's LOW/HIGH constants are the paper's defaults (§3.2 suggests
~20-30 instructions as the core-vs-data-access tipping point), but the right
cut depends on the machine under test. This module measures it: a fleet of
KNOWN-REGIME kernels — compute-, bandwidth-, latency- and overlap-shaped
targets built from the stream-triad loop region with their regimes FORCED
through the deterministic synthetic clock (``repro.core.absorption``'s
``SynthShape`` marker) — sweeps under the ordinary campaign machinery, and
the fitted Abs^raw values are separated into per-role clusters:

  sat   the mode the regime saturates: absorption must land at ~0
  mid   partial absorption (the latency signature's memory mode)
  high  deep absorption: the mode the regime leaves slack on

``fit_thresholds`` then places LOW and HIGH at the max-margin midpoints
between adjacent clusters (Pareto-style separation maximization: each
threshold maximizes its distance to BOTH neighbouring clusters), falling
back to the paper defaults whenever the clusters fail to separate. The
result persists as a ``calib`` record in the CampaignStore — keyed by
hardware config, superseded like any other record kind — and
``resolve_thresholds`` threads it into every ``classify`` call site
(``Campaign``, ``AnalyticCampaign``, the fleet executor).

Calibration is definitionally synthetic: the forced regimes are clock
shapes, not real kernel behaviour, so ``run_calibration`` refuses to run
without ``REPRO_SYNTH_MEASURE`` (the ``fleet calibrate`` CLI sets it).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence

import logging

from repro.core.absorption import SYNTH_MEASURE_VAR, SynthShape
from repro.core.classifier import HIGH, LOW, classify

log = logging.getLogger("repro.calibration")

#: the loop-vocabulary modes every calibration regime sweeps
CALIB_MODES = ("fp_add", "l1_ld", "mem_ld")

#: default synthetic base time (seconds) the CLI exports when the synth
#: clock is not already configured
DEFAULT_BASE_S = "1e-3"

# The three cluster roles as clock shapes. Knees are what the hinge fit
# recovers as Abs^raw; slopes are chosen so the sensitivity probe routes
# each role onto a k-grid that samples its knee well (sat/mid: the fine
# grid; high: the robust far grid).
_SAT0 = SynthShape(knee=0.0, slope=0.3)     # saturated from the first pattern
_SAT = SynthShape(knee=1.0, slope=0.3)      # saturated almost immediately
_MID = SynthShape(knee=8.0, slope=0.2)      # partial absorption
_HIGHK = SynthShape(knee=24.0, slope=0.2)   # deep absorption (clear slack)

#: regime name -> {mode: (cluster role, forced clock shape)}. Every regime
#: shapes ALL of CALIB_MODES (a fleet TargetSpec shares one mode list across
#: its regions), with roles arranged so the default strategy tree assigns
#: each regime its eponymous label under both default and fitted thresholds.
REGIMES: dict[str, dict[str, tuple[str, SynthShape]]] = {
    # fp noise hurts immediately; data-access noise is absorbed deep
    "calib_compute": {"fp_add": ("sat", _SAT0), "l1_ld": ("high", _HIGHK),
                      "mem_ld": ("high", _HIGHK)},
    # memory-stream noise not absorbed while fp absorbs deep (l1 mid keeps
    # the bandwidth node's "l1 > low" guard honest)
    "calib_bandwidth": {"fp_add": ("high", _HIGHK), "l1_ld": ("mid", _MID),
                        "mem_ld": ("sat", _SAT)},
    # substantial-but-partial memory absorption with fp slack
    "calib_latency": {"fp_add": ("high", _HIGHK), "l1_ld": ("high", _HIGHK),
                      "mem_ld": ("mid", _MID)},
    # nothing absorbs: every resource saturated (Table 3 case 3)
    "calib_overlap": {"fp_add": ("sat", _SAT), "l1_ld": ("sat", _SAT),
                      "mem_ld": ("sat", _SAT)},
}

#: the label each regime must classify as — the calibration's ground truth
EXPECTED = {"calib_compute": "compute", "calib_bandwidth": "bandwidth",
            "calib_latency": "latency", "calib_overlap": "overlap"}

#: regime (== region) names in declaration order, for cheap grid queries
REGIME_NAMES = tuple(REGIMES)


def forced_regime(base, name: str, shapes: dict) -> "object":
    """Wrap a RegionTarget so each mode's sweep runs under a forced
    synthetic-clock shape.

    ``shapes`` maps mode -> SynthShape; the wrapper appends the mode's
    marker to the measured argument tuple (where the synthetic clock scans
    for it) and strips it again before invoking the real callable, so the
    target stays runnable under a real clock — the markers only matter when
    ``REPRO_SYNTH_MEASURE`` is set. Payload verification is skipped (the
    noise payload is irrelevant to a clock-shaped sweep)."""
    from repro.core.controller import RegionTarget

    def _strip(args: tuple) -> tuple:
        return tuple(a for a in args if not isinstance(a, SynthShape))

    def build(mode: str, k: int):
        inner = base.build(mode, k)

        def fn(*args):
            return inner(*_strip(args))
        return fn

    def args_for(mode: str, k: int) -> tuple:
        args = base.args_for(mode, k)
        shape = shapes.get(mode)
        return args if shape is None else (*args, shape)

    def build_rt(mode: str):
        inner = base.build_rt(mode) if base.build_rt is not None else None
        if inner is None:
            return None

        def fn(k, *args):
            return inner(k, *_strip(args))
        return fn

    def args_for_rt(mode: str) -> tuple:
        args = base.args_for_rt(mode)
        shape = shapes.get(mode)
        return args if shape is None else (*args, shape)

    return RegionTarget(name=name, build=build, args_for=args_for,
                        body_size=base.body_size, build_rt=build_rt,
                        args_for_rt=args_for_rt,
                        payload_check=lambda mode, k: None,
                        audit_hint=base.audit_hint)


def calibrate_targets(*, n: int = 4096, chunk: int = 512) -> list:
    """The four known-regime RegionTargets (one per ``REGIMES`` entry), each
    a small stream-triad loop region with its regime's clock shapes forced.
    ``n``/``chunk`` size the underlying buffers — the defaults are tiny
    because under the synthetic clock the kernel never actually runs."""
    from repro.bench.kernels import stream_region

    out = []
    for name, spec in REGIMES.items():
        base = stream_region(n=n, chunk=chunk)
        out.append(forced_regime(base, name,
                                 {m: shape for m, (_, shape) in spec.items()}))
    return out


def fit_thresholds(samples: Sequence[dict], *, default_low: float = LOW,
                   default_high: float = HIGH) -> tuple[float, float, bool]:
    """Fit (low, high, fitted) from calibration samples.

    ``samples`` is a list of ``{"region", "mode", "role", "k1"}`` dicts
    (the ``calib`` record's payload). LOW lands at the midpoint between the
    sat cluster's maximum and the mid∪high clusters' minimum; HIGH at the
    midpoint between the sat∪mid maximum and the high cluster's minimum —
    the max-margin (Pareto-style separation-maximizing) cuts. Whenever the
    clusters overlap, a boundary cluster is empty, or the cuts invert, the
    paper defaults come back with ``fitted=False``."""
    sats = [float(s["k1"]) for s in samples if s.get("role") == "sat"]
    mids = [float(s["k1"]) for s in samples if s.get("role") == "mid"]
    highs = [float(s["k1"]) for s in samples if s.get("role") == "high"]
    if not sats or not highs:
        log.warning("calibration saw no %s samples; keeping paper defaults",
                    "sat" if not sats else "high")
        return default_low, default_high, False
    upper = mids + highs
    lower = sats + mids
    low = (max(sats) + min(upper)) / 2.0
    high = (max(lower) + min(highs)) / 2.0
    if not (max(sats) < min(upper) and max(lower) < min(highs)
            and low < high):
        log.warning(
            "calibration regimes do not separate (sat<=%.3g, mid=%s, "
            "high>=%.3g); keeping paper defaults", max(sats),
            [round(m, 3) for m in sorted(mids)], min(highs))
        return default_low, default_high, False
    return low, high, True


def hw_name() -> str:
    """The hardware-config key a ``calib`` record is stored under (the jax
    backend platform: cpu/gpu/tpu)."""
    import jax

    return jax.default_backend()


def resolve_thresholds(store, hw: Optional[str] = None
                       ) -> tuple[float, float, str]:
    """The effective (low, high, provenance) for classifications replayed
    from ``store``.

    Provenance is ``"default"`` (no calib record for this hardware),
    ``"calibrated"`` (a fitted record), or ``"fallback"`` (a record whose
    fit fell back to the paper defaults). Stores without any calib record
    never touch jax — the common path stays cheap."""
    calib = getattr(store, "calib", None)
    if not calib:
        return LOW, HIGH, "default"
    rec = calib.get(hw if hw is not None else hw_name())
    if rec is None:
        return LOW, HIGH, "default"
    if not rec.get("fitted"):
        return LOW, HIGH, "fallback"
    return float(rec["low"]), float(rec["high"]), "calibrated"


@dataclasses.dataclass
class CalibrationResult:
    """What ``run_calibration`` produced: the fitted thresholds, the raw
    per-(region, mode) samples behind them, and each regime's RegionReport
    re-classified UNDER the fitted thresholds."""
    hw: str
    low: float
    high: float
    fitted: bool
    samples: list
    reports: dict
    stats: "object"

    def correct(self) -> bool:
        """True when every known-regime kernel classified as its expected
        label under the fitted thresholds."""
        return all(rep.bottleneck.label == EXPECTED[name]
                   for name, rep in self.reports.items())


def run_calibration(store, *, reps: int = 2, workers: int = 1,
                    n: int = 4096, chunk: int = 512) -> CalibrationResult:
    """Run (or replay) the known-regime calibration campaign into ``store``
    and persist the fitted thresholds as a ``calib`` record.

    Sweeps every ``REGIMES`` region over ``CALIB_MODES`` through the
    ordinary ``Campaign`` machinery (so a completed store REPLAYS with zero
    measurements), fits thresholds from the per-role Abs^raw clusters, and
    appends one ``calib`` record keyed by ``hw_name()``. Raises
    ``RuntimeError`` when the deterministic synthetic clock is off — forced
    regimes are meaningless under a real clock."""
    if not os.environ.get(SYNTH_MEASURE_VAR):
        raise RuntimeError(
            "calibration needs the deterministic synthetic clock: set "
            f"{SYNTH_MEASURE_VAR} (e.g. {DEFAULT_BASE_S}) or run via "
            "`python -m repro.fleet calibrate run`, which sets it")
    from repro.core.campaign import Campaign, CampaignStore
    from repro.core.controller import Controller

    opened = isinstance(store, str)
    ctl = Controller(reps=reps, verify_payload=False)
    camp = Campaign(store if not opened else CampaignStore(store), ctl,
                    workers=workers)
    try:
        samples: list[dict] = []
        reports: dict = {}
        for target in calibrate_targets(n=n, chunk=chunk):
            rep = camp.characterize(target, list(CALIB_MODES))
            for mode in CALIB_MODES:
                role = REGIMES[target.name][mode][0]
                samples.append({"region": target.name, "mode": mode,
                                "role": role,
                                "k1": float(rep.results[mode].fit.k1)})
            reports[target.name] = rep
        low, high, fitted = fit_thresholds(samples)
        hw = hw_name()
        camp.store.append({"kind": "calib", "hw": hw, "low": low,
                           "high": high, "fitted": fitted, "reps": reps,
                           "samples": samples})
        for name, rep in reports.items():
            bott = classify({m: r.fit.k1 for m, r in rep.results.items()},
                            low=low, high=high)
            reports[name] = dataclasses.replace(rep, bottleneck=bott)
        return CalibrationResult(hw=hw, low=low, high=high, fitted=fitted,
                                 samples=samples, reports=reports,
                                 stats=camp.stats)
    finally:
        if opened:
            camp.store.close()

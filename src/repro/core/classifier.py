"""Bottleneck classification from absorption signatures.

Encodes the paper's decision logic (§4.2 validation + Table 3):

  - compute-bound   : fp absorption ~ 0, data-access absorption high (HACCmk)
  - bandwidth-bound : memory-stream absorption ~ 0 even though fp/l1 absorb
                      a lot (parallel STREAM)
  - latency-bound   : absorbs *substantial* memory noise (the STREAM vs
                      lat_mem_rd distinction) and large fp noise
  - full-overlap    : ALL absorptions ~ 0 (Table 3 case 3) — every resource
                      saturated; distinguish from a frontend-style shared
                      bottleneck with the DECAN cross-check (case 4, Fig. 6)
  - ici-bound       : collective-noise absorption ~ 0 (our TPU extension)

Thresholds are in *patterns* and deliberately coarse — the paper reads the
signature shape, not exact values; §3.2 suggests ~20–30 instructions as the
tipping point between "core-level" and "data-access" codes. ``LOW``/``HIGH``
below are the paper DEFAULTS; a calibration campaign
(``repro.core.calibration``) fits per-hardware replacements from
known-regime sweeps and threads them through every ``classify`` call site.

The decision logic itself lives in a declarative strategy tree
(``strategies/default.yaml`` via ``repro.core.strategy``) — ``classify``
resolves the tree, and the report carries the evaluated decision path for
``fleet doctor --explain``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.core import strategy as strategy_mod

LOW = 4.0       # <= LOW patterns: the targeted resource is saturated
HIGH = 20.0     # >= HIGH patterns: clearly unsaturated (paper §3.2: 20-30)


@dataclasses.dataclass
class BottleneckReport:
    label: str                       # compute|bandwidth|latency|ici|overlap|mixed
    confidence: float                # 0..1, separation-based
    absorptions: dict[str, float]    # mode -> Abs^raw (or Abs^rel * scale)
    explanation: str
    decan_hint: Optional[str] = None  # set by the DECAN cross-check
    # static audit evidence per mode (apply_audit_evidence); None = no audit
    evidence: Optional[list] = None
    # runtime measurement-quality evidence per mode (apply_quality_evidence);
    # None = no quality guard ran
    quality: Optional[list] = None
    # the strategy tree's evaluated decision path (which nodes were tried,
    # which fired, under which thresholds) — NOT serialized into report
    # JSON / __str__ (byte-identity with pre-tree reports); rendered by
    # fleet doctor --explain
    path: Optional[dict] = None

    def __str__(self) -> str:
        abss = ", ".join(f"{m}={a:.1f}" for m, a in self.absorptions.items())
        s = f"[{self.label} | conf={self.confidence:.2f}] {self.explanation} ({abss})"
        if self.decan_hint:
            s += f" | DECAN: {self.decan_hint}"
        if self.evidence is not None:
            n_sup = sum(1 for e in self.evidence if e["supports"])
            s += f" | audit: {n_sup}/{len(self.evidence)} mode(s) support"
        if self.quality is not None:
            n_clean = sum(1 for q in self.quality if not q["quarantined"])
            s += f" | quality: {n_clean}/{len(self.quality)} mode(s) clean"
        return s


def classify(absorptions: Mapping[str, float], *, low: float = LOW,
             high: float = HIGH,
             tree: Optional["strategy_mod.StrategyTree"] = None,
             ) -> BottleneckReport:
    """Map {mode: absorption} to a bottleneck class.

    Mode names accept loop-level (fp_add/l1_ld/mem_ld/chase), graph-level
    (fp_add32/mxu_fma128/vmem_ld/hbm_stream/hbm_latency/ici_*) and Pallas
    kernel-level (fp/mxu/vmem — repro.kernels.noise_slots) vocabularies,
    plus the paper aliases.

    The decision is delegated to a strategy tree (``tree``, defaulting to
    ``strategies/default.yaml``); ``low``/``high`` are the effective
    thresholds — pass a calibration's fitted values to classify under them
    (confidence is normalized by the *effective* ``high``, never the module
    default). The returned report's ``path`` records the evaluated
    decision path.
    """
    t = tree if tree is not None else strategy_mod.default_tree()
    d = t.decide(absorptions, low=low, high=high)
    return BottleneckReport(d.label, d.confidence, dict(absorptions),
                            d.explanation, path=d.path)


def apply_audit_evidence(report: BottleneckReport,
                         audits: Mapping[str, Mapping],
                         *, downgrade: float = 0.6) -> BottleneckReport:
    """Annotate a classification with static audit evidence
    (``repro.analysis`` records, one per audited mode).

    A mode SUPPORTS the label when its noise survived compilation intact
    and the audit's predicted sensitivity direction matches the mode's
    declared target — the absorption reading measured what the classifier
    assumed it measured. A mode whose payload died or degraded, or whose
    surviving instructions pressure a different resource, CONFLICTS: its
    reading is structurally suspect, and each conflicting mode multiplies
    the confidence by ``downgrade``.

    Deterministic and measurement-free: two runs over the same store attach
    byte-identical evidence.
    """
    if not audits:
        return report
    evidence = []
    conf = report.confidence
    for mode in sorted(audits):
        rec = audits[mode]
        supports = (rec.get("verdict") == "intact"
                    and rec.get("agrees") is not False)
        evidence.append({
            "mode": mode,
            "verdict": rec.get("verdict"),
            "survival": rec.get("survival"),
            "predicted": rec.get("predicted"),
            "target": rec.get("target"),
            "corruption": rec.get("corruption"),
            "supports": supports,
        })
        if not supports:
            conf *= downgrade
    return dataclasses.replace(report, confidence=conf, evidence=evidence)


UNRELIABLE = "unreliable"    # the refused label: measurements can't back one


def apply_quality_evidence(report: BottleneckReport,
                           quality: Mapping[str, Mapping],
                           *, downgrade: float = 0.6,
                           majority: float = 0.5) -> BottleneckReport:
    """Annotate a classification with runtime measurement-quality evidence
    (the quality records a guarded campaign persisted, aggregated per mode
    as ``{"points": n, "quarantined": n, "reasons": {reason: count}}``).

    The mirror of ``apply_audit_evidence`` for *dynamic* validity: a mode
    with any quarantined points is suspect (its curve was fit through
    condemned measurements) and multiplies the confidence by ``downgrade``;
    a mode whose points are MAJORITY-quarantined (> ``majority`` of them)
    cannot back any label at all — the report's label is refused and
    replaced with ``unreliable`` at confidence 0, naming the condemned
    modes and the dominant quarantine reasons.

    Deterministic and measurement-free: two runs over the same store attach
    byte-identical evidence.
    """
    if not quality:
        return report
    evidence = []
    refused = []
    conf = report.confidence
    for mode in sorted(quality):
        rec = quality[mode]
        points = int(rec.get("points", 0))
        quarantined = int(rec.get("quarantined", 0))
        reasons = dict(rec.get("reasons", {}))
        evidence.append({"mode": mode, "points": points,
                         "quarantined": quarantined, "reasons": reasons})
        if quarantined:
            conf *= downgrade
        if points and quarantined / points > majority:
            why = ", ".join(sorted(reasons, key=lambda r: (-reasons[r], r)))
            refused.append(f"{mode} ({quarantined}/{points} point(s) "
                           f"quarantined: {why})")
    if refused:
        return dataclasses.replace(
            report, label=UNRELIABLE, confidence=0.0, quality=evidence,
            explanation="measurement quality refuses a label — majority-"
                        "quarantined curve(s): " + "; ".join(refused)
                        + " (re-measure under a quieter clock, e.g. "
                        "fleet run --resume)")
    return dataclasses.replace(report, confidence=conf, quality=evidence)


def cross_check_with_decan(report: BottleneckReport,
                           sat_fp: float, sat_ls: float,
                           *, close: float = 0.85) -> BottleneckReport:
    """Fig. 6 logic: noise saying "overlap" (all absorptions ~0) is ambiguous
    between case 3 (genuine full overlap: BOTH DECAN variants run near the
    reference) and a shared upstream/frontend bottleneck. If DECAN shows any
    variant running substantially faster than the reference, case 3 is ruled
    out — the combined verdict is "frontend" (the paper's lloops.c_1351
    resolution, where Sat_FP=0.81 / Sat_LS=0.12 already excluded overlap).
    """
    if report.label != "overlap":
        return report
    if sat_fp >= close and sat_ls >= close:
        hint = (f"both variants near reference (Sat_FP={sat_fp:.2f}, "
                f"Sat_LS={sat_ls:.2f}) -> genuine full overlap of FP and LS")
        return dataclasses.replace(report, decan_hint=hint)
    hint = (f"DECAN rules out full overlap (Sat_FP={sat_fp:.2f}, "
            f"Sat_LS={sat_ls:.2f}) -> shared upstream (frontend-analogue) "
            "bottleneck")
    return dataclasses.replace(report, label="frontend", decan_hint=hint)

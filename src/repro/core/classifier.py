"""Bottleneck classification from absorption signatures.

Encodes the paper's decision logic (§4.2 validation + Table 3):

  - compute-bound   : fp absorption ~ 0, data-access absorption high (HACCmk)
  - bandwidth-bound : memory-stream absorption ~ 0 even though fp/l1 absorb
                      a lot (parallel STREAM)
  - latency-bound   : absorbs *substantial* memory noise (the STREAM vs
                      lat_mem_rd distinction) and large fp noise
  - full-overlap    : ALL absorptions ~ 0 (Table 3 case 3) — every resource
                      saturated; distinguish from a frontend-style shared
                      bottleneck with the DECAN cross-check (case 4, Fig. 6)
  - ici-bound       : collective-noise absorption ~ 0 (our TPU extension)

Thresholds are in *patterns* and deliberately coarse — the paper reads the
signature shape, not exact values; §3.2 suggests ~20–30 instructions as the
tipping point between "core-level" and "data-access" codes.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

LOW = 4.0       # <= LOW patterns: the targeted resource is saturated
HIGH = 20.0     # >= HIGH patterns: clearly unsaturated (paper §3.2: 20-30)


@dataclasses.dataclass
class BottleneckReport:
    label: str                       # compute|bandwidth|latency|ici|overlap|mixed
    confidence: float                # 0..1, separation-based
    absorptions: dict[str, float]    # mode -> Abs^raw (or Abs^rel * scale)
    explanation: str
    decan_hint: Optional[str] = None  # set by the DECAN cross-check
    # static audit evidence per mode (apply_audit_evidence); None = no audit
    evidence: Optional[list] = None
    # runtime measurement-quality evidence per mode (apply_quality_evidence);
    # None = no quality guard ran
    quality: Optional[list] = None

    def __str__(self) -> str:
        abss = ", ".join(f"{m}={a:.1f}" for m, a in self.absorptions.items())
        s = f"[{self.label} | conf={self.confidence:.2f}] {self.explanation} ({abss})"
        if self.decan_hint:
            s += f" | DECAN: {self.decan_hint}"
        if self.evidence is not None:
            n_sup = sum(1 for e in self.evidence if e["supports"])
            s += f" | audit: {n_sup}/{len(self.evidence)} mode(s) support"
        if self.quality is not None:
            n_clean = sum(1 for q in self.quality if not q["quarantined"])
            s += f" | quality: {n_clean}/{len(self.quality)} mode(s) clean"
        return s


def _get(absorptions: Mapping[str, float], *names: str,
         default: Optional[float] = None) -> Optional[float]:
    for n in names:
        if n in absorptions:
            return absorptions[n]
    return default


def classify(absorptions: Mapping[str, float], *, low: float = LOW,
             high: float = HIGH) -> BottleneckReport:
    """Map {mode: absorption} to a bottleneck class.

    Mode names accept loop-level (fp_add/l1_ld/mem_ld/chase), graph-level
    (fp_add32/mxu_fma128/vmem_ld/hbm_stream/hbm_latency/ici_*) and Pallas
    kernel-level (fp/mxu/vmem — repro.kernels.noise_slots) vocabularies,
    plus the paper aliases.
    """
    fp = _get(absorptions, "fp_add", "fp_add32", "fp_fma", "mxu_fma128",
              "fp_add64", "fp", "mxu")
    l1 = _get(absorptions, "l1_ld", "vmem_ld", "l1_ld64", "vmem")
    mem = _get(absorptions, "mem_ld", "hbm_stream", "memory_ld64")
    chase = _get(absorptions, "chase", "hbm_latency", "memory_chase")
    icis = {m: a for m, a in absorptions.items() if m.startswith("ici")}

    known = {k: v for k, v in dict(fp=fp, l1=l1, mem=mem, chase=chase).items()
             if v is not None}

    def conf(sep: float) -> float:
        return max(0.0, min(1.0, sep / high))

    # ICI first: a saturated interconnect masks everything else.
    if icis and min(icis.values()) <= low:
        others = [v for v in known.values() if v is not None]
        if not others or min(others) >= high / 2:
            worst = min(icis, key=icis.get)
            return BottleneckReport(
                "ici", conf((min(others) if others else high) - icis[worst]),
                dict(absorptions),
                f"collective noise ({worst}) not absorbed while core "
                "resources have slack -> interconnect-bound")

    # compute-bound: fp degrades immediately while L1 noise is absorbed.
    # Separation is relative — the paper's x86 HACCmk row is 0/13/0, so the
    # data-access side need not clear the absolute HIGH bar (mem noise is
    # rarely absorbed by anything but latency-bound codes, Table 1).
    if fp is not None and fp <= low and (
            (l1 is not None and l1 >= max(high / 2, 3.0 * max(fp, 1.0)))
            or (mem is not None and mem >= high)):
        return BottleneckReport(
            "compute", conf((l1 if l1 is not None else mem) - fp),
            dict(absorptions),
            "fp noise degrades immediately while data-access noise is "
            "absorbed -> compute-bound (HACCmk signature)")

    # bandwidth: the STREAM signature also absorbs L1 noise (l1 > low) —
    # if L1 noise degrades too, the LSU itself is the bottleneck (Fig. 4a),
    # handled below.
    if mem is not None and mem <= low and (fp is None or fp >= high) \
            and (l1 is None or l1 > low):
        return BottleneckReport(
            "bandwidth", conf((fp or high) - mem), dict(absorptions),
            "memory-stream noise not absorbed while fp noise is -> "
            "bandwidth-saturated (parallel-STREAM signature)")

    if (mem is not None and mem > low) and (fp is None or fp >= high):
        return BottleneckReport(
            "latency", conf(mem - low), dict(absorptions),
            "substantial memory noise absorbed (stalls come from load "
            "dependencies, not bandwidth) -> latency-bound "
            "(lat_mem_rd signature)")

    if known and max(known.values()) <= low:
        return BottleneckReport(
            "overlap", conf(low - max(known.values()) + high / 2),
            dict(absorptions),
            "no mode is absorbed: either full resource overlap (Table 3 "
            "case 3) or a shared upstream bottleneck (case 4) — run the "
            "DECAN cross-check to distinguish")

    if l1 is not None and l1 <= low and (fp is None or fp > low):
        return BottleneckReport(
            "l1", conf((fp or high) - l1), dict(absorptions),
            "L1/LSU noise degrades first -> load/store-unit bound "
            "(the -O0 matmul signature, Fig. 4a)")

    return BottleneckReport(
        "mixed", 0.3, dict(absorptions),
        "ambiguous absorption levels (moderate everywhere) indicating "
        "strong interdependencies (Table 3 case 4)")


def apply_audit_evidence(report: BottleneckReport,
                         audits: Mapping[str, Mapping],
                         *, downgrade: float = 0.6) -> BottleneckReport:
    """Annotate a classification with static audit evidence
    (``repro.analysis`` records, one per audited mode).

    A mode SUPPORTS the label when its noise survived compilation intact
    and the audit's predicted sensitivity direction matches the mode's
    declared target — the absorption reading measured what the classifier
    assumed it measured. A mode whose payload died or degraded, or whose
    surviving instructions pressure a different resource, CONFLICTS: its
    reading is structurally suspect, and each conflicting mode multiplies
    the confidence by ``downgrade``.

    Deterministic and measurement-free: two runs over the same store attach
    byte-identical evidence.
    """
    if not audits:
        return report
    evidence = []
    conf = report.confidence
    for mode in sorted(audits):
        rec = audits[mode]
        supports = (rec.get("verdict") == "intact"
                    and rec.get("agrees") is not False)
        evidence.append({
            "mode": mode,
            "verdict": rec.get("verdict"),
            "survival": rec.get("survival"),
            "predicted": rec.get("predicted"),
            "target": rec.get("target"),
            "corruption": rec.get("corruption"),
            "supports": supports,
        })
        if not supports:
            conf *= downgrade
    return dataclasses.replace(report, confidence=conf, evidence=evidence)


UNRELIABLE = "unreliable"    # the refused label: measurements can't back one


def apply_quality_evidence(report: BottleneckReport,
                           quality: Mapping[str, Mapping],
                           *, downgrade: float = 0.6,
                           majority: float = 0.5) -> BottleneckReport:
    """Annotate a classification with runtime measurement-quality evidence
    (the quality records a guarded campaign persisted, aggregated per mode
    as ``{"points": n, "quarantined": n, "reasons": {reason: count}}``).

    The mirror of ``apply_audit_evidence`` for *dynamic* validity: a mode
    with any quarantined points is suspect (its curve was fit through
    condemned measurements) and multiplies the confidence by ``downgrade``;
    a mode whose points are MAJORITY-quarantined (> ``majority`` of them)
    cannot back any label at all — the report's label is refused and
    replaced with ``unreliable`` at confidence 0, naming the condemned
    modes and the dominant quarantine reasons.

    Deterministic and measurement-free: two runs over the same store attach
    byte-identical evidence.
    """
    if not quality:
        return report
    evidence = []
    refused = []
    conf = report.confidence
    for mode in sorted(quality):
        rec = quality[mode]
        points = int(rec.get("points", 0))
        quarantined = int(rec.get("quarantined", 0))
        reasons = dict(rec.get("reasons", {}))
        evidence.append({"mode": mode, "points": points,
                         "quarantined": quarantined, "reasons": reasons})
        if quarantined:
            conf *= downgrade
        if points and quarantined / points > majority:
            why = ", ".join(sorted(reasons, key=lambda r: (-reasons[r], r)))
            refused.append(f"{mode} ({quarantined}/{points} point(s) "
                           f"quarantined: {why})")
    if refused:
        return dataclasses.replace(
            report, label=UNRELIABLE, confidence=0.0, quality=evidence,
            explanation="measurement quality refuses a label — majority-"
                        "quarantined curve(s): " + "; ".join(refused)
                        + " (re-measure under a quieter clock, e.g. "
                        "fleet run --resume)")
    return dataclasses.replace(report, confidence=conf, quality=evidence)


def cross_check_with_decan(report: BottleneckReport,
                           sat_fp: float, sat_ls: float,
                           *, close: float = 0.85) -> BottleneckReport:
    """Fig. 6 logic: noise saying "overlap" (all absorptions ~0) is ambiguous
    between case 3 (genuine full overlap: BOTH DECAN variants run near the
    reference) and a shared upstream/frontend bottleneck. If DECAN shows any
    variant running substantially faster than the reference, case 3 is ruled
    out — the combined verdict is "frontend" (the paper's lloops.c_1351
    resolution, where Sat_FP=0.81 / Sat_LS=0.12 already excluded overlap).
    """
    if report.label != "overlap":
        return report
    if sat_fp >= close and sat_ls >= close:
        hint = (f"both variants near reference (Sat_FP={sat_fp:.2f}, "
                f"Sat_LS={sat_ls:.2f}) -> genuine full overlap of FP and LS")
        return dataclasses.replace(report, decan_hint=hint)
    hint = (f"DECAN rules out full overlap (Sat_FP={sat_fp:.2f}, "
            f"Sat_LS={sat_ls:.2f}) -> shared upstream (frontend-analogue) "
            "bottleneck")
    return dataclasses.replace(report, label="frontend", decan_hint=hint)

"""Analytic saturation model — absorption prediction for the TPU target.

This container has no TPU, but the dry-run compile gives per-step roofline
terms T_r (seconds each resource is busy: compute / memory / ici / serial
latency). The paper's Fig. 2 behaviour falls out of a two-parameter model:

    t(k) = alpha * max_r(T_r + k * d_r)  +  (1 - alpha) * sum_r(T_r + k * d_r)

with d_r the per-pattern cost of the noise mode on resource r and alpha the
overlap coefficient (1 = perfect overlap, the TPU ideal with async DMA/ICI;
0 = fully serial). Absorption is the knee:

    Abs^raw = max k such that t(k) <= (1 + tol) * t(0)

With alpha = 1 this reduces to the DESIGN.md closed form
Abs = (T_dom - T_tau) / d_tau — *absorption == slack of the targeted resource
measured in noise patterns*, which is exactly what the paper estimates
empirically. The same model also answers the paper's Table-4 question
("HBM or DDR for this kernel?") by re-evaluating T_r under a different
HardwareConfig.

Predictions persist: ``core.campaign.AnalyticCampaign`` runs these functions
through the campaign store ("pred" records carrying the HardwareConfig,
these StepTerms and every model setting), so predicted curves live in the
same artifact as measured ones and replay byte-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.configs.base import HardwareConfig
from repro.core.absorption import AbsorptionFit
from repro.core.noise import NoiseMode, PatternCost

RESOURCES = ("compute", "memory", "ici", "latency")


@dataclasses.dataclass(frozen=True)
class StepTerms:
    """Per-step busy seconds of each resource on ONE chip (roofline terms)."""
    compute: float
    memory: float
    ici: float = 0.0
    latency: float = 0.0

    def as_dict(self) -> dict[str, float]:
        return {r: getattr(self, r) for r in RESOURCES}

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "StepTerms":
        """Inverse of ``as_dict`` — reconstructs the terms a campaign
        ``pred`` record was computed from (its ``"terms"`` field)."""
        return cls(**{r: float(d.get(r, 0.0)) for r in RESOURCES})

    @property
    def dominant(self) -> str:
        d = self.as_dict()
        return max(d, key=d.get)

    def bound(self, alpha: float = 1.0) -> float:
        """Modeled step time (seconds)."""
        vals = list(self.as_dict().values())
        return alpha * max(vals) + (1 - alpha) * sum(vals)


def pattern_deltas(mode: NoiseMode, hw: HardwareConfig) -> dict[str, float]:
    cost: PatternCost = mode.pattern_cost(hw)
    return cost.time_on(hw)


def predict_time(terms: StepTerms, deltas: Mapping[str, float], k: float,
                 *, alpha: float = 1.0) -> float:
    vals = [terms.as_dict()[r] + k * deltas.get(r, 0.0) for r in RESOURCES]
    return alpha * max(vals) + (1 - alpha) * sum(vals)


def predict_absorption(terms: StepTerms, mode: NoiseMode, hw: HardwareConfig,
                       *, tol: float = 0.05, alpha: float = 1.0,
                       k_max: int = 1 << 20) -> AbsorptionFit:
    """Closed-form-ish absorption: binary search on the piecewise-linear t(k)."""
    deltas = pattern_deltas(mode, hw)
    t0 = predict_time(terms, deltas, 0, alpha=alpha)
    limit = (1 + tol) * t0
    if predict_time(terms, deltas, 1, alpha=alpha) > limit:
        k1 = 0.0
    elif predict_time(terms, deltas, k_max, alpha=alpha) <= limit:
        k1 = float(k_max)  # unbounded absorption at this scale
    else:
        lo, hi = 0, k_max
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if predict_time(terms, deltas, mid, alpha=alpha) <= limit:
                lo = mid
            else:
                hi = mid
        k1 = float(lo)

    # saturation slope: once noise dominates every resource it adds to the max
    slope = alpha * max(deltas.values()) + (1 - alpha) * sum(deltas.values())
    # k2: where the targeted resource becomes the global max
    tvals = terms.as_dict()
    dom = max(tvals, key=tvals.get)
    tgt = max(deltas, key=deltas.get)
    if deltas.get(tgt, 0) > 0 and tgt != dom:
        k2 = max(k1, (tvals[dom] - tvals[tgt]) / deltas[tgt])
    else:
        k2 = k1
    return AbsorptionFit(k1=k1, k2=k2, t0=t0, slope=slope, k1_threshold=k1,
                         sse=0.0, tol=tol)


def predict_curve(terms: StepTerms, mode: NoiseMode, hw: HardwareConfig,
                  ks, *, alpha: float = 1.0) -> np.ndarray:
    deltas = pattern_deltas(mode, hw)
    return np.asarray([predict_time(terms, deltas, k, alpha=alpha) for k in ks])


def compare_memory_systems(terms_by_hw: Mapping[str, StepTerms],
                           modes: Mapping[str, NoiseMode],
                           hws: Mapping[str, HardwareConfig],
                           *, tol: float = 0.05
                           ) -> dict[str, dict[str, float]]:
    """Paper Table 4: same kernel, different memory systems.

    Returns {hw_name: {"t_step": s, "<mode>": Abs, ...}} — the system with the
    smaller modeled step time *and* non-collapsed absorption profile is the
    better fit for the access pattern.
    """
    out: dict[str, dict[str, float]] = {}
    for hw_name, terms in terms_by_hw.items():
        hw = hws[hw_name]
        row: dict[str, float] = {"t_step": terms.bound()}
        for mname, mode in modes.items():
            row[mname] = predict_absorption(terms, mode, hw, tol=tol).k1
        out[hw_name] = row
    return out

"""Absorption measurement: timing, noise sweeps, and the three-phase model fit.

The paper's idealized model (Fig. 2): run time is flat up to k1 (absorption
phase), degrades through a transient, and grows linearly past k2 (saturation).
``Abs_N^raw = k1``; footnote 1 says k1 is obtained by fitting the measured
series to the model — ``fit_three_phase`` does exactly that with a hinge fit,
cross-checked by a threshold rule. ``Abs^rel = k1 / |body|`` (Eq. 1–2)
renormalizes by the size of the original loop body.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
import warnings
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

# Deterministic stand-in clock for orchestration tests and CI smoke: when this
# env var is set (to the baseline in seconds, e.g. "1e-3"), ``measure`` does
# not run or time anything — it returns a pure function of the noise quantity
# k (args[0] on the runtime-k path), so independently-run processes produce
# byte-identical stores and classifications that can be compared exactly.
# Never set it for real measurements.
SYNTH_MEASURE_VAR = "REPRO_SYNTH_MEASURE"

# Deterministic perturbations of the synthetic clock, for driving the
# measurement-integrity guard in tests and CI (all inert unless
# REPRO_SYNTH_MEASURE is also set):
#   REPRO_SYNTH_JITTER=amp    rep r>0 of every sample reads
#                             t*(1 + amp*u(k, r)) with u a hash-derived
#                             uniform in [0, 1); rep 0 is always exactly t,
#                             so MIN-OF-REPS VALUES ARE UNCHANGED — only the
#                             spread inflates (jittered and clean runs yield
#                             byte-identical curves and reports).
#   REPRO_SYNTH_DRIFT=f@n     every sample after the n-th synthetic
#                             measurement in this process is multiplied by f
#                             (mid-sweep interference for sentinel tests).
#   REPRO_SYNTH_HANG=k1,k2    a measurement at one of these noise quantities
#                             blocks until release_synth_hang() (a hung
#                             kernel for watchdog tests).
SYNTH_JITTER_VAR = "REPRO_SYNTH_JITTER"
SYNTH_DRIFT_VAR = "REPRO_SYNTH_DRIFT"
SYNTH_HANG_VAR = "REPRO_SYNTH_HANG"

_SYNTH_CALLS = 0                      # samples taken (REPRO_SYNTH_DRIFT)
_SYNTH_HANG_RELEASE = threading.Event()


def reset_synth_state() -> None:
    """Reset the synthetic clock's process state (call counter, hang latch).
    Tests that use REPRO_SYNTH_DRIFT / REPRO_SYNTH_HANG call this so one
    test's synthetic history can't leak into the next."""
    global _SYNTH_CALLS
    _SYNTH_CALLS = 0
    _SYNTH_HANG_RELEASE.clear()


def release_synth_hang() -> None:
    """Unblock any measurement parked by REPRO_SYNTH_HANG (lets a test's
    timed-out daemon thread finish instead of sleeping forever)."""
    _SYNTH_HANG_RELEASE.set()


def _synth_k(args: tuple) -> int:
    k = 0
    if args:
        try:
            a0 = np.asarray(args[0])
            if a0.ndim == 0 and np.issubdtype(a0.dtype, np.integer):
                k = int(a0)
        except (TypeError, ValueError):
            pass
    return k


@dataclasses.dataclass(frozen=True)
class SynthShape:
    """Marker that reshapes the synthetic clock for ONE measured callable.

    The default synthetic t(k) has a single knee at k=6 — every region and
    mode look alike, which is exactly wrong for calibration campaigns that
    need known-REGIME kernels (a compute-shaped target must saturate its fp
    mode immediately while absorbing l1 noise deep). A region appends a
    SynthShape to its runtime args (``args_for_rt``); the clock scans the
    argument tuple for it and moves the knee/slope accordingly. Regions
    must strip the marker before calling the real kernel (it is not an
    array), and absent a marker the clock is byte-identical to before."""
    knee: float = 6.0            # absorption Abs^raw the fit will recover
    slope: float = 0.05          # fractional slowdown per pattern past knee
    base_scale: float = 1.0      # scales the region's base time


def _synth_shape(args: tuple) -> "SynthShape | None":
    for a in args:
        if isinstance(a, SynthShape):
            return a
    return None


def _synth_time(args: tuple, base: float) -> float:
    """t(k) with a knee at k=6 — flat absorption then a linear ramp, enough
    structure for the fit/classifier to produce stable, non-trivial output.
    A ``SynthShape`` marker among the args overrides knee/slope/base (known-
    regime calibration kernels); without one the shape is unchanged."""
    shape = _synth_shape(args)
    if shape is None:
        return base * (1.0 + 0.05 * max(0, _synth_k(args) - 6))
    return base * shape.base_scale * (
        1.0 + shape.slope * max(0.0, _synth_k(args) - shape.knee))


def _synth_u(k: int, r: int) -> float:
    """Deterministic uniform in [0, 1) for rep ``r`` of noise quantity ``k``
    — hash-derived so every process, platform and run agrees."""
    h = hashlib.sha256(f"{k}:{r}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


def _synth_sample(args: tuple, base: float, *, reps: int) -> "Sample":
    """One synthetic Sample: rep 0 is the exact model time (min-of-reps and
    therefore curves/reports are jitter-invariant); later reps may be
    inflated by REPRO_SYNTH_JITTER; REPRO_SYNTH_DRIFT scales whole samples
    after its call threshold; REPRO_SYNTH_HANG parks matching ks."""
    global _SYNTH_CALLS
    k = _synth_k(args)
    hang = os.environ.get(SYNTH_HANG_VAR)
    if hang and k in {int(p) for p in hang.split(",") if p.strip()}:
        while not _SYNTH_HANG_RELEASE.wait(0.01):
            pass
    t = _synth_time(args, base)
    _SYNTH_CALLS += 1
    drift_env = os.environ.get(SYNTH_DRIFT_VAR)
    if drift_env:
        factor_s, _, at_s = drift_env.partition("@")
        if _SYNTH_CALLS > int(at_s or 0):
            t *= float(factor_s)
    amp = float(os.environ.get(SYNTH_JITTER_VAR) or 0.0)
    vals = [t]
    for r in range(1, max(1, reps)):
        vals.append(t * (1.0 + amp * _synth_u(k, r)) if amp > 0.0 else t)
    return Sample(reps=tuple(vals))

# Coarse timers (or a fully cached call) can report 0.0 s; every ratio in this
# module divides by a baseline, so baselines are floored to one timer tick.
MIN_MEASURABLE_S = 1e-9

# floor_time fires at most once per distinct ``what`` — on a fast kernel every
# point of a series trips the floor and the repeated warning floods fleet logs.
_FLOOR_WARNED: set[str] = set()


def reset_floor_warnings() -> None:
    """Forget which series already warned about the timer floor (per-test
    isolation; also bounds the dedup set in long-lived processes)."""
    _FLOOR_WARNED.clear()


def floor_time(t: float, what: str = "baseline") -> float:
    """Clamp a measured time to the minimum measurable tick, with a warning —
    a 0.0 baseline otherwise poisons every downstream ratio (t/t0, drift).
    The warning is deduplicated per ``what`` (once per series, not per call)."""
    if t < MIN_MEASURABLE_S:
        if what not in _FLOOR_WARNED:
            _FLOOR_WARNED.add(what)
            warnings.warn(
                f"{what} measured {t:.3g}s, below the {MIN_MEASURABLE_S:.0e}s "
                "timer resolution; clamping (absorption ratios for this "
                "series are unreliable)", RuntimeWarning, stacklevel=2)
        return MIN_MEASURABLE_S
    return t


@dataclasses.dataclass(frozen=True)
class Sample:
    """All rep timings of one measured point, not just the min.

    ``measure`` still reports ``t`` (min-of-reps, the paper's estimator);
    the dispersion properties are what the quality policy gates on."""
    reps: tuple[float, ...]

    def __post_init__(self) -> None:
        if not self.reps:
            raise ValueError("Sample needs at least one rep")

    @property
    def t(self) -> float:
        """Min-of-reps — the noise-robust point estimate."""
        return min(self.reps)

    @property
    def spread(self) -> float:
        """Relative spread (max-min)/min — 0 for a perfectly quiet clock."""
        t = self.t
        return (max(self.reps) - t) / max(t, MIN_MEASURABLE_S)

    @property
    def mad(self) -> float:
        """Relative median absolute deviation — a spread estimate robust to
        a single outlier rep."""
        a = np.asarray(self.reps, np.float64)
        med = float(np.median(a))
        return float(np.median(np.abs(a - med))) / max(med, MIN_MEASURABLE_S)

    def merged(self, other: "Sample") -> "Sample":
        """The pooled sample after a re-measure round."""
        return Sample(reps=self.reps + other.reps)


class MeasureTimeout(RuntimeError):
    """A measurement exceeded its watchdog deadline (hung kernel)."""


def _measure_sample_inner(fn: Callable, args: tuple, *, reps: int,
                          warmup: int, inner: int) -> Sample:
    synth = os.environ.get(SYNTH_MEASURE_VAR)
    if synth:
        return _synth_sample(args, float(synth), reps=reps)
    for _ in range(warmup):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
                 else x, out)
    vals = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
                     else x, out)
        vals.append((time.perf_counter() - t0) / inner)
    return Sample(reps=tuple(vals))


def measure_sample(fn: Callable, args: tuple = (), *, reps: int = 5,
                   warmup: int = 2, inner: int = 1,
                   deadline: Optional[float] = None) -> Sample:
    """Time ``fn(*args)`` and keep every rep (compile excluded).

    With ``deadline`` (seconds), the measurement runs on a watchdog: if it
    has not finished by then, :class:`MeasureTimeout` is raised and the hung
    call is abandoned on a daemon thread — a stuck kernel becomes a recorded
    quarantine instead of a stuck process.
    """
    if deadline is None:
        return _measure_sample_inner(fn, args, reps=reps, warmup=warmup,
                                     inner=inner)
    box: dict[str, Any] = {}

    def _run() -> None:
        try:
            box["sample"] = _measure_sample_inner(fn, args, reps=reps,
                                                  warmup=warmup, inner=inner)
        except BaseException as e:          # re-raised on the caller's thread
            box["error"] = e

    th = threading.Thread(target=_run, daemon=True,
                          name="repro-measure-watchdog")
    th.start()
    th.join(deadline)
    if th.is_alive():
        raise MeasureTimeout(
            f"measurement still running after the {deadline:.3g}s watchdog "
            "deadline (hung kernel?); abandoning it")
    if "error" in box:
        raise box["error"]
    return box["sample"]


def measure(fn: Callable, args: tuple = (), *, reps: int = 5, warmup: int = 2,
            inner: int = 1, deadline: Optional[float] = None) -> float:
    """Best-of-``reps`` wall time of ``fn(*args)`` in seconds (compile excluded).

    ``inner`` repeats the call inside the timed region for very short kernels.
    Min-of-reps is the standard noise-robust estimator for dedicated machines.
    (``measure_sample`` is the dispersion-preserving form this wraps;
    ``deadline`` raises :class:`MeasureTimeout` the same way.)
    """
    return measure_sample(fn, args, reps=reps, warmup=warmup, inner=inner,
                          deadline=deadline).t


# ---------------------------------------------------------------------------
# Sweep with online saturation detection (paper §3.1)
# ---------------------------------------------------------------------------

DEFAULT_KS = (0, 1, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256)

# online saturation rule: stop after this many consecutive points past
# stop_ratio×t0 (shared by sweep() and the campaign engine)
STOP_CONSECUTIVE = 2


def drift_corrected(ts: Sequence[float], drift: float) -> list[float]:
    """Two-point linear drift correction: the k=0 kernel re-timed after the
    sweep came out at ``drift``×t0, so divide a linear ramp out of the series.
    Implausible (>2× either way) or negligible (<2%) drift returns ``ts``
    unchanged — but an implausible factor is itself evidence of heavy
    interference, so it warns instead of being swallowed silently (the raw
    factor also lands in the campaign ``done`` record for ``fleet doctor``)."""
    if len(ts) < 3 or not (0.5 < drift < 2.0 and abs(drift - 1.0) > 0.02):
        if len(ts) >= 3 and not (0.5 < drift < 2.0):
            warnings.warn(
                f"baseline drift factor {drift:.3g} is implausible (outside "
                "0.5–2.0) — not correcting; the machine was likely under "
                "heavy interference during this sweep", RuntimeWarning,
                stacklevel=2)
        return list(ts)
    n = len(ts) - 1
    return [t / (1.0 + (drift - 1.0) * i / n) for i, t in enumerate(ts)]


@dataclasses.dataclass
class AbsorptionCurve:
    mode: str
    ks: list[int]
    ts: list[float]                  # seconds per k
    stopped_early: bool = False

    def ratios(self) -> np.ndarray:
        return np.asarray(self.ts) / floor_time(self.ts[0], "t(k=0) baseline")


def assemble_curve(mode: str, ks: Sequence[int], ts: Sequence[float], *,
                   drift: Optional[float] = None,
                   stopped_early: bool = False) -> AbsorptionCurve:
    """The ONE place a raw (ks, ts) series becomes an AbsorptionCurve.

    Campaign stores persist points RAW and re-apply the recorded drift factor
    here on every replay, so a replayed curve is byte-identical to the curve
    the original run assembled. The golden-signature regression suite pins
    this function's behaviour — change it and those tests fail loudly.
    """
    out = drift_corrected(ts, drift) if drift is not None else list(ts)
    return AbsorptionCurve(mode=mode, ks=list(ks), ts=out,
                           stopped_early=stopped_early)


def sweep(build: Callable[[int], Callable], *, mode: str = "",
          ks: Sequence[int] = DEFAULT_KS, args_for: Optional[Callable] = None,
          reps: int = 5, inner: int = 1, stop_ratio: float = 4.0,
          stop_consecutive: int = STOP_CONSECUTIVE,
          drift_correct: bool = True) -> AbsorptionCurve:
    """Measure t(k) for increasing noise quantities.

    ``build(k)`` returns the jitted noisy callable; ``args_for(k)`` its args.
    Online saturation detection (paper §3.1): stop once ``stop_consecutive``
    successive points exceed ``stop_ratio``×t(0) — the tail is already in the
    linear regime and further points only cost experiment time.

    drift_correct: on shared/throttled machines the baseline drifts between
    builds; the k=0 kernel is re-timed after the sweep and a linear drift
    factor is divided out (two-point correction).
    """
    out_ks: list[int] = []
    out_ts: list[float] = []
    n_over = 0
    stopped = False
    base_fn = build(ks[0]) if drift_correct else None
    base_args = (args_for(ks[0]) if args_for else ()) if drift_correct else ()
    for k in ks:
        fn = build(k)
        a = args_for(k) if args_for else ()
        t = measure(fn, a, reps=reps, inner=inner)
        out_ks.append(k)
        out_ts.append(t)
        if t / floor_time(out_ts[0], f"sweep({mode}) t(k=0)") > stop_ratio:
            n_over += 1
            if n_over >= stop_consecutive:
                stopped = True
                break
        else:
            n_over = 0
    if drift_correct and len(out_ts) > 2:
        t0_end = measure(base_fn, base_args, reps=max(reps - 2, 2),
                         inner=inner)
        drift = t0_end / floor_time(out_ts[0], f"sweep({mode}) t(k=0)")
        out_ts = drift_corrected(out_ts, drift)
    return AbsorptionCurve(mode=mode, ks=out_ks, ts=out_ts, stopped_early=stopped)


# ---------------------------------------------------------------------------
# Three-phase fit (Fig. 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AbsorptionFit:
    k1: float                 # absorption — patterns absorbed for free
    k2: float                 # saturation onset — linear regime begins
    t0: float                 # baseline seconds
    slope: float              # seconds per pattern in the saturation regime
    k1_threshold: float       # cross-check: last k within (1+tol)·t0
    sse: float                # fit quality
    tol: float

    @property
    def raw(self) -> float:
        """Abs^raw — the paper's absorption metric."""
        return self.k1

    def rel(self, body_size: int) -> float:
        """Abs^rel = P̂(k1) = k1 / |l1.l2| (Eq. 1–2)."""
        return self.k1 / max(body_size, 1)


def _hinge_fit(ks: np.ndarray, ts: np.ndarray) -> tuple[float, float, float, float]:
    """Least-squares fit of t(k) = max(t0, t0 + s·(k − k1)).

    Grid over candidate knees (measured ks plus midpoints), closed-form t0/s
    per candidate. Returns (k1, t0, slope, sse).
    """
    # descending order: ties in SSE (e.g. a perfectly flat curve, where any
    # knee fits equally) resolve to the LARGEST k1 — "absorbed everywhere we
    # looked", matching the threshold reading.
    cand = sorted(set(list(ks) + [(a + b) / 2 for a, b in zip(ks[:-1], ks[1:])]),
                  reverse=True)
    best = (0.0, float(ts[0]), 0.0, float("inf"))
    for k1 in cand:
        flat = ks <= k1
        rise = ~flat
        t0 = ts[flat].mean() if flat.any() else float(ts[0])
        if rise.sum() >= 1:
            x = ks[rise] - k1
            y = ts[rise] - t0
            s = float((x * y).sum() / (x * x).sum()) if (x * x).sum() else 0.0
            s = max(s, 0.0)
        else:
            s = 0.0
        pred = np.where(flat, t0, t0 + s * (ks - k1))
        sse = float(((pred - ts) ** 2).sum())
        if sse < best[3]:
            best = (float(k1), float(t0), s, sse)
    return best


def fit_three_phase(ks: Sequence[int], ts: Sequence[float], *,
                    tol: float = 0.05) -> AbsorptionFit:
    """Fit the idealized model; k1 = absorption, k2 = saturation onset.

    k2 is where the measured curve joins the linear asymptote (tail regression)
    within ``tol`` — beyond it the system "reaches asymptotic behaviour".
    """
    ka = np.asarray(ks, np.float64)
    ta = np.asarray(ts, np.float64)
    k1, t0, slope, sse = _hinge_fit(ka, ta)

    # threshold cross-check (how a human reads the plot)
    within = ta <= (1 + tol) * ta[0]
    k1_thr = float(ka[within][-1]) if within[0] else 0.0
    if not within.all():
        first_bad = int(np.argmin(within))
        k1_thr = float(ka[first_bad - 1]) if first_bad > 0 else 0.0

    # saturation onset: tail line from the last >=3 points
    if len(ka) >= 3 and slope > 0:
        xt, yt = ka[-3:], ta[-3:]
        s2 = float(np.polyfit(xt, yt, 1)[0])
        b2 = float(yt.mean() - s2 * xt.mean())
        on_line = np.abs(ta - (s2 * ka + b2)) <= tol * np.maximum(ta, 1e-12)
        k2 = float(ka[np.argmax(on_line)]) if on_line.any() else float(ka[-1])
        k2 = max(k2, k1)
    else:
        k2 = k1
    return AbsorptionFit(k1=k1, k2=k2, t0=t0, slope=slope, k1_threshold=k1_thr,
                         sse=sse, tol=tol)


def absorption(curve: AbsorptionCurve, *, tol: float = 0.05) -> AbsorptionFit:
    return fit_three_phase(curve.ks, curve.ts, tol=tol)


# ---------------------------------------------------------------------------
# Execution clustering (paper §3.1, citing [21]): group run times into
# performance classes; each class is analyzed independently. 1-D gap split.
# ---------------------------------------------------------------------------


def cluster_times(samples: Sequence[float], *, gap_ratio: float = 1.5
                  ) -> list[list[int]]:
    """Group sample indices into performance classes.

    Sorted times are split wherever the multiplicative jump between
    neighbours exceeds ``gap_ratio`` — cheap, deterministic, and adequate for
    the bimodal/multimodal run-time families the paper clusters.
    """
    order = np.argsort(samples)
    groups: list[list[int]] = [[int(order[0])]]
    s = np.asarray(samples, np.float64)
    for prev, cur in zip(order[:-1], order[1:]):
        if s[cur] > s[prev] * gap_ratio:
            groups.append([])
        groups[-1].append(int(cur))
    return groups

"""Absorption measurement: timing, noise sweeps, and the three-phase model fit.

The paper's idealized model (Fig. 2): run time is flat up to k1 (absorption
phase), degrades through a transient, and grows linearly past k2 (saturation).
``Abs_N^raw = k1``; footnote 1 says k1 is obtained by fitting the measured
series to the model — ``fit_three_phase`` does exactly that with a hinge fit,
cross-checked by a threshold rule. ``Abs^rel = k1 / |body|`` (Eq. 1–2)
renormalizes by the size of the original loop body.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

# Deterministic stand-in clock for orchestration tests and CI smoke: when this
# env var is set (to the baseline in seconds, e.g. "1e-3"), ``measure`` does
# not run or time anything — it returns a pure function of the noise quantity
# k (args[0] on the runtime-k path), so independently-run processes produce
# byte-identical stores and classifications that can be compared exactly.
# Never set it for real measurements.
SYNTH_MEASURE_VAR = "REPRO_SYNTH_MEASURE"


def _synth_time(args: tuple, base: float) -> float:
    """t(k) with a knee at k=6 — flat absorption then a linear ramp, enough
    structure for the fit/classifier to produce stable, non-trivial output."""
    k = 0
    if args:
        try:
            a0 = np.asarray(args[0])
            if a0.ndim == 0 and np.issubdtype(a0.dtype, np.integer):
                k = int(a0)
        except (TypeError, ValueError):
            pass
    return base * (1.0 + 0.05 * max(0, k - 6))

# Coarse timers (or a fully cached call) can report 0.0 s; every ratio in this
# module divides by a baseline, so baselines are floored to one timer tick.
MIN_MEASURABLE_S = 1e-9


def floor_time(t: float, what: str = "baseline") -> float:
    """Clamp a measured time to the minimum measurable tick, with a warning —
    a 0.0 baseline otherwise poisons every downstream ratio (t/t0, drift)."""
    if t < MIN_MEASURABLE_S:
        warnings.warn(
            f"{what} measured {t:.3g}s, below the {MIN_MEASURABLE_S:.0e}s "
            "timer resolution; clamping (absorption ratios for this series "
            "are unreliable)", RuntimeWarning, stacklevel=2)
        return MIN_MEASURABLE_S
    return t


def measure(fn: Callable, args: tuple = (), *, reps: int = 5, warmup: int = 2,
            inner: int = 1) -> float:
    """Best-of-``reps`` wall time of ``fn(*args)`` in seconds (compile excluded).

    ``inner`` repeats the call inside the timed region for very short kernels.
    Min-of-reps is the standard noise-robust estimator for dedicated machines.
    """
    synth = os.environ.get(SYNTH_MEASURE_VAR)
    if synth:
        return _synth_time(args, float(synth))
    for _ in range(warmup):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
                 else x, out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
                     else x, out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


# ---------------------------------------------------------------------------
# Sweep with online saturation detection (paper §3.1)
# ---------------------------------------------------------------------------

DEFAULT_KS = (0, 1, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256)

# online saturation rule: stop after this many consecutive points past
# stop_ratio×t0 (shared by sweep() and the campaign engine)
STOP_CONSECUTIVE = 2


def drift_corrected(ts: Sequence[float], drift: float) -> list[float]:
    """Two-point linear drift correction: the k=0 kernel re-timed after the
    sweep came out at ``drift``×t0, so divide a linear ramp out of the series.
    Implausible (>2×) or negligible (<2%) drift returns ``ts`` unchanged."""
    if len(ts) < 3 or not (0.5 < drift < 2.0 and abs(drift - 1.0) > 0.02):
        return list(ts)
    n = len(ts) - 1
    return [t / (1.0 + (drift - 1.0) * i / n) for i, t in enumerate(ts)]


@dataclasses.dataclass
class AbsorptionCurve:
    mode: str
    ks: list[int]
    ts: list[float]                  # seconds per k
    stopped_early: bool = False

    def ratios(self) -> np.ndarray:
        return np.asarray(self.ts) / floor_time(self.ts[0], "t(k=0) baseline")


def assemble_curve(mode: str, ks: Sequence[int], ts: Sequence[float], *,
                   drift: Optional[float] = None,
                   stopped_early: bool = False) -> AbsorptionCurve:
    """The ONE place a raw (ks, ts) series becomes an AbsorptionCurve.

    Campaign stores persist points RAW and re-apply the recorded drift factor
    here on every replay, so a replayed curve is byte-identical to the curve
    the original run assembled. The golden-signature regression suite pins
    this function's behaviour — change it and those tests fail loudly.
    """
    out = drift_corrected(ts, drift) if drift is not None else list(ts)
    return AbsorptionCurve(mode=mode, ks=list(ks), ts=out,
                           stopped_early=stopped_early)


def sweep(build: Callable[[int], Callable], *, mode: str = "",
          ks: Sequence[int] = DEFAULT_KS, args_for: Optional[Callable] = None,
          reps: int = 5, inner: int = 1, stop_ratio: float = 4.0,
          stop_consecutive: int = STOP_CONSECUTIVE,
          drift_correct: bool = True) -> AbsorptionCurve:
    """Measure t(k) for increasing noise quantities.

    ``build(k)`` returns the jitted noisy callable; ``args_for(k)`` its args.
    Online saturation detection (paper §3.1): stop once ``stop_consecutive``
    successive points exceed ``stop_ratio``×t(0) — the tail is already in the
    linear regime and further points only cost experiment time.

    drift_correct: on shared/throttled machines the baseline drifts between
    builds; the k=0 kernel is re-timed after the sweep and a linear drift
    factor is divided out (two-point correction).
    """
    out_ks: list[int] = []
    out_ts: list[float] = []
    n_over = 0
    stopped = False
    base_fn = build(ks[0]) if drift_correct else None
    base_args = (args_for(ks[0]) if args_for else ()) if drift_correct else ()
    for k in ks:
        fn = build(k)
        a = args_for(k) if args_for else ()
        t = measure(fn, a, reps=reps, inner=inner)
        out_ks.append(k)
        out_ts.append(t)
        if t / floor_time(out_ts[0], f"sweep({mode}) t(k=0)") > stop_ratio:
            n_over += 1
            if n_over >= stop_consecutive:
                stopped = True
                break
        else:
            n_over = 0
    if drift_correct and len(out_ts) > 2:
        t0_end = measure(base_fn, base_args, reps=max(reps - 2, 2),
                         inner=inner)
        drift = t0_end / floor_time(out_ts[0], f"sweep({mode}) t(k=0)")
        out_ts = drift_corrected(out_ts, drift)
    return AbsorptionCurve(mode=mode, ks=out_ks, ts=out_ts, stopped_early=stopped)


# ---------------------------------------------------------------------------
# Three-phase fit (Fig. 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AbsorptionFit:
    k1: float                 # absorption — patterns absorbed for free
    k2: float                 # saturation onset — linear regime begins
    t0: float                 # baseline seconds
    slope: float              # seconds per pattern in the saturation regime
    k1_threshold: float       # cross-check: last k within (1+tol)·t0
    sse: float                # fit quality
    tol: float

    @property
    def raw(self) -> float:
        """Abs^raw — the paper's absorption metric."""
        return self.k1

    def rel(self, body_size: int) -> float:
        """Abs^rel = P̂(k1) = k1 / |l1.l2| (Eq. 1–2)."""
        return self.k1 / max(body_size, 1)


def _hinge_fit(ks: np.ndarray, ts: np.ndarray) -> tuple[float, float, float, float]:
    """Least-squares fit of t(k) = max(t0, t0 + s·(k − k1)).

    Grid over candidate knees (measured ks plus midpoints), closed-form t0/s
    per candidate. Returns (k1, t0, slope, sse).
    """
    # descending order: ties in SSE (e.g. a perfectly flat curve, where any
    # knee fits equally) resolve to the LARGEST k1 — "absorbed everywhere we
    # looked", matching the threshold reading.
    cand = sorted(set(list(ks) + [(a + b) / 2 for a, b in zip(ks[:-1], ks[1:])]),
                  reverse=True)
    best = (0.0, float(ts[0]), 0.0, float("inf"))
    for k1 in cand:
        flat = ks <= k1
        rise = ~flat
        t0 = ts[flat].mean() if flat.any() else float(ts[0])
        if rise.sum() >= 1:
            x = ks[rise] - k1
            y = ts[rise] - t0
            s = float((x * y).sum() / (x * x).sum()) if (x * x).sum() else 0.0
            s = max(s, 0.0)
        else:
            s = 0.0
        pred = np.where(flat, t0, t0 + s * (ks - k1))
        sse = float(((pred - ts) ** 2).sum())
        if sse < best[3]:
            best = (float(k1), float(t0), s, sse)
    return best


def fit_three_phase(ks: Sequence[int], ts: Sequence[float], *,
                    tol: float = 0.05) -> AbsorptionFit:
    """Fit the idealized model; k1 = absorption, k2 = saturation onset.

    k2 is where the measured curve joins the linear asymptote (tail regression)
    within ``tol`` — beyond it the system "reaches asymptotic behaviour".
    """
    ka = np.asarray(ks, np.float64)
    ta = np.asarray(ts, np.float64)
    k1, t0, slope, sse = _hinge_fit(ka, ta)

    # threshold cross-check (how a human reads the plot)
    within = ta <= (1 + tol) * ta[0]
    k1_thr = float(ka[within][-1]) if within[0] else 0.0
    if not within.all():
        first_bad = int(np.argmin(within))
        k1_thr = float(ka[first_bad - 1]) if first_bad > 0 else 0.0

    # saturation onset: tail line from the last >=3 points
    if len(ka) >= 3 and slope > 0:
        xt, yt = ka[-3:], ta[-3:]
        s2 = float(np.polyfit(xt, yt, 1)[0])
        b2 = float(yt.mean() - s2 * xt.mean())
        on_line = np.abs(ta - (s2 * ka + b2)) <= tol * np.maximum(ta, 1e-12)
        k2 = float(ka[np.argmax(on_line)]) if on_line.any() else float(ka[-1])
        k2 = max(k2, k1)
    else:
        k2 = k1
    return AbsorptionFit(k1=k1, k2=k2, t0=t0, slope=slope, k1_threshold=k1_thr,
                         sse=sse, tol=tol)


def absorption(curve: AbsorptionCurve, *, tol: float = 0.05) -> AbsorptionFit:
    return fit_three_phase(curve.ks, curve.ts, tol=tol)


# ---------------------------------------------------------------------------
# Execution clustering (paper §3.1, citing [21]): group run times into
# performance classes; each class is analyzed independently. 1-D gap split.
# ---------------------------------------------------------------------------


def cluster_times(samples: Sequence[float], *, gap_ratio: float = 1.5
                  ) -> list[list[int]]:
    """Group sample indices into performance classes.

    Sorted times are split wherever the multiplicative jump between
    neighbours exceeds ``gap_ratio`` — cheap, deterministic, and adequate for
    the bimodal/multimodal run-time families the paper clusters.
    """
    order = np.argsort(samples)
    groups: list[list[int]] = [[int(order[0])]]
    s = np.asarray(samples, np.float64)
    for prev, cur in zip(order[:-1], order[1:]):
        if s[cur] > s[prev] * gap_ratio:
            groups.append([])
        groups[-1].append(int(cur))
    return groups

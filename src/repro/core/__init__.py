"""The paper's contribution: noise injection for bottleneck analysis, in JAX.

Two injection sites (DESIGN.md §2):
  - loop-level  (core.loopnoise + core.controller.loop_region): patterns
    emitted inside the target loop body — the LLVM-pass analogue; measured
    absorption on the host is genuine OoO absorption.
  - graph-level (core.noise + core.injector): patterns injected around a whole
    jitted train/serve step — used with payload verification and the analytic
    saturation model for the TPU dry-run target.
"""
from repro.core.absorption import (  # noqa: F401
    AbsorptionCurve,
    AbsorptionFit,
    MeasureTimeout,
    Sample,
    absorption,
    cluster_times,
    fit_three_phase,
    measure,
    measure_sample,
    sweep,
)
from repro.core.analytic import (  # noqa: F401
    StepTerms,
    compare_memory_systems,
    predict_absorption,
    predict_curve,
)
from repro.core.campaign import (AnalyticCampaign, Campaign, CampaignStats,  # noqa: F401
                                 CampaignStore, CampaignStoreError,
                                 CompactStats, MergeStats, PairStatus,
                                 compact_store, host_store, merge_stores,
                                 read_store_records, worker_store)
from repro.core.segments import (SegmentStore, io_tally, is_segmented,  # noqa: F401
                                 manifest_status, remove_store, segments_dir,
                                 store_exists)
from repro.core.classifier import (BottleneckReport, apply_audit_evidence,  # noqa: F401
                                   apply_quality_evidence, classify,
                                   cross_check_with_decan)
from repro.core.calibration import (CALIB_MODES, EXPECTED, REGIMES,  # noqa: F401
                                    CalibrationResult, calibrate_targets,
                                    fit_thresholds, forced_regime, hw_name,
                                    resolve_thresholds, run_calibration)
from repro.core.strategy import (StrategyError, StrategyTree, default_tree,  # noqa: F401
                                 load_tree, strategies_dir)
from repro.core.quality import (QualityPolicy, RemeasureBudget,  # noqa: F401
                                measure_quality, quality_from_dict)
from repro.core.controller import Controller, RegionReport, RegionTarget, loop_region  # noqa: F401
from repro.core.decan import DecanResult, DecanTarget, run_decan  # noqa: F401
from repro.core.injector import (inject, inject_rt, init_state, probe_step,  # noqa: F401
                                 step_region, verify_semantics)
from repro.core.loopnoise import LoopNoise, make_loop_modes, noisy_loop  # noqa: F401
from repro.core.noise import NOISE_SCOPE, NoiseMode, NoiseScale, PatternCost, make_modes  # noqa: F401
from repro.core.payload import InjectionReport, analyze_injection, body_size  # noqa: F401

"""Noise controller — the paper's high-level tool (§3.1/§3.2) that automates
the injection experiments: sensitivity probing, adaptive sweeps, online
saturation detection, execution clustering, payload verification, and
classification.

The paper's controller rebuilds the target application per (mode, k); ours
re-traces and re-jits — same cost model (criteria 6: "Fast: ✗"), same
mitigations (probe first with one or two quantities; coarse steps of 5–10 for
robust loops; stop the sweep online once saturation is evident).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Optional, Sequence

import jax

from repro.core.absorption import (AbsorptionCurve, AbsorptionFit, absorption,
                                   measure, sweep)
from repro.core.classifier import BottleneckReport, classify
from repro.core.loopnoise import LoopNoise, make_loop_modes
from repro.core import payload as payload_mod


@dataclasses.dataclass(frozen=True)
class RegionTarget:
    """One noisable region (the paper: a loop nest selected by pragma/config).

    ``build(mode_name, k)`` returns the jitted noisy callable;
    ``args_for(mode_name, k)`` its arguments. ``build("", 0)`` must be the
    clean reference. ``body_size``: |l1.l2| for Abs^rel; 0 = derive from HLO.
    """
    name: str
    build: Callable[[str, int], Callable]
    args_for: Callable[[str, int], tuple]
    body_size: int = 0
    payload_target: dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ModeResult:
    mode: str
    curve: AbsorptionCurve
    fit: AbsorptionFit
    injection: Optional[payload_mod.InjectionReport] = None

    def row(self) -> dict:
        return {
            "mode": self.mode,
            "abs_raw": self.fit.k1,
            "abs_threshold": self.fit.k1_threshold,
            "k2": self.fit.k2,
            "t0_s": self.fit.t0,
            "slope_s_per_pattern": self.fit.slope,
            "ks": self.curve.ks,
            "ts": self.curve.ts,
            "payload_survival": (self.injection.survival_fraction
                                 if self.injection else None),
            "payload_overhead": (self.injection.overhead_fraction
                                 if self.injection else None),
        }


@dataclasses.dataclass
class RegionReport:
    region: str
    results: dict[str, ModeResult]
    bottleneck: BottleneckReport
    body_size: int

    def absorptions(self, *, relative: bool = False) -> dict[str, float]:
        if relative and self.body_size:
            return {m: r.fit.rel(self.body_size) for m, r in self.results.items()}
        return {m: r.fit.k1 for m, r in self.results.items()}

    def to_json(self) -> str:
        return json.dumps({
            "region": self.region,
            "body_size": self.body_size,
            "bottleneck": {
                "label": self.bottleneck.label,
                "confidence": self.bottleneck.confidence,
                "explanation": self.bottleneck.explanation,
            },
            "modes": {m: r.row() for m, r in self.results.items()},
        }, indent=2)

    def summary(self) -> str:
        lines = [f"region {self.region!r}  (|body|={self.body_size})"]
        for m, r in self.results.items():
            surv = (f" payload={r.injection.survival_fraction:.0%}"
                    if r.injection else "")
            lines.append(
                f"  {m:12s} Abs^raw={r.fit.k1:7.1f}  Abs^rel="
                f"{r.fit.rel(self.body_size):6.3f}  t0={r.fit.t0*1e3:8.3f}ms"
                f"  slope={r.fit.slope*1e6:8.3f}us/pat{surv}")
        lines.append(f"  => {self.bottleneck}")
        return "\n".join(lines)


class Controller:
    """Runs the §3.2 methodology against a region."""

    def __init__(self, *, tol: float = 0.05, reps: int = 5,
                 probe_k: int = 24, stop_ratio: float = 4.0,
                 verify_payload: bool = True):
        self.tol = tol
        self.reps = reps
        self.probe_k = probe_k            # paper: "values around 20 or 30"
        self.stop_ratio = stop_ratio
        self.verify_payload = verify_payload

    # -- §3.2: one or two quantities first, to learn the sensitivity --------
    def probe_sensitivity(self, target: RegionTarget, mode: str) -> float:
        t0 = measure(target.build(mode, 0), target.args_for(mode, 0),
                     reps=max(2, self.reps - 2))
        tk = measure(target.build(mode, self.probe_k),
                     target.args_for(mode, self.probe_k),
                     reps=max(2, self.reps - 2))
        return tk / t0

    def _ks_for(self, sensitivity: float) -> Sequence[int]:
        if sensitivity > 2.0:       # very sensitive: fine steps near zero
            return (0, 1, 2, 3, 4, 6, 8, 12, 16, 24)
        if sensitivity > 1.1:       # moderate
            return (0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64)
        # robust to noise: steps of 5-10 (paper's guidance), go far
        return (0, 5, 10, 20, 30, 40, 60, 80, 120, 160, 240, 320)

    def run_mode(self, target: RegionTarget, mode: str) -> ModeResult:
        sens = self.probe_sensitivity(target, mode)
        ks = self._ks_for(sens)
        curve = sweep(lambda k: target.build(mode, k), mode=mode, ks=ks,
                      args_for=lambda k: target.args_for(mode, k),
                      reps=self.reps, stop_ratio=self.stop_ratio)
        fit = absorption(curve, tol=self.tol)
        inj = None
        if self.verify_payload:
            k_chk = next((k for k in reversed(curve.ks) if k), 8)
            fn = target.build(mode, k_chk)
            try:
                txt = fn.lower(*target.args_for(mode, k_chk)).compile().as_text()
                tgt = target.payload_target.get(mode, _default_target(mode))
                inj = payload_mod.analyze_injection(
                    txt, mode=mode, target=tgt, expected=k_chk)
            except Exception:
                inj = None  # non-jit callables: measurement only
        return ModeResult(mode=mode, curve=curve, fit=fit, injection=inj)

    def characterize(self, target: RegionTarget,
                     modes: Sequence[str] = ("fp_add", "l1_ld", "mem_ld"),
                     ) -> RegionReport:
        results = {m: self.run_mode(target, m) for m in modes}
        body = target.body_size
        if not body:
            try:
                txt = (target.build("", 0)
                       .lower(*target.args_for("", 0)).compile().as_text())
                body = payload_mod.body_size(txt)
            except Exception:
                body = 0
        report = classify({m: r.fit.k1 for m, r in results.items()})
        return RegionReport(region=target.name, results=results,
                            bottleneck=report, body_size=body)


def _default_target(mode: str) -> str:
    modes = make_loop_modes()
    if mode in modes:
        return modes[mode].target
    return {"fp_add32": "compute", "mxu_fma128": "compute",
            "vmem_ld": "vmem", "hbm_stream": "memory",
            "hbm_latency": "latency"}.get(mode, "compute")


def loop_region(name: str, make_fn: Callable[[Optional[LoopNoise], int], Callable],
                args_for: Callable[[], tuple], *, body_size: int = 0,
                rng=None) -> RegionTarget:
    """Adapter for loop-level targets: ``make_fn(noise_or_None, k)`` returns a
    jitted fn whose last positional arg is the noise carry (or no extra arg
    when noise is None)."""
    modes = make_loop_modes()
    rng = jax.random.PRNGKey(0) if rng is None else rng
    carries = {m: modes[m].init(rng) for m in modes}

    def build(mode: str, k: int):
        if not mode or k == 0:
            return make_fn(None, 0)
        return make_fn(modes[mode], k)

    def args(mode: str, k: int):
        base = args_for()
        if not mode or k == 0:
            return base
        return (*base, carries[mode])

    return RegionTarget(name=name, build=build, args_for=args,
                        body_size=body_size)

"""Noise controller — the paper's high-level tool (§3.1/§3.2) that automates
the injection experiments: sensitivity probing, adaptive sweeps, online
saturation detection, execution clustering, payload verification, and
classification.

The paper's controller rebuilds the target application per (mode, k) — its own
criteria table concedes the cost ("Fast: ✗"). This controller escapes it: on
the compile-once path the noise quantity k is a RUNTIME operand of one jitted
executable per (region, mode) (``RegionTarget.build_rt``), so a whole k-sweep
compiles O(1) executables instead of O(len(ks)). The trace-per-k path is kept
as a fallback for regions that cannot thread a traced k, and the paper's
mitigations still apply on both paths (probe first with one or two quantities;
coarse steps of 5–10 for robust loops; stop the sweep online once saturation
is evident).
"""
from __future__ import annotations

import dataclasses
import json
import logging
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.absorption import (AbsorptionCurve, AbsorptionFit, absorption,
                                   floor_time, measure, sweep)
from repro.core.classifier import HIGH, LOW, BottleneckReport, classify
from repro.core.loopnoise import LoopNoise, make_loop_modes
from repro.core import payload as payload_mod

log = logging.getLogger("repro.controller")


@dataclasses.dataclass(frozen=True)
class RegionTarget:
    """One noisable region (the paper: a loop nest selected by pragma/config).

    ``build(mode_name, k)`` returns the jitted noisy callable;
    ``args_for(mode_name, k)`` its arguments. ``build("", 0)`` must be the
    clean reference. ``body_size``: |l1.l2| for Abs^rel; 0 = derive from HLO.

    Compile-once sweeps (optional): ``build_rt(mode_name)`` returns ONE jitted
    callable taking ``(k, *args_for_rt(mode_name))`` with k an int32 runtime
    operand (or None when the mode doesn't support it); the controller then
    sweeps k without retracing. Regions without ``build_rt`` use the
    trace-per-k fallback.

    ``payload_check(mode_name, k)`` (optional) overrides the default
    HLO-scope-counting payload verification with a region-specific static
    check — Pallas regions use it to compare the noise accumulator against
    its exact oracle (scope metadata does not survive Pallas lowering).

    ``audit_hint`` (optional) parameterizes the static noise audit
    (``repro.analysis``): ``scoped`` — noise ops carry the named-scope tag
    in optimized HLO (graph/loop regions; Pallas bodies do not);
    ``in_loop`` — patterns are emitted inside the region's loop body, so
    the audit checks for loop-invariant hoisting / fusion-into-consumer;
    ``steps`` — per-sweep-point executions of the noise body.
    """
    name: str
    build: Callable[[str, int], Callable]
    args_for: Callable[[str, int], tuple]
    body_size: int = 0
    payload_target: dict[str, str] = dataclasses.field(default_factory=dict)
    build_rt: Optional[Callable[[str], Optional[Callable]]] = None
    args_for_rt: Optional[Callable[[str], tuple]] = None
    payload_check: Optional[Callable[[str, int], object]] = None
    audit_hint: Optional[dict] = None


@dataclasses.dataclass
class ModeResult:
    mode: str
    curve: AbsorptionCurve
    fit: AbsorptionFit
    injection: Optional[payload_mod.InjectionReport] = None

    def row(self) -> dict:
        return {
            "mode": self.mode,
            "abs_raw": self.fit.k1,
            "abs_threshold": self.fit.k1_threshold,
            "k2": self.fit.k2,
            "t0_s": self.fit.t0,
            "slope_s_per_pattern": self.fit.slope,
            "ks": self.curve.ks,
            "ts": self.curve.ts,
            "payload_survival": (self.injection.survival_fraction
                                 if self.injection else None),
            "payload_overhead": (self.injection.overhead_fraction
                                 if self.injection else None),
        }


@dataclasses.dataclass
class RegionReport:
    region: str
    results: dict[str, ModeResult]
    bottleneck: BottleneckReport
    body_size: int

    def absorptions(self, *, relative: bool = False) -> dict[str, float]:
        if relative and self.body_size:
            return {m: r.fit.rel(self.body_size) for m, r in self.results.items()}
        return {m: r.fit.k1 for m, r in self.results.items()}

    def to_json(self) -> str:
        bn = {
            "label": self.bottleneck.label,
            "confidence": self.bottleneck.confidence,
            "explanation": self.bottleneck.explanation,
        }
        # static audit evidence serializes only when attached — non-audited
        # reports stay byte-identical to pre-audit output
        if getattr(self.bottleneck, "evidence", None):
            bn["evidence"] = self.bottleneck.evidence
        # likewise runtime measurement-quality evidence: attached only when
        # a quality guard found something to say, so clean runs' reports
        # stay byte-identical to unguarded ones
        if getattr(self.bottleneck, "quality", None):
            bn["quality"] = self.bottleneck.quality
        return json.dumps({
            "region": self.region,
            "body_size": self.body_size,
            "bottleneck": bn,
            "modes": {m: r.row() for m, r in self.results.items()},
        }, indent=2)

    def summary(self) -> str:
        lines = [f"region {self.region!r}  (|body|={self.body_size})"]
        for m, r in self.results.items():
            surv = (f" payload={r.injection.survival_fraction:.0%}"
                    if r.injection else "")
            lines.append(
                f"  {m:12s} Abs^raw={r.fit.k1:7.1f}  Abs^rel="
                f"{r.fit.rel(self.body_size):6.3f}  t0={r.fit.t0*1e3:8.3f}ms"
                f"  slope={r.fit.slope*1e6:8.3f}us/pat{surv}")
        lines.append(f"  => {self.bottleneck}")
        return "\n".join(lines)


class Controller:
    """Runs the §3.2 methodology against a region."""

    def __init__(self, *, tol: float = 0.05, reps: int = 5,
                 probe_k: int = 24, stop_ratio: float = 4.0,
                 verify_payload: bool = True, compile_once: bool = True):
        self.tol = tol
        self.reps = reps
        self.probe_k = probe_k            # paper: "values around 20 or 30"
        self.stop_ratio = stop_ratio
        self.verify_payload = verify_payload
        self.compile_once = compile_once  # use build_rt when the region has it
        # memoize runtime-k callables per (target, mode): build_rt returns a
        # fresh jit wrapper each call, and jax's compile cache keys on the
        # callable's identity — without this the sensitivity probe and the
        # sweep would each trace their own copy of the SAME program. Keyed
        # by target IDENTITY (two targets may share a name but close over
        # different buffers); the entry pins the target so its id() cannot
        # be recycled onto a stale executable.
        self._rt_cache: dict[tuple[int, str],
                             tuple[RegionTarget, Optional[Callable]]] = {}

    def _rt_fn(self, target: RegionTarget, mode: str) -> Optional[Callable]:
        """The region's runtime-k callable, or None -> trace-per-k fallback."""
        if not self.compile_once or target.build_rt is None:
            return None
        key = (id(target), mode)
        if key not in self._rt_cache:
            self._rt_cache[key] = (target, target.build_rt(mode))
        return self._rt_cache[key][1]

    # -- §3.2: one or two quantities first, to learn the sensitivity --------
    def probe_sensitivity(self, target: RegionTarget, mode: str,
                          deadline: Optional[float] = None) -> float:
        reps = max(2, self.reps - 2)
        fn_rt = self._rt_fn(target, mode)
        if fn_rt is not None:
            args = target.args_for_rt(mode)
            t0 = measure(fn_rt, (jnp.int32(0), *args), reps=reps,
                         deadline=deadline)
            tk = measure(fn_rt, (jnp.int32(self.probe_k), *args), reps=reps,
                         deadline=deadline)
        else:
            t0 = measure(target.build(mode, 0), target.args_for(mode, 0),
                         reps=reps, deadline=deadline)
            tk = measure(target.build(mode, self.probe_k),
                         target.args_for(mode, self.probe_k), reps=reps,
                         deadline=deadline)
        return tk / floor_time(t0, f"probe_sensitivity({target.name}/{mode}) t0")

    def _ks_for(self, sensitivity: float) -> Sequence[int]:
        if sensitivity > 2.0:       # very sensitive: fine steps near zero
            return (0, 1, 2, 3, 4, 6, 8, 12, 16, 24)
        if sensitivity > 1.1:       # moderate
            return (0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64)
        # robust to noise: steps of 5-10 (paper's guidance), go far
        return (0, 5, 10, 20, 30, 40, 60, 80, 120, 160, 240, 320)

    def run_mode(self, target: RegionTarget, mode: str,
                 ks: Optional[Sequence[int]] = None) -> ModeResult:
        """Sweep one mode. Compile-once path: the sensitivity probe and every
        sweep point reuse ONE runtime-k executable; payload verification adds
        one static-k executable — at most 2 compilations for the whole sweep
        (the fallback path compiles one per k, the paper's cost model).

        ``ks``: override the sensitivity-chosen quantities (campaign resume).
        """
        fn_rt = self._rt_fn(target, mode)
        if ks is None:
            ks = self._ks_for(self.probe_sensitivity(target, mode))
        if fn_rt is not None:
            args_rt = target.args_for_rt(mode)
            curve = sweep(lambda k: fn_rt, mode=mode, ks=ks,
                          args_for=lambda k: (jnp.int32(k), *args_rt),
                          reps=self.reps, stop_ratio=self.stop_ratio)
        else:
            curve = sweep(lambda k: target.build(mode, k), mode=mode, ks=ks,
                          args_for=lambda k: target.args_for(mode, k),
                          reps=self.reps, stop_ratio=self.stop_ratio)
        fit = absorption(curve, tol=self.tol)
        inj = self.verify_mode_payload(target, mode, curve.ks) \
            if self.verify_payload else None
        return ModeResult(mode=mode, curve=curve, fit=fit, injection=inj)

    def verify_mode_payload(self, target: RegionTarget, mode: str,
                            ks: Sequence[int]):
        """Static payload check (§2.3) on a trace-per-k executable — the HLO
        of the runtime-k path holds ONE pattern in a loop body, so surviving
        ops must be counted on a static unrolled trace. Regions with a
        ``payload_check`` override (Pallas kernels) verify against their own
        oracle instead."""
        k_chk = next((k for k in reversed(list(ks)) if k), 8)
        if target.payload_check is not None:
            try:
                return target.payload_check(mode, k_chk)
            except Exception:
                log.warning("payload check failed for %s/%s k=%d",
                            target.name, mode, k_chk, exc_info=True)
                return None
        fn = target.build(mode, k_chk)
        if not hasattr(fn, "lower"):
            # expected: region builds a plain (non-jitted) callable with no
            # .lower/.compile — measurement only, nothing to verify statically
            return None
        try:
            txt = fn.lower(*target.args_for(mode, k_chk)).compile().as_text()
            tgt = target.payload_target.get(mode, _default_target(mode))
            return payload_mod.analyze_injection(txt, mode=mode, target=tgt,
                                                 expected=k_chk)
        except Exception:
            log.warning("payload verification failed for %s/%s k=%d",
                        target.name, mode, k_chk, exc_info=True)
            return None

    def characterize(self, target: RegionTarget,
                     modes: Sequence[str] = ("fp_add", "l1_ld", "mem_ld"),
                     *, low: float = LOW, high: float = HIGH) -> RegionReport:
        """Sweep every mode and classify the region; ``low``/``high`` are
        the effective classification thresholds (pass a calibration's
        fitted values — ``repro.core.calibration`` — to classify under
        them; the defaults reproduce the paper constants)."""
        results = {m: self.run_mode(target, m) for m in modes}
        body = target.body_size
        if not body:
            body = derive_body_size(target)
        report = classify({m: r.fit.k1 for m, r in results.items()},
                          low=low, high=high)
        return RegionReport(region=target.name, results=results,
                            bottleneck=report, body_size=body)


def derive_body_size(target: RegionTarget) -> int:
    """|l1.l2| from the clean reference's optimized HLO (0 when the region
    builds a plain callable with nothing to lower)."""
    fn = target.build("", 0)
    if not hasattr(fn, "lower"):
        return 0
    try:
        txt = fn.lower(*target.args_for("", 0)).compile().as_text()
        return payload_mod.body_size(txt)
    except Exception:
        log.warning("body-size derivation failed for %s", target.name,
                    exc_info=True)
        return 0


def _default_target(mode: str) -> str:
    modes = make_loop_modes()
    if mode in modes:
        return modes[mode].target
    return {"fp_add32": "compute", "mxu_fma128": "compute",
            "vmem_ld": "vmem", "hbm_stream": "memory",
            "hbm_latency": "latency",
            # Pallas kernel-level vocabulary (repro.kernels.noise_slots)
            "fp": "compute", "mxu": "compute", "vmem": "vmem",
            }.get(mode, "compute")


def loop_region(name: str, make_fn: Callable[[Optional[LoopNoise], int], Callable],
                args_for: Callable[[], tuple], *, body_size: int = 0,
                rng=None) -> RegionTarget:
    """Adapter for loop-level targets: ``make_fn(noise_or_None, k)`` returns a
    jitted fn whose last positional arg is the noise carry (or no extra arg
    when noise is None).

    Compile-once support comes for free as long as ``make_fn`` passes its k
    straight through to ``noise.emit(carry, k, i)`` (the documented contract):
    ``build_rt`` hands make_fn a LoopNoise whose emit ignores that static k and
    runs the runtime-k emitter with a k captured from the jitted signature.
    """
    modes = make_loop_modes()
    rng = jax.random.PRNGKey(0) if rng is None else rng
    carries = {m: modes[m].init(rng) for m in modes}

    def build(mode: str, k: int):
        if not mode or k == 0:
            return make_fn(None, 0)
        return make_fn(modes[mode], k)

    def args(mode: str, k: int):
        base = args_for()
        if not mode or k == 0:
            return base
        return (*base, carries[mode])

    def build_rt(mode: str):
        noise = modes[mode]
        if noise.emit_rt is None:
            return None

        def fn(k, *args_and_carry):
            rt_noise = dataclasses.replace(
                noise, emit=lambda nc, _k, i: noise.emit_rt(nc, k, i))
            # the static k=1 handed to make_fn is a placeholder; every
            # pattern is emitted by the runtime-k fori_loop above
            return make_fn(rt_noise, 1)(*args_and_carry)

        return jax.jit(fn)

    def args_rt(mode: str):
        return (*args_for(), carries[mode])

    return RegionTarget(name=name, build=build, args_for=args,
                        body_size=body_size, build_rt=build_rt,
                        args_for_rt=args_rt,
                        audit_hint={"scoped": True, "in_loop": True})

"""Loop-body noise emitters — the direct analogue of the paper's LLVM pass.

The paper injects assembly patterns INTO the target loop body so the CPU's
out-of-order engine can overlap them with the original instructions. The JAX
analogue: kernels written as ``lax.fori_loop``/``lax.scan`` expose a *noise
slot* in their body; the emitters below generate k patterns there. XLA:CPU
compiles the body to one machine loop, so the host's real OoO engine performs
the absorption — measured host signatures are genuine, not simulated
(validated: a memory-bound triad absorbs 64+ fp patterns, a compute-bound FMA
chain saturates from k≈8).

Protocol (mirrors core.noise graph-level modes, but loop-carried):

  init(rng)               -> carry pytree of small noise buffers (disjoint
                             from kernel state: the paper's R_n ∩ R_s = ∅)
  emit(carry, k, i)       -> new carry, after issuing k patterns; ``i`` is the
                             loop induction variable (varies offsets so the
                             compiler cannot hoist or CSE patterns); k is a
                             static python int baked into the trace
  emit_rt(carry, k, i)    -> same patterns with k a RUNTIME operand (traced
                             int32, inner bounded ``lax.fori_loop``): one
                             jitted executable serves the whole k-sweep
  finalize(carry)         -> scalar aux (returned from the jitted function —
                             the `volatile` analogue: DCE-proof)

Every pattern is emitted under ``named_scope(NOISE_SCOPE)`` so payload
verification (core.payload) can count surviving ops in optimized HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import NOISE_SCOPE, N_CHAINS

VEC = 8  # noise vector width (one AVX2 f32 register / one VPU sublane group)


@dataclasses.dataclass(frozen=True)
class LoopNoise:
    name: str
    target: str                       # compute | l1 | memory | latency
    init: Callable[[jax.Array], Any]
    emit: Callable[[Any, int, jax.Array], Any]
    finalize: Callable[[Any], jax.Array]
    payload_op: str = "add"           # dominant HLO opcode of one pattern
    # runtime-k emitter (compile-once sweeps); None = trace-per-k only
    emit_rt: Optional[Callable[[Any, jax.Array, jax.Array], Any]] = None
    description: str = ""


# ---------------------------------------------------------------------------
# fp_add — chained vector adds, round-robin over N_CHAINS accumulators
# (paper Fig. 1a: fadd d31/d30/d29/d28)
# ---------------------------------------------------------------------------

def _fp_init(rng):
    c = jax.random.normal(rng, (VEC,), jnp.float32) * 1e-6
    return {"c": c, "accs": tuple(jnp.zeros((VEC,), jnp.float32)
                                  for _ in range(N_CHAINS))}


def _fp_emit(carry, k, i):
    del i
    accs = list(carry["accs"])
    with jax.named_scope(NOISE_SCOPE):
        for j in range(k):
            accs[j % N_CHAINS] = accs[j % N_CHAINS] + carry["c"]
    return dict(carry, accs=tuple(accs))


def _fp_finalize(carry):
    return sum(jnp.sum(a) for a in carry["accs"])


def _unstack(accs):
    return tuple(accs[j] for j in range(N_CHAINS))


def _fp_emit_rt(carry, k, i):
    del i
    c = carry["c"]
    accs = jnp.stack(carry["accs"])
    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(
            0, k, lambda j, a: a.at[j % N_CHAINS].add(c), accs)
    return dict(carry, accs=_unstack(accs))


# ---------------------------------------------------------------------------
# fp_fma — multiply-add patterns (denser issue on FMA ports than plain add)
# ---------------------------------------------------------------------------

def _fma_emit(carry, k, i):
    del i
    accs = list(carry["accs"])
    c = carry["c"]
    with jax.named_scope(NOISE_SCOPE):
        for j in range(k):
            accs[j % N_CHAINS] = accs[j % N_CHAINS] * 0.999999 + c
    return dict(carry, accs=tuple(accs))


def _fma_emit_rt(carry, k, i):
    del i
    c = carry["c"]
    accs = jnp.stack(carry["accs"])

    def one(j, a):
        return a.at[j % N_CHAINS].set(a[j % N_CHAINS] * 0.999999 + c)

    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(0, k, one, accs)
    return dict(carry, accs=_unstack(accs))


# ---------------------------------------------------------------------------
# l1_ld — reads of a small cache-resident buffer at rotating offsets
# (paper Fig. 1c: l1_ld64)
# ---------------------------------------------------------------------------

L1_ROWS = 512  # 512*8*4B = 16 KiB: comfortably L1-resident


def _l1_init(rng):
    return {"buf": jax.random.normal(rng, (L1_ROWS, VEC), jnp.float32),
            "accs": tuple(jnp.zeros((VEC,), jnp.float32)
                          for _ in range(N_CHAINS))}


def _l1_emit(carry, k, i):
    buf = carry["buf"]
    accs = list(carry["accs"])
    with jax.named_scope(NOISE_SCOPE):
        for j in range(k):
            # offset varies with the induction variable AND the pattern index:
            # not hoistable, not CSE-able, still always an L1 hit.
            off = (i * 7 + j * 13) % L1_ROWS
            row = jax.lax.dynamic_slice(buf, (off, 0), (1, VEC))[0]
            accs[j % N_CHAINS] = accs[j % N_CHAINS] + row
    return dict(carry, accs=tuple(accs))


def _l1_emit_rt(carry, k, i):
    buf = carry["buf"]
    accs = jnp.stack(carry["accs"])

    def one(j, a):
        off = (i * 7 + j * 13) % L1_ROWS
        row = jax.lax.dynamic_slice(buf, (off, 0), (1, VEC))[0]
        return a.at[j % N_CHAINS].add(row)

    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(0, k, one, accs)
    return dict(carry, accs=_unstack(accs))


# ---------------------------------------------------------------------------
# mem_ld — strided reads of a dedicated buffer far larger than LLC
# (paper: memory_ld64, bandwidth flavour)
# ---------------------------------------------------------------------------

MEM_ROWS = 1 << 21  # 2M rows * 32B = 64 MiB >> LLC


def _mem_init(rng):
    del rng  # too big to fill with normals; iota is fine (never a constant)
    buf = (jnp.arange(MEM_ROWS * VEC, dtype=jnp.float32)
           .reshape(MEM_ROWS, VEC) * 1e-9)
    return {"buf": buf, "accs": tuple(jnp.zeros((VEC,), jnp.float32)
                                      for _ in range(N_CHAINS))}


def _mem_emit(carry, k, i):
    buf = carry["buf"]
    accs = list(carry["accs"])
    with jax.named_scope(NOISE_SCOPE):
        for j in range(k):
            # large co-prime stride: each pattern touches a fresh cache line
            # region; hardware prefetch gets no simple stream.
            off = ((i * (k or 1) + j) * 40_503) % MEM_ROWS
            row = jax.lax.dynamic_slice(buf, (off, 0), (1, VEC))[0]
            accs[j % N_CHAINS] = accs[j % N_CHAINS] + row
    return dict(carry, accs=tuple(accs))


def _mem_emit_rt(carry, k, i):
    buf = carry["buf"]
    accs = jnp.stack(carry["accs"])
    k_eff = jnp.maximum(k, 1)   # traced analogue of (k or 1)

    def one(j, a):
        off = ((i * k_eff + j) * 40_503) % MEM_ROWS
        row = jax.lax.dynamic_slice(buf, (off, 0), (1, VEC))[0]
        return a.at[j % N_CHAINS].add(row)

    with jax.named_scope(NOISE_SCOPE):
        accs = jax.lax.fori_loop(0, k, one, accs)
    return dict(carry, accs=_unstack(accs))


# ---------------------------------------------------------------------------
# chase — serially dependent loads (paper: memory_ld64 latency flavour /
# lat_mem_rd's own access pattern). The dependency chain is the point.
# ---------------------------------------------------------------------------

CHASE_LEN = 1 << 20  # 4 MiB of int32 — larger than L2


def _chase_init(rng):
    seed = int(np.asarray(jax.random.key_data(rng)).ravel()[-1]) % (2**31)
    perm = np.random.RandomState(seed).permutation(CHASE_LEN).astype(np.int32)
    table = np.empty(CHASE_LEN, np.int32)
    table[perm[:-1]] = perm[1:]
    table[perm[-1]] = perm[0]
    return {"table": jnp.asarray(table), "idx": jnp.int32(int(perm[0]))}


def _chase_emit(carry, k, i):
    del i
    table, idx = carry["table"], carry["idx"]
    with jax.named_scope(NOISE_SCOPE):
        for _ in range(k):
            idx = jax.lax.dynamic_slice(table, (idx,), (1,))[0]
    return dict(carry, idx=idx)


def _chase_emit_rt(carry, k, i):
    del i
    table = carry["table"]

    def one(_, idx):
        return jax.lax.dynamic_slice(table, (idx,), (1,))[0]

    with jax.named_scope(NOISE_SCOPE):
        idx = jax.lax.fori_loop(0, k, one, carry["idx"])
    return dict(carry, idx=idx)


def _chase_finalize(carry):
    return carry["idx"].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def make_loop_modes() -> dict[str, LoopNoise]:
    return {
        "fp_add": LoopNoise(
            "fp_add", "compute", _fp_init, _fp_emit, _fp_finalize, "add",
            emit_rt=_fp_emit_rt,
            description="round-robin chained vector adds (paper: fp_add64)"),
        "fp_fma": LoopNoise(
            "fp_fma", "compute", _fp_init, _fma_emit, _fp_finalize, "add",
            emit_rt=_fma_emit_rt,
            description="round-robin chained FMAs — saturates FMA ports faster"),
        "l1_ld": LoopNoise(
            "l1_ld", "l1", _l1_init, _l1_emit, _fp_finalize, "dynamic-slice",
            emit_rt=_l1_emit_rt,
            description="rotating reads of a 16 KiB resident buffer "
                        "(paper: l1_ld64)"),
        "mem_ld": LoopNoise(
            "mem_ld", "memory", _mem_init, _mem_emit, _fp_finalize,
            "dynamic-slice", emit_rt=_mem_emit_rt,
            description="strided reads of a 64 MiB buffer (paper: memory_ld64)"),
        "chase": LoopNoise(
            "chase", "latency", _chase_init, _chase_emit, _chase_finalize,
            "dynamic-slice", emit_rt=_chase_emit_rt,
            description="serially dependent pointer chase (latency probe)"),
    }


# Paper-facing aliases.
PAPER_LOOP_ALIASES = {
    "fp_add64": "fp_add",
    "l1_ld64": "l1_ld",
    "memory_ld64": "mem_ld",
}


def noisy_loop(body, n_iter, init_carry, noise: LoopNoise, k: int, rng=None):
    """Run ``body(i, carry) -> carry`` for ``n_iter`` iterations with ``k``
    noise patterns of ``noise`` emitted per iteration.

    Returns (final_carry, noise_aux). This is the generic injection site used
    by the bench ports; kernels with custom structure call ``noise.emit``
    directly in their own loop bodies.
    """
    rng = jax.random.PRNGKey(0) if rng is None else rng
    nc0 = noise.init(rng)

    def full_body(i, state):
        carry, nc = state
        carry = body(i, carry)
        nc = noise.emit(nc, k, i)
        return carry, nc

    carry, nc = jax.lax.fori_loop(0, n_iter, full_body, (init_carry, nc0))
    return carry, noise.finalize(nc)

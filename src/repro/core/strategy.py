"""Declarative strategy trees: the classifier's decision logic as data.

The paper's decision table (§4.2 / Table 3) was originally an if-chain in
``repro.core.classifier.classify``. This module re-expresses it as a
STRATEGY TREE loaded from ``strategies/*.yaml``: an ordered list of nodes,
each a boolean predicate over the resolved mode slots and the LOW/HIGH
thresholds; the first node whose predicate holds names the bottleneck,
its separation expression scores the confidence, and its explanation
template renders the human-readable rationale. New vocabularies or
backends add a YAML file, not classifier code — and every classification
now carries the evaluated decision path (which nodes were tried, which
fired, under which thresholds), the raw material for
``fleet doctor --explain``.

Schema (``strategies/default.yaml`` is the reference):

* ``strategy: 1`` — schema version;
* ``name`` — the tree's name (echoed in decision paths);
* ``slots`` — mapping slot name -> ordered mode-alias list; the first
  alias present in the signature binds the slot (None when absent);
* ``groups`` — mapping group name -> mode-name prefix; the group binds
  to the sub-signature of modes with that prefix (``icis: "ici"``);
* ``nodes`` — ordered list; each node has ``name``, ``label``, ``when``
  (a guarded boolean expression over slots/groups/``known``/``low``/
  ``high``), exactly one of ``sep`` (separation expression, clamped to a
  confidence by ``sep / high`` into [0, 1]) or ``fixed`` (literal
  confidence), and ``explanation`` (a ``str.format`` template; for each
  group prefix ``p`` the key ``worst_p`` names the group's worst mode).

Expressions are compiled once at load and evaluated with empty builtins
against a whitelisted namespace — slot/group names, ``known`` (the
non-None slots), ``low``/``high``, and ``min``/``max``/``bool``/``abs``.
Comprehensions, lambdas and any other name are rejected at load time.

Trees resolve from the repo's ``strategies/`` directory (override with
``REPRO_STRATEGY_DIR``). Files parse with PyYAML when available and with
the built-in YAML-subset parser otherwise (runtime needs only
jax/jaxlib + numpy; the test suite pins both parsers to agree on every
shipped tree).
"""
from __future__ import annotations

import dataclasses
import os
import types
from typing import Any, Mapping, Optional

STRATEGY_SCHEMA = 1

# the strategies/ directory sits at the repo root, next to src/
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
STRATEGY_DIR_VAR = "REPRO_STRATEGY_DIR"

# names an expression may reference beyond the tree's slots/groups
_BASE_NAMES = frozenset({"known", "low", "high", "min", "max", "bool", "abs"})
# attribute/method names (compile() lists them in co_names too)
_ATTR_NAMES = frozenset({"values", "keys", "items", "get"})


class StrategyError(ValueError):
    """A strategy tree failed to load, validate, or decide."""


# ---------------------------------------------------------------------------
# YAML-subset parser (fallback when PyYAML is absent at runtime)
# ---------------------------------------------------------------------------

def _parse_scalar(s: str) -> Any:
    if s.startswith('"') and s.endswith('"') and len(s) >= 2:
        body = s[1:-1]
        if "\\" in body or '"' in body:
            raise StrategyError(
                f"escaped/nested quotes unsupported by the subset parser: {s!r}")
        return body
    if s.startswith("[") and s.endswith("]"):
        inner = s[1:-1].strip()
        if not inner:
            return []
        return [_parse_scalar(p.strip()) for p in inner.split(",")]
    if s in ("true", "True"):
        return True
    if s in ("false", "False"):
        return False
    if s in ("null", "~"):
        return None
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    return s


def _parse_block(items: list, i: int, indent: int):
    if items[i][1].startswith("- "):
        out_list: list = []
        while (i < len(items) and items[i][0] == indent
               and items[i][1].startswith("- ")):
            head = items[i][1][2:].strip()
            j = i + 1
            children = []
            while j < len(items) and items[j][0] > indent:
                children.append(items[j])
                j += 1
            sub = [(indent + 2, head)] + children
            val, used = _parse_block(sub, 0, indent + 2)
            if used != len(sub):
                raise StrategyError(f"unparsed lines in list item near {head!r}")
            out_list.append(val)
            i = j
        return out_list, i
    out: dict = {}
    while (i < len(items) and items[i][0] == indent
           and not items[i][1].startswith("- ")):
        line = items[i][1]
        key, sep, rest = line.partition(":")
        if not sep or not key.strip():
            raise StrategyError(f"expected 'key: value', got {line!r}")
        key, rest = key.strip(), rest.strip()
        if rest:
            out[key] = _parse_scalar(rest)
            i += 1
        else:
            j = i + 1
            if j >= len(items) or items[j][0] <= indent:
                out[key] = None
                i = j
            else:
                out[key], i = _parse_block(items, j, items[j][0])
    return out, i


def _parse_simple_yaml(text: str) -> Any:
    """Parse the YAML subset ``strategies/*.yaml`` is written in: nested
    maps by 2-space indent, block lists of maps (``- key: value``), flow
    lists of scalars, double-quoted strings, ints/floats/bools/null, and
    full-line ``#`` comments. The test suite asserts this agrees with
    ``yaml.safe_load`` on every shipped tree, so environments without
    PyYAML load byte-identical strategies."""
    items = []
    for raw in text.splitlines():
        if not raw.strip() or raw.lstrip().startswith("#"):
            continue
        items.append((len(raw) - len(raw.lstrip(" ")), raw.strip()))
    if not items:
        return None
    value, used = _parse_block(items, 0, items[0][0])
    if used != len(items):
        raise StrategyError(
            f"unparsed trailing content near {items[used][1]!r}")
    return value


def _load_yaml(text: str) -> Any:
    try:
        import yaml
    except ModuleNotFoundError:
        return _parse_simple_yaml(text)
    return yaml.safe_load(text)


# ---------------------------------------------------------------------------
# Guarded expressions
# ---------------------------------------------------------------------------

def _compile_expr(expr: Any, allowed: frozenset, where: str):
    if not isinstance(expr, str):
        raise StrategyError(f"{where}: expression must be a string, "
                            f"got {type(expr).__name__}")
    try:
        code = compile(expr, f"<{where}>", "eval")
    except SyntaxError as e:
        raise StrategyError(f"{where}: {e}") from None
    if any(isinstance(c, types.CodeType) for c in code.co_consts):
        raise StrategyError(
            f"{where}: comprehensions/lambdas are not allowed")
    bad = sorted(set(code.co_names) - allowed - _ATTR_NAMES)
    if bad:
        raise StrategyError(
            f"{where}: expression references unknown name(s) {bad} "
            f"(allowed: {sorted(allowed)})")
    return code


def _eval(code, namespace: dict):
    return eval(code, {"__builtins__": {}}, namespace)  # noqa: S307 (guarded)


@dataclasses.dataclass(frozen=True)
class StrategyNode:
    """One compiled decision node: predicate -> label + confidence +
    explanation template."""
    name: str
    label: str
    when: Any                       # compiled boolean expression
    sep: Optional[Any]              # compiled separation expression, or None
    fixed: Optional[float]          # literal confidence when sep is None
    explanation: str


@dataclasses.dataclass(frozen=True)
class Decision:
    """What a tree decided for one signature, plus the evaluated path."""
    label: str
    confidence: float
    explanation: str
    path: dict


class StrategyTree:
    """An ordered, compiled decision tree loaded from a strategy spec."""

    def __init__(self, spec: Mapping, *, source: str = "<spec>"):
        if not isinstance(spec, Mapping):
            raise StrategyError(f"{source}: strategy spec must be a mapping")
        if spec.get("strategy") != STRATEGY_SCHEMA:
            raise StrategyError(
                f"{source}: unsupported strategy schema "
                f"{spec.get('strategy')!r} (want {STRATEGY_SCHEMA})")
        self.source = source
        self.name = str(spec.get("name") or "unnamed")
        slots = spec.get("slots") or {}
        groups = spec.get("groups") or {}
        if not isinstance(slots, Mapping) or not slots:
            raise StrategyError(f"{source}: 'slots' must be a non-empty map")
        self.slots = {str(s): [str(a) for a in aliases]
                      for s, aliases in slots.items()}
        self.groups = {str(g): str(p) for g, p in (groups or {}).items()}
        allowed = frozenset(self.slots) | frozenset(self.groups) | _BASE_NAMES
        nodes = spec.get("nodes")
        if not isinstance(nodes, list) or not nodes:
            raise StrategyError(f"{source}: 'nodes' must be a non-empty list")
        self.nodes: list[StrategyNode] = []
        for n in nodes:
            name = str(n.get("name") or f"node{len(self.nodes)}")
            where = f"{self.name}.{name}"
            if not n.get("label"):
                raise StrategyError(f"{where}: missing 'label'")
            if ("sep" in n) == ("fixed" in n):
                raise StrategyError(
                    f"{where}: exactly one of 'sep'/'fixed' required")
            if not isinstance(n.get("explanation"), str):
                raise StrategyError(f"{where}: missing 'explanation'")
            self.nodes.append(StrategyNode(
                name=name, label=str(n["label"]),
                when=_compile_expr(n.get("when"), allowed, f"{where}.when"),
                sep=(_compile_expr(n["sep"], allowed, f"{where}.sep")
                     if "sep" in n else None),
                fixed=(float(n["fixed"]) if "fixed" in n else None),
                explanation=n["explanation"]))

    @classmethod
    def from_file(cls, path: str) -> "StrategyTree":
        """Load and compile one ``strategies/*.yaml`` tree."""
        with open(path) as f:
            text = f.read()
        return cls(_load_yaml(text), source=path)

    def decide(self, absorptions: Mapping[str, float], *, low: float,
               high: float) -> Decision:
        """Evaluate the tree against one absorption signature.

        Nodes are tried in order; the first truthy predicate fires. The
        returned :class:`Decision` carries the full evaluated path: bound
        slots/groups, the thresholds, every node tried with its outcome."""
        slots: dict[str, Optional[float]] = {}
        for slot, aliases in self.slots.items():
            v = None
            for a in aliases:
                if a in absorptions:
                    v = absorptions[a]
                    break
            slots[slot] = v
        groups = {g: {m: a for m, a in absorptions.items()
                      if m.startswith(p)} for g, p in self.groups.items()}
        known = {s: v for s, v in slots.items() if v is not None}
        namespace = {**slots, **groups, "known": known, "low": low,
                     "high": high, "min": min, "max": max, "bool": bool,
                     "abs": abs}
        fmt: dict[str, Any] = {"low": low, "high": high}
        for g, p in self.groups.items():
            members = groups[g]
            fmt[f"worst_{p}"] = (min(members, key=members.get)
                                 if members else "")
        tried = []
        fired: Optional[StrategyNode] = None
        for node in self.nodes:
            ok = bool(_eval(node.when, dict(namespace)))
            tried.append({"node": node.name, "fired": ok})
            if ok:
                fired = node
                break
        if fired is None:
            raise StrategyError(
                f"{self.source}: no node fired for signature "
                f"{dict(absorptions)!r} (the last node should be a "
                "catch-all with when: \"True\")")
        if fired.fixed is not None:
            confidence = fired.fixed
        else:
            sep = float(_eval(fired.sep, dict(namespace)))
            confidence = max(0.0, min(1.0, sep / high))
        try:
            explanation = fired.explanation.format(**fmt)
        except (KeyError, IndexError) as e:
            raise StrategyError(
                f"{self.name}.{fired.name}: explanation template "
                f"references unknown key {e}") from None
        path = {
            "strategy": self.name,
            "low": low,
            "high": high,
            "slots": slots,
            "groups": {g: dict(v) for g, v in groups.items()},
            "nodes": tried,
            "fired": fired.name,
            "label": fired.label,
        }
        return Decision(label=fired.label, confidence=confidence,
                        explanation=explanation, path=path)


# ---------------------------------------------------------------------------
# Tree resolution + cache
# ---------------------------------------------------------------------------

_TREES: dict[str, StrategyTree] = {}


def strategies_dir() -> str:
    """The directory strategy trees load from — the repo's ``strategies/``
    unless ``REPRO_STRATEGY_DIR`` overrides it."""
    return (os.environ.get(STRATEGY_DIR_VAR)
            or os.path.join(_REPO_ROOT, "strategies"))


def load_tree(name: str = "default") -> StrategyTree:
    """Load (and cache) ``strategies/<name>.yaml``."""
    path = os.path.abspath(os.path.join(strategies_dir(), name + ".yaml"))
    if path not in _TREES:
        _TREES[path] = StrategyTree.from_file(path)
    return _TREES[path]


def default_tree() -> StrategyTree:
    """The default tree — byte-identical decisions to the historical
    ``classify`` if-chain under the default thresholds (golden-pinned)."""
    return load_tree("default")

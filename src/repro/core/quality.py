"""Measurement-quality policy: valid / re-measure / quarantine decisions.

The paper's classification rests on trusting small t(k)/t(0) deltas, so a
measurement that cannot be trusted must not flow unmarked into a curve.
This module is the single place that decides what "cannot be trusted"
means at runtime (PR 6's audit pass is the static counterpart):

  * ``QualityPolicy`` — thresholds: relative spread across reps, the
    timer-resolution floor, sentinel cadence/tolerance for mid-sweep
    baseline drift, and the per-point watchdog deadline.
  * ``RemeasureBudget`` — bounded extra reps: a noisy sample earns a few
    more repetitions before it is condemned, never unbounded retries.
  * ``decide(sample, policy)`` — the valid / re-measure / quarantine
    decision table over a :class:`repro.core.absorption.Sample`.
  * ``measure_quality(...)`` — the re-measure loop: merge extra reps into
    the sample until the spread stabilizes or the budget is exhausted.

Quarantine reasons are a closed vocabulary (``REASONS``) so stores,
``fleet doctor`` and the classifier agree on *why* a point was rejected:

  * ``timer_floor`` — the time is below the trustworthy timer resolution;
  * ``spread``      — rep dispersion stayed above ``max_spread`` after the
                      re-measure budget;
  * ``drift_span``  — a baseline sentinel moved more than ``sentinel_tol``,
                      invalidating the span since the previous sentinel;
  * ``timeout``     — the watchdog deadline expired (hung kernel).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.absorption import Sample

# closed quarantine-reason vocabulary (stores / doctor / classifier share it)
REASON_TIMER_FLOOR = "timer_floor"
REASON_SPREAD = "spread"
REASON_DRIFT_SPAN = "drift_span"
REASON_TIMEOUT = "timeout"
REASONS = (REASON_TIMER_FLOOR, REASON_SPREAD, REASON_DRIFT_SPAN,
           REASON_TIMEOUT)

VERDICT_VALID = "valid"
VERDICT_REMEASURE = "remeasure"
VERDICT_QUARANTINE = "quarantine"


@dataclass(frozen=True)
class QualityPolicy:
    """Thresholds for the runtime measurement-integrity guard.

    ``sentinel_every`` and ``watchdog_floor_s`` default to 0 = off, so a
    policy-less campaign behaves exactly like the pre-guard code path.
    """
    max_spread: float = 0.15        # max relative (max-min)/min across reps
    timer_floor_s: float = 1e-8     # below this, the timer itself is noise
    sentinel_every: int = 0         # re-time k=0 every N points (0 = off)
    sentinel_tol: float = 0.25      # baseline may move this much, relatively
    watchdog_margin: float = 8.0    # deadline = margin * expected worst time
    watchdog_floor_s: float = 0.0   # minimum deadline; 0 disables watchdog

    def __post_init__(self) -> None:
        if self.max_spread <= 0:
            raise ValueError(f"max_spread must be > 0, got {self.max_spread}")
        if self.timer_floor_s < 0:
            raise ValueError("timer_floor_s must be >= 0, got "
                             f"{self.timer_floor_s}")
        if self.sentinel_every < 0:
            raise ValueError("sentinel_every must be >= 0, got "
                             f"{self.sentinel_every}")
        if self.sentinel_tol <= 0:
            raise ValueError("sentinel_tol must be > 0, got "
                             f"{self.sentinel_tol}")
        if self.watchdog_margin <= 0:
            raise ValueError("watchdog_margin must be > 0, got "
                             f"{self.watchdog_margin}")
        if self.watchdog_floor_s < 0:
            raise ValueError("watchdog_floor_s must be >= 0, got "
                             f"{self.watchdog_floor_s}")

    @property
    def watchdog_on(self) -> bool:
        return self.watchdog_floor_s > 0

    def deadline(self, t0: Optional[float], *, stop_ratio: float,
                 reps: int, warmup: int = 0, inner: int = 1
                 ) -> Optional[float]:
        """Per-point watchdog deadline in seconds, or None when off.

        Derived from the worst time the online stop rule would accept —
        ``stop_ratio * t(0)`` per call, across every warmup+rep call —
        scaled by ``watchdog_margin``.  Before t(0) is known (the k=0
        point itself) only the floor applies.
        """
        if not self.watchdog_on:
            return None
        if t0 is None:
            return self.watchdog_floor_s
        calls = max(1, warmup + reps) * max(1, inner)
        return max(self.watchdog_floor_s,
                   self.watchdog_margin * stop_ratio * t0 * calls)

    def to_dict(self) -> dict:
        return {"max_spread": self.max_spread,
                "timer_floor_s": self.timer_floor_s,
                "sentinel_every": self.sentinel_every,
                "sentinel_tol": self.sentinel_tol,
                "watchdog_margin": self.watchdog_margin,
                "watchdog_floor_s": self.watchdog_floor_s}


@dataclass(frozen=True)
class RemeasureBudget:
    """Bounded re-measurement: how much extra timing a noisy point earns
    before quarantine.  ``max_total_reps`` caps the merged sample so a
    pathological clock cannot consume unbounded wall time."""
    max_attempts: int = 2       # extra measure rounds beyond the first
    extra_reps: int = 3         # reps per extra round
    max_total_reps: int = 12    # hard cap on merged sample size

    def __post_init__(self) -> None:
        if self.max_attempts < 0:
            raise ValueError("max_attempts must be >= 0, got "
                             f"{self.max_attempts}")
        if self.extra_reps < 1:
            raise ValueError(f"extra_reps must be >= 1, got "
                             f"{self.extra_reps}")
        if self.max_total_reps < 1:
            raise ValueError("max_total_reps must be >= 1, got "
                             f"{self.max_total_reps}")

    def to_dict(self) -> dict:
        return {"max_attempts": self.max_attempts,
                "extra_reps": self.extra_reps,
                "max_total_reps": self.max_total_reps}


def decide(sample: Sample, policy: QualityPolicy, *,
           can_remeasure: bool = True) -> tuple[str, Optional[str]]:
    """The decision table: (verdict, reason).

    ``timer_floor`` wins over everything (more reps cannot fix a timer);
    an in-tolerance spread is ``valid``; an out-of-tolerance spread is
    ``remeasure`` while budget remains, else ``quarantine``.
    """
    if sample.t < policy.timer_floor_s:
        return VERDICT_QUARANTINE, REASON_TIMER_FLOOR
    if sample.spread <= policy.max_spread:
        return VERDICT_VALID, None
    if can_remeasure:
        return VERDICT_REMEASURE, None
    return VERDICT_QUARANTINE, REASON_SPREAD


def measure_quality(measure_once: Callable[[int], Sample], *, reps: int,
                    policy: QualityPolicy,
                    budget: Optional[RemeasureBudget] = None
                    ) -> tuple[Sample, str, Optional[str]]:
    """Measure one point under the policy: time it, and while the spread
    verdict is ``remeasure``, take ``budget.extra_reps`` more timings.

    The spread verdict is judged on the LATEST round alone: transient
    interference during one round is exactly what re-measurement forgives,
    and a clean later round vindicates the point. The returned sample is
    the MERGE of every round (its min is the best-supported time), so a
    vindicated point still benefits from all the timings taken. The
    timer-floor check uses the merged minimum — more reps cannot fix a
    timer, so a sub-floor time quarantines immediately.

    ``measure_once(n)`` must return a fresh :class:`Sample` of n reps.
    Returns ``(sample, verdict, reason)`` where verdict is ``valid`` or
    ``quarantine`` (never ``remeasure`` — the loop resolves it).
    """
    budget = budget or RemeasureBudget()
    sample = latest = measure_once(reps)
    attempts = 0
    while True:
        if sample.t < policy.timer_floor_s:
            return sample, VERDICT_QUARANTINE, REASON_TIMER_FLOOR
        if latest.spread <= policy.max_spread:
            return sample, VERDICT_VALID, None
        extra = min(budget.extra_reps,
                    budget.max_total_reps - len(sample.reps))
        # a 1-rep round has zero spread by construction and would vindicate
        # anything — if that's all the budget leaves, the point is condemned
        if attempts >= budget.max_attempts or extra < 2:
            return sample, VERDICT_QUARANTINE, REASON_SPREAD
        latest = measure_once(extra)
        sample = sample.merged(latest)
        attempts += 1


_POLICY_KEYS = frozenset(QualityPolicy().to_dict())
_BUDGET_KEYS = frozenset(RemeasureBudget().to_dict())


def quality_from_dict(d: dict) -> tuple[QualityPolicy, RemeasureBudget]:
    """Build (policy, budget) from one flat dict — the shape a SweepPlan's
    ``quality`` field and ``--quality-policy`` carry.  Unknown keys are an
    error: a typoed threshold silently ignored is a policy not applied."""
    if not isinstance(d, dict):
        raise ValueError(f"quality policy must be a dict, got {type(d).__name__}")
    unknown = sorted(set(d) - _POLICY_KEYS - _BUDGET_KEYS)
    if unknown:
        raise ValueError(
            "unknown quality key(s) " + ", ".join(unknown) + "; policy keys: "
            + ", ".join(sorted(_POLICY_KEYS)) + "; budget keys: "
            + ", ".join(sorted(_BUDGET_KEYS)))
    try:
        policy = QualityPolicy(**{k: d[k] for k in d if k in _POLICY_KEYS})
        budget = RemeasureBudget(**{k: d[k] for k in d if k in _BUDGET_KEYS})
    except (TypeError, ValueError) as e:
        raise ValueError(f"bad quality policy: {e}")
    return policy, budget

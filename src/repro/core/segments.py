"""Segmented campaign stores — append-only segments plus a checksummed
manifest.

A legacy campaign store is ONE JSONL file: every open re-reads all of it and
every merge rewrites all of it, which is O(store) per fleet round and the
scaling wall for million-point campaigns. A *segmented* store replaces the
single file with a directory next to the store path::

    experiments/campaigns/sweep.jsonl            # (absent — path is a name)
    experiments/campaigns/sweep.segments/
        MANIFEST.json                            # checksummed index
        000001-4242-0-9f1c.jsonl                 # sealed segment
        000002-4311-0-02ab.jsonl                 # unsealed (live writer)

Rules that make this safe without any locking:

  * segments are APPEND-ONLY while open and IMMUTABLE once sealed — a writer
    session opens a fresh segment, appends records to it, and seals it into
    the manifest at ``close()``; nothing ever appends to a sealed segment;
  * the manifest records each sealed segment's id, byte length, record count
    and per-(region, mode) pair coverage, plus a ``folded`` list of segment
    ids already compacted away; a sha256 checksum over the canonical JSON
    detects edits/bit-rot (checksum mismatch refuses to load);
  * replay order is deterministic: manifest segments in manifest order, then
    unsealed orphans sorted by filename (ids start with a zero-padded
    sequence number). Supersede semantics are therefore a property of READ
    time, exactly as in a legacy single file;
  * a writer killed before sealing leaves an *orphan* segment: the next
    writable open heals it — truncates a torn tail and seals it into the
    manifest — while readonly opens just tolerate it. Orphans whose id is in
    ``folded`` are garbage from an interrupted compaction (their records
    already live in the compacted segment) and are deleted, never replayed;
  * ``adopt_segments`` is the incremental merge: it copies whole segments a
    destination has never seen (id not in manifest or ``folded``) and skips
    the rest — cost is O(new segments), never O(store). Legacy single-file
    sources are adopted as one content-addressed snapshot segment.

``read_store_records`` (the line-streaming JSONL reader shared with legacy
stores) and ``CampaignStoreError`` live here so ``repro.core.campaign`` can
build both layouts on one tolerant read path.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import logging
import os
import shutil
from typing import Iterable, Iterator, Optional, Sequence

log = logging.getLogger("repro.segments")

SEGMENT_SCHEMA = 1
MANIFEST_NAME = "MANIFEST.json"

_SEG_COUNT = itertools.count()


class CampaignStoreError(RuntimeError):
    """A store is corrupt in a way the loader must not paper over."""


# ---------------------------------------------------------------------------
# Streaming JSONL read path (shared by legacy files and segments)
# ---------------------------------------------------------------------------

_IO_TALLY = {"bytes": 0, "records": 0}


def io_tally(*, reset: bool = False) -> dict:
    """Process-wide tally of store bytes/records parsed by
    ``read_store_records`` — the measurement behind the incremental-merge
    guarantee (folding one new segment into an N-segment store reads O(new
    segment), not O(store)). Returns ``{"bytes": b, "records": n}``;
    ``reset=True`` zeroes the counters after reading them."""
    out = dict(_IO_TALLY)
    if reset:
        _IO_TALLY["bytes"] = 0
        _IO_TALLY["records"] = 0
    return out


def read_store_records(path: str) -> tuple[list[dict], int]:
    """Parse a JSONL store, streaming line-by-line, tolerating a truncated
    FINAL line.

    A process killed between ``write`` and ``flush`` leaves a partial last
    record; that is expected damage and costs at most one point, so it is
    dropped with a warning. A malformed record with valid records AFTER it
    cannot come from a torn append — that store is corrupt, and loading it
    raises ``CampaignStoreError``.

    Returns ``(records, valid_bytes)`` where ``valid_bytes`` is the length of
    the clean prefix (the caller may truncate the file to it).
    """
    records: list[dict] = []
    valid = 0
    pos = 0
    bad: Optional[tuple[int, int, Exception]] = None  # (pos, len, error)
    with open(path, "rb") as f:
        for raw in f:
            line = raw.strip()
            if line:
                if bad is not None:
                    # valid-looking data AFTER a corrupt record: not a torn
                    # append — refuse to load rather than silently drop
                    raise CampaignStoreError(
                        f"{path}: corrupt record at byte {bad[0]} with valid "
                        f"records after it ({bad[2]}); refusing to load"
                    ) from bad[2]
                try:
                    rec = json.loads(line.decode("utf-8"))
                    if not isinstance(rec, dict):
                        raise ValueError(f"record is {type(rec).__name__}, "
                                         "not an object")
                except (UnicodeDecodeError, ValueError) as e:
                    n = len(raw) - (1 if raw.endswith(b"\n") else 0)
                    bad = (pos, n, e)
                    pos += len(raw)
                    continue
                records.append(rec)
                _IO_TALLY["records"] += 1
            pos += len(raw)
            if bad is None:
                valid = pos
    _IO_TALLY["bytes"] += pos
    if bad is not None:
        log.warning(
            "%s: dropping truncated final record (%d bytes) — a previous "
            "run died mid-append", path, bad[1])
    return records, valid


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------


def segments_dir(path: str) -> str:
    """The segment directory of a store path: ``base.jsonl`` ->
    ``base.segments``."""
    base, _ = os.path.splitext(path)
    return base + ".segments"


def is_segmented(path: str) -> bool:
    """True when a segment directory exists for this store path."""
    return os.path.isdir(segments_dir(path))


def store_exists(path: str) -> bool:
    """True when a store exists at ``path`` in EITHER layout (legacy single
    file or segment directory) — the existence check every caller that used
    ``os.path.exists(store)`` must use instead."""
    return os.path.exists(path) or is_segmented(path)


def remove_store(path: str) -> None:
    """Delete a store in whichever layout(s) it exists."""
    if os.path.exists(path):
        os.unlink(path)
    sdir = segments_dir(path)
    if os.path.isdir(sdir):
        shutil.rmtree(sdir)


def _seq_of(sid: str) -> int:
    head = sid.split("-", 1)[0]
    return int(head) if head.isdigit() else 0


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------


def _fresh_manifest() -> dict:
    return {"segment_store": SEGMENT_SCHEMA, "next_seq": 1,
            "segments": [], "folded": []}


def manifest_checksum(m: dict) -> str:
    """sha256 over the canonical JSON of the manifest minus ``checksum``."""
    body = {k: v for k, v in m.items() if k != "checksum"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode("utf-8")).hexdigest()


def load_manifest(sdir: str) -> dict:
    """Load and verify a segment directory's manifest (fresh when absent)."""
    p = os.path.join(sdir, MANIFEST_NAME)
    if not os.path.exists(p):
        return _fresh_manifest()
    try:
        with open(p) as f:
            m = json.load(f)
    except ValueError as e:
        raise CampaignStoreError(
            f"{p}: manifest is not valid JSON ({e})") from e
    if not isinstance(m, dict) or m.get("segment_store") != SEGMENT_SCHEMA:
        raise CampaignStoreError(
            f"{p}: unsupported segment_store schema "
            f"{m.get('segment_store') if isinstance(m, dict) else m!r}")
    if m.get("checksum") != manifest_checksum(m):
        raise CampaignStoreError(
            f"{p}: manifest checksum mismatch — the manifest was edited or "
            "the disk lies; refusing to load")
    m.setdefault("segments", [])
    m.setdefault("folded", [])
    return m


def save_manifest(sdir: str, m: dict) -> None:
    """Checksum and atomically publish a manifest (tmp + rename)."""
    m = dict(m)
    m["checksum"] = manifest_checksum(m)
    tmp = os.path.join(sdir, f"{MANIFEST_NAME}.tmp-{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump(m, f, sort_keys=True, indent=1)
        f.write("\n")
    os.replace(tmp, os.path.join(sdir, MANIFEST_NAME))


# -- per-segment pair coverage (what `fleet watch` renders) -----------------


def _cov_add(cov: dict, rec: dict) -> None:
    key = (rec.get("region"), rec.get("mode"))
    c = cov.setdefault(key, {"region": key[0], "mode": key[1],
                             "points": 0, "done": False})
    kind = rec.get("kind")
    if kind == "point":
        c["points"] += 1
    elif kind == "done":
        c["done"] = True
    elif kind == "quality":
        # last verdict per k within this segment (JSON keys are strings);
        # the key is absent for segments with no quality records, so
        # pre-guard manifests keep their exact shape
        c.setdefault("quality", {})[str(rec.get("k"))] = rec.get("verdict")


def _cov_list(cov: dict) -> list[dict]:
    return [cov[k] for k in sorted(cov, key=lambda k: (str(k[0]), str(k[1])))]


def _coverage(records: Iterable[dict]) -> list[dict]:
    cov: dict = {}
    for rec in records:
        _cov_add(cov, rec)
    return _cov_list(cov)


# ---------------------------------------------------------------------------
# SegmentStore: the write/replay backend behind CampaignStore
# ---------------------------------------------------------------------------


class SegmentStore:
    """One campaign store as a directory of append-only segment files.

    This is a storage BACKEND: it replays raw records and appends raw lines;
    supersede semantics, in-memory views, and the public store API stay in
    ``repro.core.campaign.CampaignStore``, which delegates here when the
    store is segmented.
    """

    def __init__(self, path: str, *, readonly: bool = False):
        self.path = path
        self.dir = segments_dir(path)
        self.readonly = readonly
        self._f = None          # active (unsealed) segment file handle
        self._sid: Optional[str] = None
        self._seq = 0
        self._n_records = 0
        self._cov: dict = {}
        if not os.path.isdir(self.dir):
            if readonly:
                raise FileNotFoundError(
                    f"campaign store {path} does not exist")
            os.makedirs(self.dir, exist_ok=True)
            save_manifest(self.dir, _fresh_manifest())

    # -- replay -------------------------------------------------------------
    def load(self) -> list[dict]:
        """Replay every record in deterministic order: manifest segments in
        manifest (adoption) order, then orphans sorted by filename. Writable
        opens heal orphans — torn tails truncated, then sealed into the
        manifest — and delete folded leftovers; readonly opens change
        nothing on disk."""
        m = load_manifest(self.dir)
        out: list[dict] = []
        listed: set[str] = set()
        for ent in m["segments"]:
            fp = os.path.join(self.dir, ent["file"])
            listed.add(ent["file"])
            if not os.path.exists(fp):
                raise CampaignStoreError(
                    f"{self.path}: manifest names segment {ent['file']} but "
                    "the file is missing")
            size = os.path.getsize(fp)
            recs, valid = read_store_records(fp)
            if size != int(ent["bytes"]) or valid != size:
                raise CampaignStoreError(
                    f"{self.path}: sealed segment {ent['file']} is {size} "
                    f"bytes ({valid} valid), manifest says {ent['bytes']} — "
                    "sealed segments are immutable; refusing to load")
            out.extend(recs)
        folded = set(m["folded"])
        healed = False
        for name in sorted(os.listdir(self.dir)):
            if name in listed or not name.endswith(".jsonl"):
                continue
            sid = name[:-len(".jsonl")]
            fp = os.path.join(self.dir, name)
            if sid in folded:
                # interrupted compaction leftovers: these records already
                # live in the compacted segment — never replay them
                if not self.readonly:
                    os.unlink(fp)
                continue
            recs, valid = read_store_records(fp)   # tolerates a torn tail
            out.extend(recs)
            if self.readonly:
                continue
            if not recs:
                os.unlink(fp)
                continue
            if valid < os.path.getsize(fp):
                with open(fp, "r+b") as f:
                    f.truncate(valid)
            m["segments"].append({
                "id": sid, "file": name, "bytes": valid,
                "records": len(recs), "pairs": _coverage(recs)})
            m["next_seq"] = max(int(m.get("next_seq", 1)), _seq_of(sid) + 1)
            healed = True
            log.warning("%s: healed unsealed segment %s (%d record(s)) — a "
                        "previous writer died before sealing",
                        self.path, name, len(recs))
        if healed:
            save_manifest(self.dir, m)
        return out

    # -- append -------------------------------------------------------------
    def append_line(self, line: str, rec: dict) -> None:
        """Append one already-serialized record to this session's segment
        (opened lazily on first append) and flush it."""
        if self.readonly:
            raise RuntimeError(f"store {self.path} was opened readonly")
        if self._f is None:
            self._open_segment()
        self._f.write(line + "\n")
        self._f.flush()
        self._n_records += 1
        _cov_add(self._cov, rec)

    def _open_segment(self) -> None:
        m = load_manifest(self.dir)
        self._seq = int(m.get("next_seq", 1))
        self._sid = (f"{self._seq:06d}-{os.getpid()}-{next(_SEG_COUNT)}"
                     f"-{os.urandom(2).hex()}")
        self._f = open(os.path.join(self.dir, self._sid + ".jsonl"), "a")

    def close(self) -> None:
        """Seal this session's segment into the manifest (drop it when it
        never received a record). Until this runs the segment is an orphan —
        replayable, healed by the next writable open — so a crash loses at
        most the usual one torn record."""
        if self._f is None:
            return
        self._f.close()
        self._f = None
        fp = os.path.join(self.dir, self._sid + ".jsonl")
        if self._n_records == 0:
            os.unlink(fp)
            self._sid = None
            return
        # re-load: another writer may have sealed its segment meanwhile;
        # last sealer wins the manifest race and the loser's segment comes
        # back as a healed orphan on the next writable open
        m = load_manifest(self.dir)
        if all(e["id"] != self._sid for e in m["segments"]):
            m["segments"].append({
                "id": self._sid, "file": self._sid + ".jsonl",
                "bytes": os.path.getsize(fp), "records": self._n_records,
                "pairs": _cov_list(self._cov)})
        m["next_seq"] = max(int(m.get("next_seq", 1)), self._seq + 1)
        save_manifest(self.dir, m)
        self._sid = None
        self._n_records = 0
        self._cov = {}


# ---------------------------------------------------------------------------
# Incremental merge: adopt whole segments the destination has never seen
# ---------------------------------------------------------------------------


def _source_segments(src: str) -> Iterator[tuple[str, str, Optional[int]]]:
    """Yield ``(segment_id, file_path, sealed_bytes)`` for a merge source in
    replay order; ``sealed_bytes`` is None for unsealed/legacy content (adopt
    the valid prefix). Legacy single-file stores yield one content-addressed
    snapshot segment, so re-merging an unchanged file is a no-op and a grown
    file becomes a NEW snapshot whose records supersede the old one at read
    time (compaction reclaims the overlap)."""
    if is_segmented(src):
        sdir = segments_dir(src)
        sm = load_manifest(sdir)
        listed: set[str] = set()
        for ent in sm["segments"]:
            fp = os.path.join(sdir, ent["file"])
            listed.add(ent["file"])
            if not os.path.exists(fp):
                raise CampaignStoreError(
                    f"{src}: manifest names segment {ent['file']} but the "
                    "file is missing")
            yield ent["id"], fp, int(ent["bytes"])
        folded = set(sm["folded"])
        for name in sorted(os.listdir(sdir)):
            if (name.endswith(".jsonl") and name not in listed
                    and name[:-len(".jsonl")] not in folded):
                yield name[:-len(".jsonl")], os.path.join(sdir, name), None
    else:
        _, valid = read_store_records(src)   # validate before snapshotting
        with open(src, "rb") as f:
            digest = hashlib.sha256(f.read(valid)).hexdigest()
        yield f"lgcy-{digest[:12]}", src, None


def _copy_prefix(src_fp: str, dst_fp: str, nbytes: int) -> None:
    tmp = f"{dst_fp}.tmp-{os.getpid()}"
    try:
        with open(src_fp, "rb") as s, open(tmp, "wb") as t:
            remaining = nbytes
            while remaining > 0:
                chunk = s.read(min(1 << 20, remaining))
                if not chunk:
                    break
                t.write(chunk)
                remaining -= len(chunk)
        os.replace(tmp, dst_fp)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def adopt_segments(dest: str, sources: Sequence[str]) -> dict:
    """Fold ``sources`` into a segmented ``dest`` by ADOPTING whole segments.

    Every source segment whose id the destination manifest has never seen
    (neither live nor ``folded``) is copied in and appended to the manifest;
    everything else is skipped without reading a byte of record data — the
    incremental-merge contract. Unsealed source segments (a crashed writer's
    orphan) are adopted under a content-suffixed id, so if the source later
    seals that segment with MORE records, the sealed version is adopted too
    and its records supersede the partial snapshot at read time.

    Records never need rewriting because supersede semantics resolve at read
    time; dest-as-source is a no-op. Returns ``{"records_in", "records_out",
    "segments_new", "segments_skipped"}``.
    """
    ddir = segments_dir(dest)
    if not os.path.isdir(ddir):
        os.makedirs(ddir, exist_ok=True)
        save_manifest(ddir, _fresh_manifest())
    m = load_manifest(ddir)
    known = {e["id"] for e in m["segments"]} | set(m["folded"])
    dest_real = os.path.realpath(ddir)
    new = skipped = records_in = 0
    for src in sources:
        if os.path.realpath(segments_dir(src)) == dest_real:
            continue                    # dest as its own source: nothing new
        for sid, fp, sealed_bytes in _source_segments(src):
            if sid in known and sealed_bytes is not None:
                skipped += 1
                continue
            recs, valid = read_store_records(fp)
            if sealed_bytes is not None and valid != sealed_bytes:
                raise CampaignStoreError(
                    f"{src}: sealed segment {os.path.basename(fp)} has only "
                    f"{valid} valid bytes of {sealed_bytes}; refusing to "
                    "adopt a torn sealed segment")
            if sealed_bytes is None:
                # unsealed orphan: content-address the snapshot so a later
                # sealed (grown) version of the same segment is NOT skipped
                if not sid.startswith("lgcy-"):
                    with open(fp, "rb") as f:
                        tail = hashlib.sha256(f.read(valid)).hexdigest()[:8]
                    sid = f"{sid}-t{tail}"
                if sid in known:
                    skipped += 1
                    continue
            if not recs:
                continue
            name = sid + ".jsonl"
            _copy_prefix(fp, os.path.join(ddir, name), valid)
            m["segments"].append({
                "id": sid, "file": name, "bytes": valid,
                "records": len(recs), "pairs": _coverage(recs)})
            m["next_seq"] = max(int(m.get("next_seq", 1)), _seq_of(sid) + 1)
            known.add(sid)
            new += 1
            records_in += len(recs)
    save_manifest(ddir, m)
    return {"records_in": records_in,
            "records_out": sum(int(e.get("records", 0))
                               for e in m["segments"]),
            "segments_new": new, "segments_skipped": skipped}


# ---------------------------------------------------------------------------
# Compaction commit + manifest-driven live status
# ---------------------------------------------------------------------------


def replace_all_segments(path: str, lines: Sequence[str],
                         records: Sequence[dict]) -> dict:
    """The compaction commit: write ``lines`` as ONE new segment, publish a
    manifest whose ``folded`` list names every prior segment id (so an
    interrupted cleanup can never resurrect superseded records, and future
    incremental merges still skip already-folded source segments), then
    delete the old segment files. Returns ``{"bytes_in", "bytes_out",
    "segments_in"}``."""
    sdir = segments_dir(path)
    m = load_manifest(sdir)
    old = m["segments"]
    bytes_in = sum(int(e["bytes"]) for e in old)
    seq = int(m.get("next_seq", 1))
    sid = f"{seq:06d}-compact-{os.getpid()}-{next(_SEG_COUNT)}"
    name = sid + ".jsonl"
    tmp = os.path.join(sdir, f"{name}.tmp-{os.getpid()}")
    with open(tmp, "w") as f:
        for ln in lines:
            f.write(ln + "\n")
    os.replace(tmp, os.path.join(sdir, name))
    nbytes = os.path.getsize(os.path.join(sdir, name))
    save_manifest(sdir, {
        "segment_store": SEGMENT_SCHEMA, "next_seq": seq + 1,
        "segments": [{"id": sid, "file": name, "bytes": nbytes,
                      "records": len(records), "pairs": _coverage(records)}],
        "folded": sorted(set(m["folded"]) | {e["id"] for e in old})})
    for ent in old:
        try:
            os.unlink(os.path.join(sdir, ent["file"]))
        except FileNotFoundError:
            pass
    return {"bytes_in": bytes_in, "bytes_out": nbytes,
            "segments_in": len(old)}


def manifest_status(path: str) -> dict:
    """Live store status from the manifest ALONE — no segment file is read,
    so ``fleet watch`` can poll this every couple of seconds against a store
    that active writers are appending to. Returns segment/record/byte totals,
    unsealed-orphan counts (live or crashed writers), and aggregated
    per-(region, mode) pair coverage ``{(r, m): {"points": n, "done": b,
    "quarantined": n}}`` from the sealed segments' coverage entries."""
    sdir = segments_dir(path)
    m = load_manifest(sdir)
    pairs: dict[tuple, dict] = {}
    verdicts: dict[tuple, dict] = {}
    records = nbytes = 0
    for ent in m["segments"]:
        records += int(ent.get("records", 0))
        nbytes += int(ent.get("bytes", 0))
        for c in ent.get("pairs", []):
            key = (c.get("region"), c.get("mode"))
            p = pairs.setdefault(key, {"points": 0, "done": False,
                                       "quarantined": 0})
            p["points"] += int(c.get("points", 0))
            p["done"] = p["done"] or bool(c.get("done"))
            # segments are listed in seal order, so a later segment's
            # verdict for the same k supersedes (a healed point clears
            # its quarantine)
            verdicts.setdefault(key, {}).update(c.get("quality", {}))
    for key, per_k in verdicts.items():
        pairs[key]["quarantined"] = sum(
            1 for v in per_k.values() if v == "quarantine")
    listed = {e["file"] for e in m["segments"]}
    folded = set(m["folded"])
    orphans = orphan_bytes = 0
    for name in os.listdir(sdir):
        if (name.endswith(".jsonl") and name not in listed
                and name[:-len(".jsonl")] not in folded):
            orphans += 1
            orphan_bytes += os.path.getsize(os.path.join(sdir, name))
    return {"segments": len(m["segments"]), "records": records,
            "bytes": nbytes, "orphans": orphans,
            "orphan_bytes": orphan_bytes, "pairs": pairs}

"""Static payload/overhead verification (paper §2.3).

The paper splits injected instructions into *payload* (the useful noise) and
*overhead* (spills / setup), computed by statically analyzing the compiler's
output, "ensuring that noise did not produce unexpected and significant side
effects that may bias analysis". Here the compiler is XLA: we re-parse the
*optimized* HLO and count surviving instructions whose ``op_name`` metadata
carries the ``noise_pattern`` scope tag.

Graph-level noise cannot spill registers, but XLA can fuse, dedup (CSE), or
reschedule patterns — the exact analogue of "did my noise survive -O3". A
``survival_fraction`` < 1 means patterns were merged and absorption readings
for that (code, mode, k) are biased; the controller re-emits with more chains.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.noise import NOISE_SCOPE
from repro.hlo.parse import Instr, nesting_multipliers, find_entry, parse_module

# Opcodes that are pure plumbing, never counted as payload or overhead.
_BOOKKEEPING = frozenset({
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "copy", "broadcast", "reshape", "transpose", "iota", "after-all",
    "bitcast-convert",
})

# payload opcode families per noise-mode target
PAYLOAD_OPS = {
    "compute": {"add", "multiply", "subtract", "dot", "convolution"},
    "l1": {"dynamic-slice", "gather", "slice"},
    "vmem": {"dynamic-slice", "gather", "slice", "add"},
    "memory": {"dynamic-slice", "gather", "slice"},
    "latency": {"dynamic-slice", "gather"},
    "ici": {"all-reduce", "all-gather", "all-to-all", "reduce-scatter",
            "collective-permute"},
}


@dataclasses.dataclass
class InjectionReport:
    mode: str
    target: str
    expected: int              # k patterns requested (static count)
    payload: int               # surviving payload ops (static)
    overhead: int              # surviving non-payload noise ops
    payload_dynamic: int       # payload weighted by loop trip counts
    body_ops: int              # non-noise ops in the injected loop body |l1.l2|

    @property
    def survival_fraction(self) -> float:
        return self.payload / self.expected if self.expected else 1.0

    @property
    def overhead_fraction(self) -> float:
        tot = self.payload + self.overhead
        return self.overhead / tot if tot else 0.0

    def ok(self, min_survival: float = 0.9, max_overhead: float = 0.5) -> bool:
        return (self.survival_fraction >= min_survival
                and self.overhead_fraction <= max_overhead)


def _is_noise(ins: Instr) -> bool:
    return NOISE_SCOPE in ins.op_name


def analyze_injection(compiled_text: str, *, mode: str, target: str,
                      expected: int,
                      fused_inner: bool = True) -> InjectionReport:
    """Count surviving noise ops in optimized HLO.

    ``fused_inner``: on CPU, noise ends up inside fusion computations whose
    instructions are printed as separate computations — count those (the real
    machine ops), not the fusion wrappers.
    """
    comps = parse_module(compiled_text)
    entry = find_entry(comps, compiled_text)
    mult = nesting_multipliers(comps, entry)
    pay_ops = PAYLOAD_OPS.get(target, PAYLOAD_OPS["compute"])

    payload = overhead = 0
    payload_dyn = 0
    noisy_comps: set[str] = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if not _is_noise(ins):
                continue
            if ins.opcode in _BOOKKEEPING or ins.opcode == "fusion":
                continue
            noisy_comps.add(cname)
            if ins.opcode in pay_ops:
                payload += 1
                payload_dyn += mult.get(cname, 1)
            else:
                overhead += 1

    # |l1.l2|: non-noise, non-bookkeeping ops in computations where noise
    # landed (= the target loop body after optimization).
    body_ops = 0
    for cname in noisy_comps:
        for ins in comps[cname]:
            if _is_noise(ins) or ins.opcode in _BOOKKEEPING:
                continue
            body_ops += 1

    return InjectionReport(mode=mode, target=target, expected=expected,
                           payload=payload, overhead=overhead,
                           payload_dynamic=payload_dyn, body_ops=body_ops)


def body_size(compiled_text: str, *, computation_hint: Optional[str] = None
              ) -> int:
    """Instruction count of the hottest loop body |l1.l2| (for Abs^rel when a
    clean (k=0) compile is analyzed — no noise tags to locate the body).

    The hottest body = all computations executing at the maximum loop-nesting
    multiplier (the while body plus the fusion computations it calls — on CPU
    the real work lives inside ``fused_computation.*``)."""
    comps = parse_module(compiled_text)
    if computation_hint and computation_hint in comps:
        return sum(1 for i in comps[computation_hint]
                   if i.opcode not in _BOOKKEEPING)
    entry = find_entry(comps, compiled_text)
    mult = nesting_multipliers(comps, entry)
    inner = {c: m for c, m in mult.items() if m > 1}
    if not inner:
        return sum(1 for i in comps.get(entry, ())
                   if i.opcode not in _BOOKKEEPING)
    mmax = max(inner.values())
    total = 0
    for cname, m in inner.items():
        if m != mmax or "condition" in cname or "cond" in cname.split(".")[0]:
            continue
        total += sum(1 for i in comps[cname]
                     if i.opcode not in _BOOKKEEPING and i.opcode != "fusion")
    return max(total, 1)

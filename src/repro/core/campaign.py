"""Campaign engine — persistent, resumable, fan-out noise-injection sweeps.

The paper's methodology is a grid of measurements: for every (region, mode)
pair, a k-sweep of wall-times. A campaign makes that grid a durable artifact
instead of a transient loop:

  * every measured point (region, mode, k, t) is appended to a JSONL store the
    moment it exists — a killed campaign loses at most one point;
  * re-running a campaign first replays the store: completed (region, mode)
    sweeps are rebuilt from disk with ZERO new measurements, partially
    measured sweeps resume at the first missing k;
  * independent (region, mode) sweeps fan out through a worker pool. Builds,
    compiles and payload verification parallelize; the actual timed
    measurements serialize through a lock so concurrent workers never corrupt
    each other's wall-clock readings.

Combined with the controller's compile-once path (one runtime-k executable
per sweep) this turns the slowest loop in the repo — recompile-per-(mode, k)
— into a cached, restartable pipeline.

Store schema (one JSON object per line; later records supersede earlier ones
for the same key, so a settings change appends fresh data without rewriting):
  {"kind": "meta",   "region": r, "mode": m, "reps": n, "compile_once": b}
  {"kind": "sens",   "region": r, "mode": m, "value": s}
  {"kind": "point",  "region": r, "mode": m, "k": k, "t": seconds}  # raw t
  {"kind": "done",   "region": r, "mode": m, "ks": [...], "drift": f|null,
   "stopped_early": b, "payload": {...}|null}
  {"kind": "region", "region": r, "body_size": n}

Points persist RAW; the two-point drift correction (absorption.sweep's
behaviour) is applied at curve-assembly time using the drift factor recorded
in the "done" marker, so replayed curves reproduce the original run exactly.
Timings are only comparable under identical measurement settings, so each
(region, mode) carries a "meta" record: resuming with different reps or a
different sweep path (compile-once vs trace-per-k) discards the stored pair
with a warning instead of splicing incompatible executables into one curve.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

from repro.core.absorption import (STOP_CONSECUTIVE, AbsorptionCurve,
                                   absorption, drift_corrected, floor_time,
                                   measure)
from repro.core.classifier import classify
from repro.core.controller import (Controller, ModeResult, RegionReport,
                                   RegionTarget, derive_body_size)
from repro.core.payload import InjectionReport

log = logging.getLogger("repro.campaign")


class CampaignStore:
    """Append-only JSONL measurement store, loaded eagerly on open.

    Thread-safe: appends take a lock and flush immediately, so the on-disk
    store is never more than one record behind the in-memory view.
    """

    def __init__(self, path: str):
        self.path = path
        self.points: dict[tuple[str, str], dict[int, float]] = {}
        self.sens: dict[tuple[str, str], float] = {}
        self.done: dict[tuple[str, str], dict] = {}
        self.meta: dict[tuple[str, str], dict] = {}
        self.body_sizes: dict[str, int] = {}
        self._lock = threading.Lock()
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        self._ingest(json.loads(line))
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")

    def _ingest(self, rec: dict) -> None:
        kind = rec.get("kind")
        key = (rec.get("region"), rec.get("mode"))
        if kind == "point":
            self.points.setdefault(key, {})[int(rec["k"])] = float(rec["t"])
        elif kind == "sens":
            self.sens[key] = float(rec["value"])
        elif kind == "done":
            self.done[key] = rec
        elif kind == "meta":
            self.meta[key] = rec
        elif kind == "region":
            self.body_sizes[rec["region"]] = int(rec["body_size"])

    def append(self, rec: dict) -> None:
        with self._lock:
            self._ingest(rec)
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()

    def close(self) -> None:
        self._f.close()

    # convenience views ----------------------------------------------------
    def stored_ts(self, region: str, mode: str) -> dict[int, float]:
        return self.points.get((region, mode), {})

    def is_done(self, region: str, mode: str) -> bool:
        return (region, mode) in self.done

    def discard(self, region: str, mode: str) -> None:
        """Drop a pair's in-memory data; the file keeps the old lines (this
        run's fresh appends supersede them on the next load)."""
        for d in (self.points, self.sens, self.done, self.meta):
            d.pop((region, mode), None)


@dataclasses.dataclass
class CampaignStats:
    measured: int = 0      # freshly timed points (incl. sensitivity probes)
    cached: int = 0        # points replayed from the store


class Campaign:
    """Resumable measurement campaign over RegionTargets × noise modes.

    ``workers`` > 1 fans independent (region, mode) sweeps across a thread
    pool; every timed section still serializes through one lock (wall-clock
    measurements on a shared machine must not overlap), so extra workers buy
    back the compile/verify time, which dominates on the trace-per-k fallback
    path and still bounds campaign latency on the compile-once path.
    """

    def __init__(self, store: CampaignStore | str,
                 controller: Optional[Controller] = None, *,
                 workers: int = 1):
        self.store = store if isinstance(store, CampaignStore) \
            else CampaignStore(store)
        self.ctl = controller if controller is not None else Controller()
        self.workers = max(1, int(workers))
        self.stats = CampaignStats()
        self._measure_lock = threading.Lock()
        self._stats_lock = threading.Lock()

    def _note(self, *, measured: int = 0, cached: int = 0) -> None:
        with self._stats_lock:
            self.stats.measured += measured
            self.stats.cached += cached

    # -- one (region, mode) sweep, store-backed -----------------------------
    def _check_meta(self, target: RegionTarget, mode: str) -> None:
        """Stored timings are only reusable under the same measurement
        settings; on mismatch, discard the pair and remeasure."""
        key = (target.name, mode)
        cur = {"reps": self.ctl.reps,
               "compile_once": self.ctl._rt_fn(target, mode) is not None}
        old = self.store.meta.get(key)
        if old is not None and any(old.get(f) != cur[f] for f in cur):
            log.warning(
                "campaign store for %s/%s was measured with %s, current "
                "settings are %s; discarding stored sweep and remeasuring",
                target.name, mode,
                {f: old.get(f) for f in cur}, cur)
            self.store.discard(*key)
        if self.store.meta.get(key) is None:
            self.store.append({"kind": "meta", "region": target.name,
                               "mode": mode, **cur})

    def _sensitivity(self, target: RegionTarget, mode: str) -> float:
        key = (target.name, mode)
        if key in self.store.sens:
            return self.store.sens[key]
        with self._measure_lock:
            s = self.ctl.probe_sensitivity(target, mode)
        self._note(measured=2)   # t0 + t(probe_k)
        self.store.append({"kind": "sens", "region": target.name,
                           "mode": mode, "value": s})
        return s

    def _point_fn(self, target: RegionTarget, mode: str, fn_rt, k: int):
        if fn_rt is not None:
            import jax.numpy as jnp
            return fn_rt, (jnp.int32(k), *target.args_for_rt(mode))
        return target.build(mode, k), target.args_for(mode, k)

    def sweep_mode(self, target: RegionTarget, mode: str) -> ModeResult:
        """Measure (or replay) the k-sweep for one (region, mode) pair."""
        key = (target.name, mode)
        self._check_meta(target, mode)
        if self.store.is_done(*key):
            return self._replay(target, mode)

        ks = self.ctl._ks_for(self._sensitivity(target, mode))
        stored = self.store.stored_ts(*key)
        fn_rt = self.ctl._rt_fn(target, mode)

        out_ks: list[int] = []
        out_ts: list[float] = []
        n_over = 0
        n_fresh = 0
        stopped = False
        for k in ks:
            if k in stored:
                t = stored[k]
                self._note(cached=1)
            else:
                fn, a = self._point_fn(target, mode, fn_rt, k)
                with self._measure_lock:
                    t = measure(fn, a, reps=self.ctl.reps)
                self._note(measured=1)
                n_fresh += 1
                self.store.append({"kind": "point", "region": target.name,
                                   "mode": mode, "k": k, "t": t})
            out_ks.append(k)
            out_ts.append(t)
            # same online saturation rule as absorption.sweep
            if t / floor_time(out_ts[0], f"campaign({target.name}/{mode}) "
                              "t(k=0)") > self.ctl.stop_ratio:
                n_over += 1
                if n_over >= STOP_CONSECUTIVE:
                    stopped = True
                    break
            else:
                n_over = 0

        # two-point drift correction (absorption.sweep's behaviour), only
        # when the whole series was measured in THIS run — a drift factor is
        # meaningless across sessions. Raw points stay raw in the store; the
        # factor is recorded so replays reproduce this exact curve.
        drift = None
        if n_fresh == len(out_ks) and len(out_ts) > 2:
            fn, a = self._point_fn(target, mode, fn_rt, out_ks[0])
            with self._measure_lock:
                t0_end = measure(fn, a, reps=max(self.ctl.reps - 2, 2))
            self._note(measured=1)
            drift = t0_end / floor_time(
                out_ts[0], f"campaign({target.name}/{mode}) t(k=0)")

        inj = self.ctl.verify_mode_payload(target, mode, out_ks) \
            if self.ctl.verify_payload else None
        self.store.append({
            "kind": "done", "region": target.name, "mode": mode,
            "ks": out_ks, "stopped_early": stopped, "drift": drift,
            "payload": dataclasses.asdict(inj) if inj is not None else None})
        return self._assemble_mode(mode, out_ks, out_ts, drift, stopped, inj)

    def _assemble_mode(self, mode, ks, ts, drift, stopped, inj) -> ModeResult:
        if drift is not None:
            ts = drift_corrected(ts, drift)
        curve = AbsorptionCurve(mode=mode, ks=list(ks), ts=list(ts),
                                stopped_early=stopped)
        return ModeResult(mode=mode, curve=curve,
                          fit=absorption(curve, tol=self.ctl.tol),
                          injection=inj)

    def _replay(self, target: RegionTarget, mode: str) -> ModeResult:
        rec = self.store.done[(target.name, mode)]
        ts = self.store.stored_ts(target.name, mode)
        ks = [int(k) for k in rec["ks"]]
        missing = [k for k in ks if k not in ts]
        if missing:   # truncated store: re-enter the measuring path
            log.warning("campaign store for %s/%s lost points %s; remeasuring",
                        target.name, mode, missing)
            del self.store.done[(target.name, mode)]
            return self.sweep_mode(target, mode)
        self._note(cached=len(ks))
        inj = InjectionReport(**rec["payload"]) if rec.get("payload") else None
        return self._assemble_mode(mode, ks, [ts[k] for k in ks],
                                   rec.get("drift"),
                                   bool(rec.get("stopped_early")), inj)

    # -- region / campaign level --------------------------------------------
    def _body_size(self, target: RegionTarget) -> int:
        if target.body_size:
            return target.body_size
        if target.name in self.store.body_sizes:
            return self.store.body_sizes[target.name]
        body = derive_body_size(target)
        self.store.append({"kind": "region", "region": target.name,
                           "body_size": body})
        return body

    def _assemble_region(self, target: RegionTarget,
                         results: dict[str, ModeResult]) -> RegionReport:
        report = classify({m: r.fit.k1 for m, r in results.items()})
        return RegionReport(region=target.name, results=results,
                            bottleneck=report,
                            body_size=self._body_size(target))

    def _pooled_sweeps(self, pairs):
        """Run (target, mode) sweeps, fanned over the pool when enabled."""
        if self.workers > 1 and len(pairs) > 1:
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                futs = [pool.submit(self.sweep_mode, t, m) for t, m in pairs]
                return {(t.name, m): f.result()
                        for (t, m), f in zip(pairs, futs)}
        return {(t.name, m): self.sweep_mode(t, m) for t, m in pairs}

    def characterize(self, target: RegionTarget,
                     modes: Sequence[str]) -> RegionReport:
        """Store-backed equivalent of ``Controller.characterize``: mode sweeps
        fan out over the worker pool, completed sweeps replay from disk."""
        res = self._pooled_sweeps([(target, m) for m in modes])
        return self._assemble_region(
            target, {m: res[(target.name, m)] for m in modes})

    def run(self, targets: Sequence[RegionTarget],
            modes: Sequence[str]) -> dict[str, RegionReport]:
        """Characterize every region; (region, mode) pairs share one pool."""
        res = self._pooled_sweeps([(t, m) for t in targets for m in modes])
        return {t.name: self._assemble_region(
                    t, {m: res[(t.name, m)] for m in modes})
                for t in targets}

"""Campaign engine — persistent, resumable, fan-out noise-injection sweeps.

The paper's methodology is a grid of measurements: for every (region, mode)
pair, a k-sweep of wall-times. A campaign makes that grid a durable artifact
instead of a transient loop:

  * every measured point (region, mode, k, t) is appended to a JSONL store the
    moment it exists — a killed campaign loses at most one point;
  * re-running a campaign first replays the store: completed (region, mode)
    sweeps are rebuilt from disk with ZERO new measurements, partially
    measured sweeps resume at the first missing k;
  * independent (region, mode) sweeps fan out through a worker pool. Builds,
    compiles and payload verification parallelize; the actual timed
    measurements serialize through a lock so concurrent workers never corrupt
    each other's wall-clock readings;
  * independent HOSTS (or processes) fan out through per-worker stores:
    ``measure_shard`` measures a deterministic subset of the (region, mode)
    grid into its own store (``worker_store`` names it), and ``merge_stores``
    folds the worker stores into one canonical store whose replay performs
    zero new measurements;
  * the ANALYTIC path (``AnalyticCampaign``) runs ``core.analytic``
    predictions through the same store machinery, so measured and predicted
    curves live in one artifact, and ``core.decan`` variant timings persist
    as ``decan`` records — one file holds a region's full dossier.

Combined with the controller's compile-once path (one runtime-k executable
per sweep) this turns the slowest loop in the repo — recompile-per-(mode, k)
— into a cached, restartable pipeline.

Store schema (one JSON object per line):
  {"kind": "meta",   "region": r, "mode": m, "reps": n, "compile_once": b}
  {"kind": "sens",   "region": r, "mode": m, "value": s}
  {"kind": "point",  "region": r, "mode": m, "k": k, "t": seconds}  # raw t
  {"kind": "done",   "region": r, "mode": m, "ks": [...], "drift": f|null,
   "stopped_early": b, "payload": {...}|null}
  {"kind": "region", "region": r, "body_size": n}
  {"kind": "pred",   "region": r, "mode": m, "ks": [...], "ts": [...],
   "fit": {...}, "hw": {HardwareConfig fields}, "terms": {resource: s},
   "alpha": a, "tol": t, "k_max": n}            # analytic prediction
  {"kind": "decan",  "region": r, "variant": "ref"|"fp"|"ls", "t": seconds,
   "reps": n, "inner": n}                       # decremental baseline
  {"kind": "audit",  "region": r, "mode": m, "verdict": "intact"|"degraded"
   |"dead", "survival": f, "corruption": c|null, "predicted": d, "target":
   t, "agrees": b|null, "resources": {...}, "k_lo": n, "k_hi": n,
   "detail": s}                                 # static noise audit
  {"kind": "quality", "region": r, "mode": m, "k": k, "verdict": "valid"
   |"quarantine", "reason": null|"timer_floor"|"spread"|"drift_span"
   |"timeout", "spread": f|null, "reps": n, "detail": s|null}
                                                # runtime measurement quality
  {"kind": "calib",  "hw": backend, "low": f, "high": f, "fitted": b,
   "reps": n, "samples": [{"region": r, "mode": m, "role": s, "k1": f}, ...]}
                                                # fitted classifier thresholds

Points measured under a quality policy also carry their sample's relative
"spread", and their "done" marker an optional "sentinels" list (the
interleaved k=0 re-timings); both keys are absent when no policy ran, so
pre-guard stores stay byte-identical.

Supersede rules (they define both in-file appends and ``merge_stores``):
  * later records supersede earlier ones for the same key — (region, mode)
    for meta/sens/done/pred/audit, (region, mode, k) for points and quality
    records, (region,) for region records, (region, variant) for decan
    records, (hw,) for calib records — so a settings change appends fresh
    data without rewriting the
    file (and a re-measured point's fresh "valid" quality record clears its
    old quarantine);
  * a "meta" record whose measurement settings differ from the pair's
    current meta DISCARDS the pair's accumulated sens/point/done/audit/
    quality records: timings from different settings (reps, sweep path)
    must never be spliced into one curve, and stale static-audit or
    measurement-quality evidence must never annotate a re-measured pair.
    "pred" and "decan" records carry their own settings inline and
    supersede independently of measured meta;
  * ``merge_stores`` streams source stores in argument order (so a later
    source's records supersede an earlier source's, and a meta CONFLICT
    between stores resolves to the later source, dropping the earlier
    pair), then writes records in a canonical sorted order with sorted
    keys — merging is idempotent, and order-independent for stores whose
    keys are disjoint.

Points persist RAW; the two-point drift correction (absorption.sweep's
behaviour) is applied at curve-assembly time using the drift factor recorded
in the "done" marker, so replayed curves reproduce the original run exactly.

Durability: a process killed mid-append leaves a truncated final line; the
loader tolerates (and removes) it — "loses at most one point". A torn append
that flushed the whole record but not its newline is healed in place (the
record parses, so nothing is lost). Any corruption BEFORE the final record
means the file was edited or the disk lies, and the loader hard-fails
rather than silently dropping data. ``CampaignStore(path, readonly=True)``
loads without creating, healing, or truncating anything.

Layouts: a store is either ONE legacy JSONL file at ``path`` or a SEGMENTED
store (append-only segment files plus a checksummed manifest in
``path``'s ``.segments`` directory — see ``repro.core.segments``). Both
share the record schema, supersede rules, and this module's whole API;
``CampaignStore(path, segmented=True)`` opts a new store in, existing
stores auto-detect. Segmented stores make ``merge_stores`` INCREMENTAL
(O(new segments), not O(store)) and gain ``compact_store`` /
``python -m repro.core.campaign compact`` to reclaim superseded records.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import logging
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Mapping, Optional, Sequence

from repro.core.absorption import (DEFAULT_KS, STOP_CONSECUTIVE,
                                   AbsorptionFit, MeasureTimeout, absorption,
                                   assemble_curve, floor_time, measure,
                                   measure_sample)
from repro.core.analytic import StepTerms, predict_absorption, predict_curve
from repro.core.classifier import HIGH, LOW, BottleneckReport, classify
from repro.core.controller import (Controller, ModeResult, RegionReport,
                                   RegionTarget, derive_body_size)
from repro.core import decan as decan_mod
from repro.core import segments as seg_mod
from repro.core.quality import (REASON_DRIFT_SPAN, REASON_TIMEOUT,
                                QualityPolicy, RemeasureBudget,
                                VERDICT_QUARANTINE, measure_quality)
from repro.core.payload import InjectionReport
# the tolerant line-streaming reader and the corrupt-store error live in
# repro.core.segments (shared with the segmented layout); re-exported here
# because this module is their historical public home
from repro.core.segments import (CampaignStoreError, io_tally,  # noqa: F401
                                 read_store_records, store_exists)

log = logging.getLogger("repro.campaign")


def _meta_settings(rec: dict) -> dict:
    """The measurement-settings payload of a meta record (key fields off)."""
    return {f: v for f, v in rec.items()
            if f not in ("kind", "region", "mode")}


def worker_store(path: str, index: int, count: int) -> str:
    """Per-worker store naming for fan-out: ``base.jsonl`` -> ``base.w0of2.jsonl``."""
    base, ext = os.path.splitext(path)
    return f"{base}.w{index}of{count}{ext or '.jsonl'}"


def host_store(path: str, host: str) -> str:
    """Per-HOST namespacing of a store path: ``base.jsonl`` ->
    ``base.h<host>-<hash6>.jsonl`` (host sanitized to filename-safe chars,
    plus a short hash of the RAW host name — sanitization alone maps
    distinct hosts like ``node:1`` and ``node-1`` to the same tag, and two
    hosts sharing a staging file could clobber each other's pulls).

    Multi-host launchers stage files they fetch from a remote host under
    this name before atomically renaming them into place, so a torn
    transfer can never corrupt the local worker store — and two hosts that
    both touched the same shard (a retry that moved hosts) can never
    clobber each other mid-copy."""
    base, ext = os.path.splitext(path)
    tag = "".join(c if c.isalnum() or c in "._-" else "-" for c in host)
    h = hashlib.sha256(host.encode("utf-8")).hexdigest()[:6]
    return f"{base}.h{tag}-{h}{ext or '.jsonl'}"


@dataclasses.dataclass(frozen=True)
class PairStatus:
    """Grid completeness of one (region, mode) pair — what a fleet executor
    (or a human at the ``inspect`` CLI) needs to decide whether the pair must
    be (re)measured: the points present, the points the sweep's ``done``
    marker promised, and which of those are missing (a truncated store)."""
    points: int                       # point records present
    expected: Optional[int]           # len(done ks); None until done-marked
    done: bool                        # a "done" marker exists
    missing: tuple[int, ...] = ()     # done-promised ks with no point record
    quarantined: tuple[int, ...] = ()  # ks whose quality record condemns them

    @property
    def complete(self) -> bool:
        """Replayable with zero new measurements."""
        return self.done and not self.missing


class CampaignStore:
    """Append-only measurement store, loaded eagerly on open.

    Thread-safe: appends take a lock and flush immediately, so the on-disk
    store is never more than one record behind the in-memory view.

    ``segmented=None`` (the default) auto-detects the on-disk layout:
    a ``path.segments`` directory opens the segmented backend
    (``repro.core.segments.SegmentStore``), otherwise the legacy single
    JSONL file at ``path``. ``segmented=True`` opts a NEW store into the
    segmented layout; both layouts present this exact class API.
    """

    def __init__(self, path: str, *, readonly: bool = False,
                 segmented: Optional[bool] = None):
        self.path = path
        self.points: dict[tuple[str, str], dict[int, float]] = {}
        self.sens: dict[tuple[str, str], float] = {}
        self.done: dict[tuple[str, str], dict] = {}
        self.meta: dict[tuple[str, str], dict] = {}
        self.preds: dict[tuple[str, str], dict] = {}
        self.decan: dict[tuple[str, str], dict] = {}
        self.audits: dict[tuple[str, str], dict] = {}
        self.quality: dict[tuple[str, str], dict[int, dict]] = {}
        # fitted classifier thresholds, keyed by hardware config (like
        # preds, calib records carry their own settings and survive
        # per-pair meta conflicts)
        self.calib: dict[str, dict] = {}
        self.body_sizes: dict[str, int] = {}
        self._lock = threading.Lock()
        self._f = None
        self._seg: Optional[seg_mod.SegmentStore] = None
        has_dir = seg_mod.is_segmented(path)
        has_file = os.path.exists(path)
        if has_dir and has_file:
            raise CampaignStoreError(
                f"{path}: both a legacy store file and a segment dir "
                f"({seg_mod.segments_dir(path)}) exist; merge or remove one")
        if segmented is None:
            segmented = has_dir
        elif segmented and has_file:
            raise CampaignStoreError(
                f"{path}: cannot open as a segmented store — a legacy "
                "single-file store already exists (merge or compact it into "
                "a segmented path first)")
        elif not segmented and has_dir:
            raise CampaignStoreError(
                f"{path}: cannot open as a legacy store — a segment dir "
                f"exists at {seg_mod.segments_dir(path)}")
        self.segmented = bool(segmented)
        if segmented:
            if readonly and not has_dir:
                raise FileNotFoundError(
                    f"campaign store {path} does not exist")
            self._seg = seg_mod.SegmentStore(path, readonly=readonly)
            for rec in self._seg.load():
                self._ingest(rec)
            return
        if readonly and not has_file:
            raise FileNotFoundError(f"campaign store {path} does not exist")
        if has_file:
            records, valid = read_store_records(path)
            for rec in records:
                self._ingest(rec)
            if not readonly:
                if valid < os.path.getsize(path):
                    with open(path, "r+b") as f:  # drop the torn tail for
                        f.truncate(valid)         # good: appends start clean
                elif valid and not self._ends_with_newline(path):
                    # torn append that DID flush the whole record but not its
                    # newline: the record is intact (JSON is self-delimiting)
                    # — heal the terminator so the next append starts a line
                    with open(path, "ab") as f:
                        f.write(b"\n")
        if readonly:
            return
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")

    @staticmethod
    def _ends_with_newline(path: str) -> bool:
        with open(path, "rb") as f:
            f.seek(-1, os.SEEK_END)
            return f.read(1) == b"\n"

    def _ingest(self, rec: dict) -> None:
        kind = rec.get("kind")
        key = (rec.get("region"), rec.get("mode"))
        if kind == "point":
            self.points.setdefault(key, {})[int(rec["k"])] = float(rec["t"])
        elif kind == "sens":
            self.sens[key] = float(rec["value"])
        elif kind == "done":
            self.done[key] = rec
        elif kind == "meta":
            old = self.meta.get(key)
            if old is not None and _meta_settings(old) != _meta_settings(rec):
                # a settings change mid-file means the old pair was discarded
                self._drop_measured(key)
            self.meta[key] = rec
        elif kind == "region":
            self.body_sizes[rec["region"]] = int(rec["body_size"])
        elif kind == "pred":
            self.preds[key] = rec
        elif kind == "decan":
            self.decan[(rec.get("region"), rec.get("variant"))] = rec
        elif kind == "audit":
            self.audits[key] = rec
        elif kind == "quality":
            self.quality.setdefault(key, {})[int(rec["k"])] = rec
        elif kind == "calib":
            self.calib[str(rec.get("hw", ""))] = rec

    def append(self, rec: dict) -> None:
        """Ingest one record and flush it to disk (locked; readonly stores
        refuse)."""
        if self._seg is not None:
            with self._lock:
                self._ingest(rec)
                self._seg.append_line(json.dumps(rec), rec)
            return
        if self._f is None:
            raise RuntimeError(f"store {self.path} was opened readonly")
        with self._lock:
            self._ingest(rec)
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()

    def close(self) -> None:
        """Close the append handle — for segmented stores this SEALS the
        session's segment into the manifest (no-op for readonly stores)."""
        if self._seg is not None:
            if not self._seg.readonly:
                self._seg.close()
            return
        if self._f is not None:
            self._f.close()

    # convenience views ----------------------------------------------------
    def stored_ts(self, region: str, mode: str) -> dict[int, float]:
        """The pair's stored {k: wall-time} points (empty when unmeasured)."""
        return self.points.get((region, mode), {})

    def is_done(self, region: str, mode: str) -> bool:
        """True when the pair's sweep wrote its ``done`` marker."""
        return (region, mode) in self.done

    def quarantined_ks(self, region: str, mode: str) -> tuple[int, ...]:
        """The pair's ks condemned by a quarantine quality record (a later
        valid record for the same k clears it — supersede last-wins)."""
        q = self.quality.get((region, mode), {})
        return tuple(sorted(k for k, rec in q.items()
                            if rec.get("verdict") == "quarantine"))

    def pair_status(self, region: str, mode: str) -> PairStatus:
        """Completeness of one (region, mode) pair (see ``PairStatus``)."""
        key = (region, mode)
        pts = self.points.get(key, {})
        quar = self.quarantined_ks(region, mode)
        rec = self.done.get(key)
        if rec is None:
            return PairStatus(points=len(pts), expected=None, done=False,
                              quarantined=quar)
        ks = [int(k) for k in rec["ks"]]
        return PairStatus(points=len(pts), expected=len(ks), done=True,
                          missing=tuple(k for k in ks if k not in pts),
                          quarantined=quar)

    def grid_status(self, pairs: Sequence[tuple[str, str]]
                    ) -> dict[tuple[str, str], PairStatus]:
        """Completeness of every (region, mode) pair in an expected grid —
        the query a fleet executor runs against worker stores to decide
        which shards still need (re)launching."""
        return {(r, m): self.pair_status(r, m) for r, m in pairs}

    def _drop_measured(self, key: tuple[str, str]) -> None:
        # audits and quality records are settings-scoped evidence measured
        # alongside the pair: stale ones must not feed apply_audit_evidence /
        # apply_quality_evidence after a re-measure. preds carry their own
        # settings inline and supersede independently.
        for d in (self.points, self.sens, self.done, self.audits,
                  self.quality):
            d.pop(key, None)

    def discard(self, region: str, mode: str) -> None:
        """Drop a pair's in-memory measured data (pred/decan records carry
        their own settings and stay); the file keeps the old lines — this
        run's fresh appends supersede them on the next load."""
        self._drop_measured((region, mode))
        self.meta.pop((region, mode), None)


# ---------------------------------------------------------------------------
# Multi-store fan-out: merge worker stores into one canonical store
# ---------------------------------------------------------------------------

_KIND_ORDER = {"meta": 0, "sens": 1, "point": 2, "done": 3, "region": 4,
               "decan": 5, "pred": 6, "audit": 7, "quality": 8, "calib": 9}


def _canon_line(rec: dict) -> str:
    return json.dumps(rec, sort_keys=True)


def _canon_sort_key(rec: dict) -> tuple:
    return (str(rec.get("region", "")),
            str(rec.get("mode", rec.get("variant", ""))),
            _KIND_ORDER.get(rec.get("kind"), 99),
            int(rec.get("k", -1)),
            _canon_line(rec))


@dataclasses.dataclass
class MergeStats:
    """What ``merge_stores`` did: sources read, records in/out, and the
    (region, mode) pairs whose meta conflicted (later source won). For an
    INCREMENTAL merge into a segmented destination, ``records_in`` counts
    only the newly adopted segments' records, ``records_out`` the
    destination's total, and ``conflicts`` stays empty — supersede (and
    meta-conflict) resolution is a read-time property of a segmented
    store, applied identically by every subsequent load."""
    sources: int = 0
    records_in: int = 0
    records_out: int = 0
    conflicts: list = dataclasses.field(default_factory=list)  # (region, mode)
    incremental: bool = False
    segments_new: int = 0
    segments_skipped: int = 0

    def __str__(self) -> str:
        if self.incremental:
            return (f"folded {self.segments_new} new segment(s) "
                    f"({self.records_in} record(s)) from {self.sources} "
                    f"store(s); {self.segments_skipped} segment(s) already "
                    f"merged; {self.records_out} record(s) total")
        s = (f"merged {self.records_in} records from {self.sources} stores "
             f"into {self.records_out}")
        if self.conflicts:
            s += (f"; {len(self.conflicts)} pair(s) re-measured under newer "
                  f"settings won: {sorted(set(self.conflicts))}")
        return s


class _MergeView:
    """Raw-record mirror of CampaignStore's supersede semantics: the same
    ingest rules, but keeping the winning record verbatim so the merged file
    reproduces byte-exact replays."""

    def __init__(self, stats: MergeStats):
        self.meta: dict[tuple, dict] = {}
        self.sens: dict[tuple, dict] = {}
        self.points: dict[tuple, dict[int, dict]] = {}
        self.done: dict[tuple, dict] = {}
        self.preds: dict[tuple, dict] = {}
        self.regions: dict[str, dict] = {}
        self.decan: dict[tuple, dict] = {}
        self.audits: dict[tuple, dict] = {}
        self.quality: dict[tuple, dict[int, dict]] = {}
        self.calib: dict[str, dict] = {}
        self.other: dict[str, dict] = {}
        self.stats = stats

    def ingest(self, rec: dict) -> None:
        self.stats.records_in += 1
        kind = rec.get("kind")
        key = (rec.get("region"), rec.get("mode"))
        if kind == "point":
            self.points.setdefault(key, {})[int(rec["k"])] = rec
        elif kind == "sens":
            self.sens[key] = rec
        elif kind == "done":
            self.done[key] = rec
        elif kind == "meta":
            old = self.meta.get(key)
            if old is not None and _meta_settings(old) != _meta_settings(rec):
                log.warning(
                    "merge: %s/%s measured under %s and %s; keeping the "
                    "later store's sweep", key[0], key[1],
                    _meta_settings(old), _meta_settings(rec))
                self.stats.conflicts.append(key)
                # mirror CampaignStore._drop_measured: stale audit/quality
                # evidence from the superseded settings must not survive
                for d in (self.points, self.sens, self.done, self.audits,
                          self.quality):
                    d.pop(key, None)
            self.meta[key] = rec
        elif kind == "region":
            self.regions[rec["region"]] = rec
        elif kind == "pred":
            self.preds[key] = rec
        elif kind == "decan":
            self.decan[(rec.get("region"), rec.get("variant"))] = rec
        elif kind == "audit":
            self.audits[key] = rec
        elif kind == "quality":
            self.quality.setdefault(key, {})[int(rec["k"])] = rec
        elif kind == "calib":
            self.calib[str(rec.get("hw", ""))] = rec
        else:
            self.other[_canon_line(rec)] = rec   # unknown: keep, dedup exact

    def records(self) -> list[dict]:
        out: list[dict] = []
        out.extend(self.meta.values())
        out.extend(self.sens.values())
        for per_k in self.points.values():
            out.extend(per_k.values())
        out.extend(self.done.values())
        out.extend(self.regions.values())
        out.extend(self.decan.values())
        out.extend(self.preds.values())
        out.extend(self.audits.values())
        for per_k in self.quality.values():
            out.extend(per_k.values())
        out.extend(self.calib.values())
        out.extend(self.other.values())
        return sorted(out, key=_canon_sort_key)


def _read_any_store(src: str) -> list[dict]:
    """Records of a source store in replay order, whichever layout it has."""
    if seg_mod.is_segmented(src):
        return seg_mod.SegmentStore(src, readonly=True).load()
    return read_store_records(src)[0]


# concurrent merges to the same dest must never share a tmp name: each call
# gets a pid+counter-unique one, so neither racer can rename or remove the
# other's half-written output (last os.replace still wins the dest)
_MERGE_TMP_COUNT = itertools.count()


def merge_stores(dest: str, sources: Sequence[str], *,
                 incremental: Optional[bool] = None) -> MergeStats:
    """Fold worker stores into one canonical store at ``dest``.

    Two strategies share this entry point:

    * **incremental** (segmented ``dest``): adopt whole source segments the
      destination manifest has never seen — O(new segments) reads, never
      O(store); supersede resolution happens at read time. Chosen
      automatically when ``dest`` is (or, with segmented sources and no
      legacy dest file, becomes) a segmented store; dest-as-source is a
      no-op.
    * **full canonical** (legacy single-file ``dest``): sources stream in
      argument order, so later sources supersede earlier ones under the
      schema's supersede/meta-conflict rules; the output is written with
      records in a canonical sort order and canonical key order, then
      atomically renamed over ``dest`` — merging is idempotent (re-merging
      the output is a byte-level no-op), order-independent when sources'
      keys are disjoint, and safe when ``dest`` is itself one of the
      sources. ``incremental=False`` forces this path (segmented sources
      are read through their deterministic replay order), which is how a
      segmented store is flattened to a canonical single file.
    """
    dest_seg = seg_mod.is_segmented(dest)
    dest_file = os.path.isfile(dest)
    if dest_seg and dest_file:
        raise CampaignStoreError(
            f"{dest}: both a legacy store file and a segment dir exist; "
            "merge or remove one before merging into it")
    if incremental is None:
        incremental = dest_seg or (not dest_file and
                                   any(seg_mod.is_segmented(s)
                                       for s in sources))
    if incremental:
        if dest_file:
            raise CampaignStoreError(
                f"{dest}: incremental merge needs a segmented destination "
                "but a legacy store file is in the way (pass "
                "incremental=False for a full canonical merge, or pick a "
                "fresh dest)")
        r = seg_mod.adopt_segments(dest, sources)
        return MergeStats(sources=len(sources), records_in=r["records_in"],
                          records_out=r["records_out"], incremental=True,
                          segments_new=r["segments_new"],
                          segments_skipped=r["segments_skipped"])
    if dest_seg:
        raise CampaignStoreError(
            f"{dest}: destination is a segmented store; a full canonical "
            "merge would leave both layouts in place — use the incremental "
            "merge, or flatten into a different dest path")
    stats = MergeStats(sources=len(sources))
    view = _MergeView(stats)
    d = os.path.dirname(dest)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{dest}.merge-tmp.{os.getpid()}.{next(_MERGE_TMP_COUNT)}"
    try:
        with open(tmp, "w") as f:
            # sources stream with the tmp already open, so a corrupt source
            # (CampaignStoreError) aborts mid-merge; the finally guarantees
            # the aborted tmp never outlives the call — ``dest`` only ever
            # sees the atomic rename of a COMPLETE merge
            for src in sources:
                for rec in _read_any_store(src):
                    view.ingest(rec)
            records = view.records()
            stats.records_out = len(records)
            for rec in records:
                f.write(_canon_line(rec) + "\n")
        os.replace(tmp, dest)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return stats


@dataclasses.dataclass
class CompactStats:
    """What ``compact_store`` reclaimed."""
    records_in: int = 0
    records_out: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    segments_in: int = 0          # 0 for a legacy single-file store

    def __str__(self) -> str:
        pct = 1.0 - (self.bytes_out / self.bytes_in) if self.bytes_in else 0.0
        s = (f"compacted {self.records_in} -> {self.records_out} record(s), "
             f"{self.bytes_in} -> {self.bytes_out} bytes ({pct:.0%} "
             "reclaimed)")
        if self.segments_in:
            s += f"; {self.segments_in} segment(s) -> 1"
        return s


def compact_store(path: str) -> CompactStats:
    """Rewrite a store in place with superseded/discarded records dropped.

    A segmented store collapses to ONE canonical segment: the compaction
    commit publishes a manifest whose ``folded`` list names every prior
    segment id, so an interrupted cleanup can never resurrect superseded
    records and future incremental merges still skip already-folded source
    segments. A legacy store is rewritten through the canonical full merge
    (``merge_stores(path, [path])``). Do not compact a store a live writer
    is appending to.
    """
    if not seg_mod.store_exists(path):
        raise FileNotFoundError(f"campaign store {path} does not exist")
    if seg_mod.is_segmented(path):
        backend = seg_mod.SegmentStore(path)   # writable: heals orphans in
        raw = backend.load()
        view = _MergeView(MergeStats())
        for rec in raw:
            view.ingest(rec)
        records = view.records()
        r = seg_mod.replace_all_segments(
            path, [_canon_line(rec) for rec in records], records)
        return CompactStats(records_in=len(raw), records_out=len(records),
                            bytes_in=r["bytes_in"], bytes_out=r["bytes_out"],
                            segments_in=r["segments_in"])
    bytes_in = os.path.getsize(path)
    ms = merge_stores(path, [path], incremental=False)
    return CompactStats(records_in=ms.records_in, records_out=ms.records_out,
                        bytes_in=bytes_in, bytes_out=os.path.getsize(path))


@dataclasses.dataclass
class CampaignStats:
    """A campaign run's measure-vs-replay tally (the ``--expect-no-measure``
    contract checks ``measured == 0``)."""
    measured: int = 0      # freshly timed points (incl. sensitivity probes)
    cached: int = 0        # points replayed from the store


class Campaign:
    """Resumable measurement campaign over RegionTargets × noise modes.

    ``workers`` > 1 fans independent (region, mode) sweeps across a thread
    pool; every timed section still serializes through one lock (wall-clock
    measurements on a shared machine must not overlap), so extra workers buy
    back the compile/verify time, which dominates on the trace-per-k fallback
    path and still bounds campaign latency on the compile-once path.

    Multi-host fan-out: give each host its own store (``worker_store``) and a
    disjoint slice of the grid via ``measure_shard``; ``merge_stores`` then
    builds the canonical store any host can replay without measuring.
    """

    def __init__(self, store: CampaignStore | str,
                 controller: Optional[Controller] = None, *,
                 workers: int = 1,
                 quality: Optional[QualityPolicy] = None,
                 remeasure: Optional[RemeasureBudget] = None,
                 heal_quarantined: bool = True,
                 thresholds: Optional[tuple[float, float]] = None):
        self.store = store if isinstance(store, CampaignStore) \
            else CampaignStore(store)
        self.ctl = controller if controller is not None else Controller()
        self.workers = max(1, int(workers))
        # the runtime measurement-integrity guard: with a QualityPolicy,
        # every fresh point is dispersion-gated (re-measured under the
        # RemeasureBudget, quarantined when it won't settle), baseline
        # sentinels interleave when the policy asks, and the watchdog
        # deadline turns a hung kernel into a recorded timeout quarantine.
        # heal_quarantined makes resume re-measure previously-quarantined
        # points (pass False for a replay that must not measure).
        self.quality = quality
        self.remeasure = remeasure if remeasure is not None \
            else (RemeasureBudget() if quality is not None else None)
        self.heal_quarantined = bool(heal_quarantined)
        # the effective (low, high) classification thresholds — a fleet
        # executor resolves a store's calib record into this (see
        # repro.core.calibration.resolve_thresholds); None keeps the
        # paper defaults, byte-identical to pre-calibration reports
        self.thresholds = thresholds
        self.stats = CampaignStats()
        self._measure_lock = threading.Lock()
        self._stats_lock = threading.Lock()

    def _note(self, *, measured: int = 0, cached: int = 0) -> None:
        with self._stats_lock:
            self.stats.measured += measured
            self.stats.cached += cached

    # -- one (region, mode) sweep, store-backed -----------------------------
    def _check_meta(self, target: RegionTarget, mode: str) -> None:
        """Stored timings are only reusable under the same measurement
        settings; on mismatch, discard the pair and remeasure."""
        key = (target.name, mode)
        cur = {"reps": self.ctl.reps,
               "compile_once": self.ctl._rt_fn(target, mode) is not None}
        old = self.store.meta.get(key)
        if old is not None and any(old.get(f) != cur[f] for f in cur):
            log.warning(
                "campaign store for %s/%s was measured with %s, current "
                "settings are %s; discarding stored sweep and remeasuring",
                target.name, mode,
                {f: old.get(f) for f in cur}, cur)
            self.store.discard(*key)
        if self.store.meta.get(key) is None:
            self.store.append({"kind": "meta", "region": target.name,
                               "mode": mode, **cur})

    def _sensitivity(self, target: RegionTarget, mode: str) -> float:
        key = (target.name, mode)
        if key in self.store.sens:
            return self.store.sens[key]
        # before t(0) is known only the watchdog floor applies — enough to
        # keep a kernel that hangs on its very first call from parking the
        # shard forever (the timeout is recorded by sweep_mode's caller)
        dl = self._deadline(None)
        with self._measure_lock:
            s = self.ctl.probe_sensitivity(target, mode, deadline=dl)
        self._note(measured=2)   # t0 + t(probe_k)
        self.store.append({"kind": "sens", "region": target.name,
                           "mode": mode, "value": s})
        return s

    def _deadline(self, t0: Optional[float]) -> Optional[float]:
        """The quality policy's per-point watchdog deadline (None when no
        policy is set or its watchdog is off)."""
        if self.quality is None:
            return None
        return self.quality.deadline(t0, stop_ratio=self.ctl.stop_ratio,
                                     reps=self.ctl.reps, warmup=2)

    def _point_fn(self, target: RegionTarget, mode: str, fn_rt, k: int):
        if fn_rt is not None:
            import jax.numpy as jnp
            return fn_rt, (jnp.int32(k), *target.args_for_rt(mode))
        return target.build(mode, k), target.args_for(mode, k)

    def _quality_rec(self, region: str, mode: str, k: int, verdict: str,
                     reason: Optional[str], *, spread: Optional[float] = None,
                     reps: Optional[int] = None,
                     detail: Optional[str] = None) -> None:
        self.store.append({"kind": "quality", "region": region, "mode": mode,
                           "k": int(k), "verdict": verdict, "reason": reason,
                           "spread": spread, "reps": reps, "detail": detail})

    def _sentinel(self, target: RegionTarget, mode: str, fn_rt, k0: int,
                  t0: float, span: list[int], sentinels: list[dict]) -> None:
        """Interleaved baseline sentinel: re-time k=k0 mid-sweep (the
        generalization of the end-of-sweep two-point drift check). A reading
        outside ``sentinel_tol`` means something changed under the sweep —
        quarantine ONLY the span of fresh points since the last sentinel."""
        fn, a = self._point_fn(target, mode, fn_rt, k0)
        with self._measure_lock:
            t = measure(fn, a, reps=max(self.ctl.reps - 2, 2),
                        deadline=self._deadline(t0))
        self._note(measured=1)
        ratio = t / floor_time(t0, f"campaign({target.name}/{mode}) t(k=0)")
        ok = abs(ratio - 1.0) <= self.quality.sentinel_tol
        sentinels.append({"after_k": int(span[-1]) if span else int(k0),
                          "ratio": ratio, "ok": ok})
        if not ok and span:
            log.warning(
                "campaign %s/%s: baseline sentinel read %.3gx t(0) "
                "mid-sweep; quarantining the affected span ks=%s",
                target.name, mode, ratio, span)
            for qk in span:
                self._quality_rec(target.name, mode, qk, VERDICT_QUARANTINE,
                                  REASON_DRIFT_SPAN,
                                  detail=f"sentinel ratio {ratio:.4g}")
        span.clear()

    def sweep_mode(self, target: RegionTarget, mode: str) -> ModeResult:
        """Measure (or replay) the k-sweep for one (region, mode) pair."""
        key = (target.name, mode)
        self._check_meta(target, mode)
        if self.store.is_done(*key):
            return self._replay(target, mode)

        try:
            ks = self.ctl._ks_for(self._sensitivity(target, mode))
        except MeasureTimeout as e:
            # the sensitivity probe (k=0 / probe_k) hung: record the timeout
            # against k=0 so doctor can explain it, then surface the error —
            # with no k grid there is nothing to sweep or mark done
            self._note(measured=1)
            self._quality_rec(target.name, mode, 0, VERDICT_QUARANTINE,
                              REASON_TIMEOUT, detail=str(e))
            raise
        stored = dict(self.store.stored_ts(*key))
        if self.quality is not None and self.heal_quarantined:
            for qk in self.store.quarantined_ks(*key):
                stored.pop(qk, None)     # quarantined points re-measure
        fn_rt = self.ctl._rt_fn(target, mode)

        out_ks: list[int] = []
        out_ts: list[float] = []
        n_over = 0
        n_fresh = 0
        stopped = False
        timed_out: list[int] = []
        sentinels: list[dict] = []
        span: list[int] = []         # fresh ks since the last sentinel
        since_sentinel = 0
        for k in ks:
            if k in stored:
                t = stored[k]
                self._note(cached=1)
            elif self.quality is None:
                fn, a = self._point_fn(target, mode, fn_rt, k)
                with self._measure_lock:
                    t = measure(fn, a, reps=self.ctl.reps)
                self._note(measured=1)
                n_fresh += 1
                self.store.append({"kind": "point", "region": target.name,
                                   "mode": mode, "k": k, "t": t})
            else:
                # quality-guarded point: dispersion-gated sample under the
                # re-measure budget, on a watchdog deadline derived from
                # the worst time the online stop rule would accept
                fn, a = self._point_fn(target, mode, fn_rt, k)
                deadline = self._deadline(out_ts[0] if out_ts else None)

                def once(n: int, _fn=fn, _a=a, _dl=deadline):
                    return measure_sample(_fn, _a, reps=n, deadline=_dl)

                try:
                    with self._measure_lock:
                        sample, verdict, reason = measure_quality(
                            once, reps=self.ctl.reps, policy=self.quality,
                            budget=self.remeasure)
                except MeasureTimeout as e:
                    self._note(measured=1)
                    log.warning("campaign %s/%s k=%d: %s — recording a "
                                "timeout quarantine and ending the sweep",
                                target.name, mode, k, e)
                    self._quality_rec(target.name, mode, k,
                                      VERDICT_QUARANTINE, REASON_TIMEOUT,
                                      reps=self.ctl.reps, detail=str(e))
                    timed_out.append(k)
                    break      # the executable hung; later ks would too
                self._note(measured=1)
                n_fresh += 1
                t = sample.t
                self.store.append({"kind": "point", "region": target.name,
                                   "mode": mode, "k": k, "t": t,
                                   "spread": sample.spread})
                self._quality_rec(target.name, mode, k, verdict, reason,
                                  spread=sample.spread,
                                  reps=len(sample.reps))
                span.append(k)
                since_sentinel += 1
                if (self.quality.sentinel_every and out_ts
                        and since_sentinel >= self.quality.sentinel_every):
                    self._sentinel(target, mode, fn_rt, out_ks[0], out_ts[0],
                                   span, sentinels)
                    since_sentinel = 0
            out_ks.append(k)
            out_ts.append(t)
            # same online saturation rule as absorption.sweep
            if t / floor_time(out_ts[0], f"campaign({target.name}/{mode}) "
                              "t(k=0)") > self.ctl.stop_ratio:
                n_over += 1
                if n_over >= STOP_CONSECUTIVE:
                    stopped = True
                    break
            else:
                n_over = 0

        # two-point drift correction (absorption.sweep's behaviour), only
        # when the whole series was measured in THIS run — a drift factor is
        # meaningless across sessions (and pointless after a timeout, whose
        # resume re-measures the pair anyway). Raw points stay raw in the
        # store; the factor is recorded so replays reproduce this curve.
        drift = None
        if n_fresh == len(out_ks) and len(out_ts) > 2 and not timed_out:
            fn, a = self._point_fn(target, mode, fn_rt, out_ks[0])
            with self._measure_lock:
                t0_end = measure(fn, a, reps=max(self.ctl.reps - 2, 2),
                                 deadline=self._deadline(out_ts[0]))
            self._note(measured=1)
            drift = t0_end / floor_time(
                out_ts[0], f"campaign({target.name}/{mode}) t(k=0)")

        inj = self.ctl.verify_mode_payload(target, mode, out_ks) \
            if self.ctl.verify_payload and out_ks else None
        rec = {
            "kind": "done", "region": target.name, "mode": mode,
            "ks": out_ks + timed_out, "stopped_early": stopped,
            "drift": drift,
            "payload": dataclasses.asdict(inj) if inj is not None else None}
        if sentinels:
            rec["sentinels"] = sentinels
        # the done marker is written even after a timeout: its ks then
        # include the hung point, so the pair reads INCOMPLETE (missing k)
        # and resume re-enters the measuring path instead of replaying
        self.store.append(rec)
        if not out_ts:
            raise MeasureTimeout(
                f"campaign {target.name}/{mode}: the first attempted point "
                f"(k={timed_out[0]}) hit its watchdog deadline; no curve")
        return self._assemble_mode(mode, out_ks, out_ts, drift, stopped, inj)

    def _assemble_mode(self, mode, ks, ts, drift, stopped, inj) -> ModeResult:
        curve = assemble_curve(mode, ks, ts, drift=drift,
                               stopped_early=stopped)
        return ModeResult(mode=mode, curve=curve,
                          fit=absorption(curve, tol=self.ctl.tol),
                          injection=inj)

    def _replay(self, target: RegionTarget, mode: str) -> ModeResult:
        rec = self.store.done[(target.name, mode)]
        ts = self.store.stored_ts(target.name, mode)
        ks = [int(k) for k in rec["ks"]]
        missing = [k for k in ks if k not in ts]
        heal: list[int] = []
        if self.quality is not None and self.heal_quarantined:
            heal = [k for k in self.store.quarantined_ks(target.name, mode)
                    if k not in missing]
        if missing or heal:   # truncated store / condemned points: re-enter
            log.warning("campaign store for %s/%s lost points %s, "
                        "quarantined %s; remeasuring",
                        target.name, mode, missing, heal)
            del self.store.done[(target.name, mode)]
            return self.sweep_mode(target, mode)
        self._note(cached=len(ks))
        inj = InjectionReport(**rec["payload"]) if rec.get("payload") else None
        return self._assemble_mode(mode, ks, [ts[k] for k in ks],
                                   rec.get("drift"),
                                   bool(rec.get("stopped_early")), inj)

    # -- DECAN variants, store-backed ---------------------------------------
    def run_decan(self, target, *, inner: int = 1):
        """Measure (or replay) DECAN variant timings through this campaign's
        store: ``decan`` records keyed (region, variant), superseded when
        reps/inner change."""
        return decan_mod.run_decan(target, reps=self.ctl.reps, inner=inner,
                                   store=self.store,
                                   lock=self._measure_lock, stats=self.stats)

    # -- region / campaign level --------------------------------------------
    def _body_size(self, target: RegionTarget) -> int:
        if target.body_size:
            return target.body_size
        if target.name in self.store.body_sizes:
            return self.store.body_sizes[target.name]
        body = derive_body_size(target)
        self.store.append({"kind": "region", "region": target.name,
                           "body_size": body})
        return body

    def _assemble_region(self, target: RegionTarget,
                         results: dict[str, ModeResult]) -> RegionReport:
        low, high = self.thresholds if self.thresholds is not None \
            else (LOW, HIGH)
        report = classify({m: r.fit.k1 for m, r in results.items()},
                          low=low, high=high)
        return RegionReport(region=target.name, results=results,
                            bottleneck=report,
                            body_size=self._body_size(target))

    def _pooled_sweeps(self, pairs):
        """Run (target, mode) sweeps, fanned over the pool when enabled."""
        if self.workers > 1 and len(pairs) > 1:
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                futs = [pool.submit(self.sweep_mode, t, m) for t, m in pairs]
                return {(t.name, m): f.result()
                        for (t, m), f in zip(pairs, futs)}
        return {(t.name, m): self.sweep_mode(t, m) for t, m in pairs}

    def characterize(self, target: RegionTarget,
                     modes: Sequence[str]) -> RegionReport:
        """Store-backed equivalent of ``Controller.characterize``: mode sweeps
        fan out over the worker pool, completed sweeps replay from disk."""
        res = self._pooled_sweeps([(target, m) for m in modes])
        return self._assemble_region(
            target, {m: res[(target.name, m)] for m in modes})

    def run(self, targets: Sequence[RegionTarget],
            modes: Sequence[str]) -> dict[str, RegionReport]:
        """Characterize every region; (region, mode) pairs share one pool."""
        res = self._pooled_sweeps([(t, m) for t in targets for m in modes])
        return {t.name: self._assemble_region(
                    t, {m: res[(t.name, m)] for m in modes})
                for t in targets}

    def measure_pairs(self, pairs: Sequence[tuple[RegionTarget, str]], *,
                      index: int = 0, count: int = 1
                      ) -> dict[tuple[str, str], ModeResult]:
        """Measure this worker's slice of an explicit (target, mode) grid.

        ``pairs`` is the FULL grid in a canonical order every worker agrees
        on (a SweepPlan's ``pairs()``, or target-major/mode-minor for
        ``measure_shard``); worker ``index`` of ``count`` takes every
        count-th pair, so every pair lands on exactly one worker given
        identical arguments. No classification happens here: a shard sees
        only its slice; ``merge_stores`` + ``characterize``/``run`` on the
        merged store produce the cross-shard reports.
        """
        if not (0 <= index < count):
            raise ValueError(f"shard index {index} not in [0, {count})")
        mine = [p for i, p in enumerate(pairs) if i % count == index]
        res = self._pooled_sweeps(mine)
        # the worker owning a region's FIRST grid pair also records its body
        # size, so the merged store replays without a single compile
        seen: set[int] = set()
        for i, (t, _) in enumerate(pairs):
            if id(t) not in seen:
                seen.add(id(t))
                if i % count == index:
                    self._body_size(t)
        return res

    def measure_shard(self, targets: Sequence[RegionTarget],
                      modes: Sequence[str], *, index: int, count: int
                      ) -> dict[tuple[str, str], ModeResult]:
        """``measure_pairs`` over the homogeneous (targets × modes) grid in
        target-major, mode-minor order."""
        return self.measure_pairs([(t, m) for t in targets for m in modes],
                                  index=index, count=count)


# ---------------------------------------------------------------------------
# Analytic campaign: predictions through the same store artifact
# ---------------------------------------------------------------------------


class AnalyticCampaign:
    """Resumable *prediction* campaign: ``core.analytic`` absorption curves
    through the same store machinery as measured sweeps.

    Each (region, mode) prediction persists as ONE self-contained ``pred``
    record (curve + fit + every setting that determined it: HardwareConfig,
    roofline terms, alpha, tol, ks, k_max). Re-running with identical
    settings replays the record byte-identically and computes nothing; any
    settings change recomputes and supersedes. Because the record kinds are
    disjoint, a pred campaign can share its store with a measured campaign —
    measured and predicted curves for a region live in one artifact.
    """

    def __init__(self, store: CampaignStore | str, *, hw, tol: float = 0.05,
                 alpha: float = 1.0, ks: Optional[Sequence[int]] = None,
                 k_max: int = 1 << 20,
                 thresholds: Optional[tuple[float, float]] = None):
        self.store = store if isinstance(store, CampaignStore) \
            else CampaignStore(store)
        self.hw = hw
        self.tol = tol
        self.alpha = alpha
        self.ks = [int(k) for k in (ks if ks is not None else DEFAULT_KS)]
        self.k_max = k_max
        # effective classification thresholds, like Campaign.thresholds
        self.thresholds = thresholds
        self.stats = CampaignStats()

    def _settings(self, terms: StepTerms) -> dict:
        return {"hw": dataclasses.asdict(self.hw), "terms": terms.as_dict(),
                "alpha": self.alpha, "tol": self.tol, "ks": self.ks,
                "k_max": self.k_max}

    def predict_mode(self, region: str, terms: StepTerms, mode) -> ModeResult:
        """Predict (or replay) the absorption curve of one noise mode."""
        cur = self._settings(terms)
        rec = self.store.preds.get((region, mode.name))
        if rec is not None and all(rec.get(f) == cur[f] for f in cur):
            self.stats.cached += len(rec["ks"])
            curve = assemble_curve(mode.name, [int(k) for k in rec["ks"]],
                                   [float(t) for t in rec["ts"]])
            return ModeResult(mode=mode.name, curve=curve,
                              fit=AbsorptionFit(**rec["fit"]))
        fit = predict_absorption(terms, mode, self.hw, tol=self.tol,
                                 alpha=self.alpha, k_max=self.k_max)
        ts = [float(t) for t in
              predict_curve(terms, mode, self.hw, self.ks, alpha=self.alpha)]
        self.store.append({"kind": "pred", "region": region,
                           "mode": mode.name, "ks": self.ks, "ts": ts,
                           "fit": dataclasses.asdict(fit), **cur})
        self.stats.measured += len(self.ks)
        curve = assemble_curve(mode.name, self.ks, ts)
        return ModeResult(mode=mode.name, curve=curve, fit=fit)

    def characterize(self, region: str, terms: StepTerms,
                     modes: Mapping[str, "object"], *,
                     classify_fn: Optional[Callable[
                         [dict[str, ModeResult]], BottleneckReport]] = None
                     ) -> RegionReport:
        """Predict every mode and classify — the analytic mirror of
        ``Campaign.characterize``. ``classify_fn`` overrides the default
        raw-absorption classification (the analytic probe classifies on
        absorbed-work fractions instead)."""
        results = {name: self.predict_mode(region, terms, mode)
                   for name, mode in modes.items()}
        if classify_fn is not None:
            report = classify_fn(results)
        else:
            low, high = self.thresholds if self.thresholds is not None \
                else (LOW, HIGH)
            report = classify({m: r.fit.k1 for m, r in results.items()},
                              low=low, high=high)
        return RegionReport(region=region, results=results, bottleneck=report,
                            body_size=0)


# ---------------------------------------------------------------------------
# CLI: merge / inspect stores (the fan-out hosts' rendezvous step)
# ---------------------------------------------------------------------------


def _cli(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.campaign",
        description="campaign store maintenance (merge worker stores, "
                    "inspect contents)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    mp = sub.add_parser("merge", help="fold worker stores into one "
                                      "canonical store (incremental when "
                                      "the destination is segmented)")
    mp.add_argument("dest")
    mp.add_argument("sources", nargs="+")
    mp.add_argument("--canonical", action="store_true",
                    help="force a full canonical single-file merge even for "
                         "segmented sources (reads every record; this is "
                         "how a segmented store flattens to one JSONL file)")
    cp = sub.add_parser("compact", help="rewrite a store in place, dropping "
                                        "superseded/discarded records (a "
                                        "segmented store collapses to one "
                                        "canonical segment)")
    cp.add_argument("path")
    ip = sub.add_parser("inspect", help="summarize one store with per-"
                                        "(region, mode) grid completeness")
    ip.add_argument("path")
    ip.add_argument("--plan", default=None, metavar="PLAN.json",
                    help="a repro.fleet SweepPlan: also check the store "
                         "against the plan's full expected grid (exit 1 "
                         "when any pair is missing or incomplete)")
    args = ap.parse_args(argv)

    if args.cmd == "merge":
        stats = merge_stores(args.dest, args.sources,
                             incremental=False if args.canonical else None)
        print(f"{args.dest}: {stats}")
        return 0
    if args.cmd == "compact":
        try:
            cstats = compact_store(args.path)
        except FileNotFoundError as e:
            print(e)
            return 2
        print(f"{args.path}: {cstats}")
        return 0
    try:   # readonly: inspecting must neither create nor heal the store
        st = CampaignStore(args.path, readonly=True)
    except FileNotFoundError as e:
        print(e)
        return 2
    print(f"{args.path}:")
    measured_keys = sorted(set(st.meta) | set(st.points) | set(st.done))
    n_complete = 0
    for key in measured_keys:
        ps = st.pair_status(*key)
        n_complete += ps.complete
        if ps.done:
            state = f"{ps.points}/{ps.expected} point(s), done"
            if ps.missing:
                state += f", MISSING ks {sorted(ps.missing)}"
        else:
            state = f"{ps.points} point(s), in progress"
        if ps.quarantined:
            reasons = sorted({(st.quality.get(key, {}).get(k) or {})
                              .get("reason") or "?"
                              for k in ps.quarantined})
            state += (f", QUARANTINED ks {sorted(ps.quarantined)} "
                      f"({', '.join(reasons)})")
        meta = _meta_settings(st.meta[key]) if key in st.meta else "?"
        print(f"  measured {key[0]}/{key[1]}: {state}  [settings {meta}]")
    for key, rec in sorted(st.preds.items()):
        terms = StepTerms.from_dict(rec.get("terms", {}))
        print(f"  pred     {key[0]}/{key[1]}: {len(rec['ks'])} point(s), "
              f"hw={rec['hw'].get('name', '?')} dominant={terms.dominant} "
              f"Abs={rec['fit']['k1']:.0f}")
    for (region, variant), rec in sorted(st.decan.items()):
        print(f"  decan    {region}/{variant}: t={rec['t']:.6f}s "
              f"(reps={rec.get('reps')}, inner={rec.get('inner')})")
    for hw, rec in sorted(st.calib.items()):
        tag = "fitted" if rec.get("fitted") else "FALLBACK (paper defaults)"
        print(f"  calib    hw={hw}: low={rec.get('low'):g} "
              f"high={rec.get('high'):g} [{tag}] from "
              f"{len(rec.get('samples', []))} sample(s)")
    for key, rec in sorted(st.audits.items()):
        surv = max(0.0, min(1.0, float(rec.get("survival", 0.0))))
        agrees = rec.get("agrees")
        extra = "" if agrees is None else f", {'' if agrees else 'DIS'}agrees"
        corr = rec.get("corruption")
        print(f"  audit    {key[0]}/{key[1]}: {rec.get('verdict')} "
              f"(survival {surv:.0%}/pattern, predicts "
              f"{rec.get('predicted')}{extra}"
              + (f", {corr}" if corr else "") + ")")
    if measured_keys:
        print(f"  grid: {n_complete}/{len(measured_keys)} measured pair(s) "
              "complete")
    if args.plan:
        from repro.fleet.plan import SweepPlan   # lazy: fleet sits above core
        plan = SweepPlan.load(args.plan)
        grid = plan.grid()
        status = st.grid_status(grid)
        missing = [key for key in grid if not status[key].complete]
        print(f"  plan {plan.name!r}: {len(grid) - len(missing)}/{len(grid)} "
              "pair(s) complete")
        for r, m in missing:
            ps = status[(r, m)]
            what = (f"{ps.points} point(s), in progress" if ps.points or ps.done
                    else "absent")
            print(f"    missing {r}/{m} ({what})")
        return 1 if missing else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(_cli())

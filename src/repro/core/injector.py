"""Graph-level noise injection — wrap a whole jitted step (train/serve) with
k patterns of a noise mode.

This is the coarse-grained injection site: noise and step co-exist in one XLA
program, competing for the same chip resources under XLA's static schedule
(the TPU's "absorber"; DESIGN.md §6.3). The noise state is threaded through
the wrapped step so buffers are allocated once and patterns chain across
calls; the scalar aux output is the ``volatile`` analogue (DCE-proof).

Semantics preservation is by construction: noise reads/writes only its own
state (R_n ∩ R_s = ∅) and the original outputs are returned untouched —
tests assert bit-identical outputs for every k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import payload as payload_mod
from repro.core.absorption import (DEFAULT_KS, AbsorptionCurve, AbsorptionFit,
                                   absorption, sweep)
from repro.core.noise import NoiseMode


def inject(step_fn: Callable, mode: NoiseMode, k: int) -> Callable:
    """Return ``noisy(noise_state, *args, **kw) -> (out, aux, new_state)``.

    ``out`` is bit-identical to ``step_fn(*args, **kw)``; ``aux`` is the
    DCE-proof noise scalar; ``new_state`` feeds the next call so noise
    chains persist across steps.
    """
    def noisy(noise_state, *args, **kw):
        out = step_fn(*args, **kw)
        aux, new_state = mode.apply(noise_state, k)
        # barrier: the noise must not be sunk after the step's outputs are
        # ready nor hoisted before its inputs — keep them in one schedule.
        out, aux = jax.lax.optimization_barrier((out, aux))
        return out, aux, new_state

    return noisy


def init_state(mode: NoiseMode, rng: Optional[jax.Array] = None):
    return mode.make_state(rng if rng is not None else jax.random.PRNGKey(0))


@dataclasses.dataclass
class StepProbe:
    """Measured + statically-verified absorption of one step × one mode."""
    mode: str
    curve: AbsorptionCurve
    fit: AbsorptionFit
    injection: payload_mod.InjectionReport


def probe_step(step_fn: Callable, args: tuple, mode: NoiseMode, *,
               ks: Sequence[int] = DEFAULT_KS, reps: int = 5,
               tol: float = 0.05, verify_payload: bool = True,
               donate_state: bool = False) -> StepProbe:
    """Sweep k for ``mode`` against ``step_fn(*args)`` (measured on the host
    backend) and statically verify the payload survived XLA optimization."""
    state0 = init_state(mode)

    def build(k: int):
        fn = inject(step_fn, mode, k)
        return jax.jit(fn, donate_argnums=(0,) if donate_state else ())

    curve = sweep(build, mode=mode.name, ks=ks,
                  args_for=lambda k: (state0, *args), reps=reps)
    fit = absorption(curve, tol=tol)

    inj = None
    if verify_payload:
        k_chk = max(8, curve.ks[-1] // 2) if len(curve.ks) > 1 else 8
        compiled = jax.jit(inject(step_fn, mode, k_chk)).lower(
            state0, *args).compile()
        inj = payload_mod.analyze_injection(
            compiled.as_text(), mode=mode.name, target=mode.target,
            expected=k_chk)
    return StepProbe(mode=mode.name, curve=curve, fit=fit, injection=inj)


def verify_semantics(step_fn: Callable, args: tuple, mode: NoiseMode,
                     k: int = 8, *, rtol: float = 0.0, atol: float = 0.0
                     ) -> bool:
    """Paper §2.3 property: injection must not change program semantics.
    Checks the wrapped output equals the clean output (bitwise by default)."""
    clean = jax.jit(step_fn)(*args)
    state0 = init_state(mode)
    noisy_out, _, _ = jax.jit(inject(step_fn, mode, k))(state0, *args)
    ok = True

    def chk(a, b):
        nonlocal ok
        import numpy as np
        a = np.asarray(a)
        b = np.asarray(b)
        if rtol == 0.0 and atol == 0.0:
            ok = ok and bool((a == b).all() or
                             (np.isnan(a) & np.isnan(b)).all())
        else:
            ok = ok and bool(np.allclose(a, b, rtol=rtol, atol=atol))

    jax.tree.map(chk, clean, noisy_out)
    return ok

"""Graph-level noise injection — wrap a whole jitted step (train/serve) with
k patterns of a noise mode.

This is the coarse-grained injection site: noise and step co-exist in one XLA
program, competing for the same chip resources under XLA's static schedule
(the TPU's "absorber"; DESIGN.md §6.3). The noise state is threaded through
the wrapped step so buffers are allocated once and patterns chain across
calls; the scalar aux output is the ``volatile`` analogue (DCE-proof).

Semantics preservation is by construction: noise reads/writes only its own
state (R_n ∩ R_s = ∅) and the original outputs are returned untouched —
tests assert bit-identical outputs for every k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import payload as payload_mod
from repro.core.absorption import (DEFAULT_KS, AbsorptionCurve, AbsorptionFit,
                                   absorption, sweep)
from repro.core.noise import NoiseMode


def inject(step_fn: Callable, mode: NoiseMode, k: int) -> Callable:
    """Return ``noisy(noise_state, *args, **kw) -> (out, aux, new_state)``.

    ``out`` is bit-identical to ``step_fn(*args, **kw)``; ``aux`` is the
    DCE-proof noise scalar; ``new_state`` feeds the next call so noise
    chains persist across steps.
    """
    def noisy(noise_state, *args, **kw):
        out = step_fn(*args, **kw)
        aux, new_state = mode.apply(noise_state, k)
        # barrier: the noise must not be sunk after the step's outputs are
        # ready nor hoisted before its inputs — keep them in one schedule.
        out, aux = jax.lax.optimization_barrier((out, aux))
        return out, aux, new_state

    return noisy


def inject_rt(step_fn: Callable, mode: NoiseMode) -> Callable:
    """Compile-once variant of ``inject``: the noise quantity is a runtime
    operand, so ONE jitted executable serves the whole k-sweep.

    Returns ``noisy(k, noise_state, *args, **kw) -> (out, aux, new_state)``
    where ``k`` is an int32 scalar (traced under jit). k leads so region
    adapters share one calling convention: ``build_rt(mode)(k, *args_rt)``.
    """
    if mode.apply_rt is None:
        raise ValueError(f"mode {mode.name!r} has no runtime-k apply")

    def noisy(k, noise_state, *args, **kw):
        out = step_fn(*args, **kw)
        aux, new_state = mode.apply_rt(noise_state, k)
        out, aux = jax.lax.optimization_barrier((out, aux))
        return out, aux, new_state

    return noisy


def init_state(mode: NoiseMode, rng: Optional[jax.Array] = None):
    return mode.make_state(rng if rng is not None else jax.random.PRNGKey(0))


def step_region(name: str, step_fn: Callable, args: tuple,
                registry: dict[str, NoiseMode], *, body_size: int = 0,
                rng: Optional[jax.Array] = None):
    """Adapt a jitted step + graph-level noise registry into a RegionTarget
    (with both the trace-per-k and the compile-once build paths)."""
    from repro.core.controller import RegionTarget   # cycle: controller->here

    rng = jax.random.PRNGKey(0) if rng is None else rng
    states = {m: registry[m].make_state(rng) for m in registry}

    def build(mode: str, k: int):
        if not mode or k == 0:
            return jax.jit(step_fn)
        return jax.jit(inject(step_fn, registry[mode], k))

    def args_for(mode: str, k: int):
        if not mode or k == 0:
            return args
        return (states[mode], *args)

    def build_rt(mode: str):
        if registry[mode].apply_rt is None:
            return None
        return jax.jit(inject_rt(step_fn, registry[mode]))

    def args_for_rt(mode: str):
        return (states[mode], *args)

    return RegionTarget(name=name, build=build, args_for=args_for,
                        body_size=body_size, build_rt=build_rt,
                        args_for_rt=args_for_rt,
                        audit_hint={"scoped": True, "in_loop": False})


@dataclasses.dataclass
class StepProbe:
    """Measured + statically-verified absorption of one step × one mode."""
    mode: str
    curve: AbsorptionCurve
    fit: AbsorptionFit
    injection: payload_mod.InjectionReport


def probe_step(step_fn: Callable, args: tuple, mode: NoiseMode, *,
               ks: Sequence[int] = DEFAULT_KS, reps: int = 5,
               tol: float = 0.05, verify_payload: bool = True,
               donate_state: bool = False,
               compile_once: bool = True) -> StepProbe:
    """Sweep k for ``mode`` against ``step_fn(*args)`` (measured on the host
    backend) and statically verify the payload survived XLA optimization.

    ``compile_once`` (default): k is a runtime operand, so the whole sweep
    traces/compiles ONE executable instead of one per k (payload verification
    still compiles one static-k executable — the count stays O(1), not
    O(len(ks))). Falls back to trace-per-k when the mode has no runtime apply.
    """
    state0 = init_state(mode)

    if compile_once and mode.apply_rt is not None:
        fn_rt = jax.jit(inject_rt(step_fn, mode))  # noise state reused: no donation
        curve = sweep(lambda k: fn_rt, mode=mode.name, ks=ks,
                      args_for=lambda k: (jnp.int32(k), state0, *args),
                      reps=reps)
    else:
        def build(k: int):
            fn = inject(step_fn, mode, k)
            return jax.jit(fn, donate_argnums=(0,) if donate_state else ())

        curve = sweep(build, mode=mode.name, ks=ks,
                      args_for=lambda k: (state0, *args), reps=reps)
    fit = absorption(curve, tol=tol)

    inj = None
    if verify_payload:
        k_chk = max(8, curve.ks[-1] // 2) if len(curve.ks) > 1 else 8
        compiled = jax.jit(inject(step_fn, mode, k_chk)).lower(
            state0, *args).compile()
        inj = payload_mod.analyze_injection(
            compiled.as_text(), mode=mode.name, target=mode.target,
            expected=k_chk)
    return StepProbe(mode=mode.name, curve=curve, fit=fit, injection=inj)


def verify_semantics(step_fn: Callable, args: tuple, mode: NoiseMode,
                     k: int = 8, *, rtol: float = 0.0, atol: float = 0.0
                     ) -> bool:
    """Paper §2.3 property: injection must not change program semantics.
    Checks the wrapped output equals the clean output (bitwise by default)."""
    clean = jax.jit(step_fn)(*args)
    state0 = init_state(mode)
    noisy_out, _, _ = jax.jit(inject(step_fn, mode, k))(state0, *args)
    ok = True

    def chk(a, b):
        nonlocal ok
        import numpy as np
        a = np.asarray(a)
        b = np.asarray(b)
        if rtol == 0.0 and atol == 0.0:
            ok = ok and bool((a == b).all() or
                             (np.isnan(a) & np.isnan(b)).all())
        else:
            ok = ok and bool(np.allclose(a, b, rtol=rtol, atol=atol))

    jax.tree.map(chk, clean, noisy_out)
    return ok

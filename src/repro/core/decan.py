"""DECAN-style decremental analysis — the paper's comparison baseline (§5.2).

DECAN *removes* instruction classes (FP variant keeps only FP, LS variant
keeps only loads/stores) and defines Sat(VAR) = T(VAR)/T(REF): a variant
running much faster than the reference means the removed class was saturated.

Here a decremental target is a kernel builder parameterized by which parts to
keep — removal happens at trace time, so the "binary patching" is free and,
unlike MADRAS, trivially portable (the paper's criticism of DECAN's
portability is structural to binary patching, not to the idea). The semantics
caveat the paper raises (removal breaks dataflow) is handled the same way
DECAN does: variants keep the control flow and write to dead buffers.

Used by benchmarks/table3 (four overlap scenarios) and fig6 (the
frontend-bottleneck case where noise injection and DECAN must be combined).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.absorption import measure


@dataclasses.dataclass(frozen=True)
class DecanTarget:
    """A kernel expressed with separable FP and LS parts.

    ``build(fp, ls)`` -> jitted callable; ``args_for()`` -> its arguments.
    build(True, True) is the reference; (True, False) the FP variant
    (memory ops removed); (False, True) the LS variant (FP ops removed).
    """
    name: str
    build: Callable[[bool, bool], Callable]
    args_for: Callable[[], tuple]


@dataclasses.dataclass
class DecanResult:
    name: str
    t_ref: float
    t_fp: float          # LS removed
    t_ls: float          # FP removed

    @property
    def sat_fp(self) -> float:
        """T(FP)/T(REF): low -> LS (the removed class) was the bottleneck...
        Note the paper's convention: Sat(VAR)=T(VAR)/T(REF) for variant VAR
        which KEEPS that class. Sat_FP ~ 1 -> FP stream alone reproduces the
        run time -> FP saturated."""
        return self.t_fp / self.t_ref

    @property
    def sat_ls(self) -> float:
        return self.t_ls / self.t_ref

    def scenario(self, *, close: float = 0.80, fast: float = 0.6) -> str:
        """Table 3 scenarios."""
        fp, ls = self.sat_fp, self.sat_ls
        if fp >= close and ls < fast:
            return "compute-bound"         # case 1: FP variant ~ ref
        if ls >= close and fp < fast:
            return "data-bound"            # case 2
        if fp >= close and ls >= close:
            return "full-overlap"          # case 3
        if fp < close and ls < close:
            return "limited-overlap"       # case 4 (ambiguous for DECAN)
        return "mixed"


def run_decan(target: DecanTarget, *, reps: int = 5, inner: int = 1
              ) -> DecanResult:
    args = target.args_for()
    t_ref = measure(target.build(True, True), args, reps=reps, inner=inner)
    t_fp = measure(target.build(True, False), args, reps=reps, inner=inner)
    t_ls = measure(target.build(False, True), args, reps=reps, inner=inner)
    return DecanResult(target.name, t_ref, t_fp, t_ls)

"""DECAN-style decremental analysis — the paper's comparison baseline (§5.2).

DECAN *removes* instruction classes (FP variant keeps only FP, LS variant
keeps only loads/stores) and defines Sat(VAR) = T(VAR)/T(REF): a variant
running much faster than the reference means the removed class was saturated.

Here a decremental target is a kernel builder parameterized by which parts to
keep — removal happens at trace time, so the "binary patching" is free and,
unlike MADRAS, trivially portable (the paper's criticism of DECAN's
portability is structural to binary patching, not to the idea). The semantics
caveat the paper raises (removal breaks dataflow) is handled the same way
DECAN does: variants keep the control flow and write to dead buffers.

Campaign integration: ``run_decan(..., store=...)`` persists the three
variant timings as ``decan`` records keyed (region, variant) — the records
carry their measurement settings (reps, inner) inline and are replayed on a
re-run with matching settings, superseded otherwise. ``Campaign.run_decan``
wires a campaign's store, measurement lock and stats in automatically, so
one store file holds a region's decremental baseline AND its incremental
noise sweeps.

Noise cross-check: a target built with ``build_noisy`` (the ``loop_region``
make_fn contract: ``build_noisy(noise_or_None, k)``) exposes ``region()``,
a RegionTarget over the reference kernel whose noise sweeps ride the
controller's compile-once runtime-k path — the whole (scenario, mode) sweep
costs O(1) executables instead of one per k.

Used by benchmarks/table3 (four overlap scenarios) and fig6 (the
frontend-bottleneck case where noise injection and DECAN must be combined).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.absorption import measure

# variant name -> (keep_fp, keep_ls); "ref" keeps both instruction classes
VARIANTS = {"ref": (True, True), "fp": (True, False), "ls": (False, True)}


@dataclasses.dataclass(frozen=True)
class DecanTarget:
    """A kernel expressed with separable FP and LS parts.

    ``build(fp, ls)`` -> jitted callable; ``args_for()`` -> its arguments.
    build(True, True) is the reference; (True, False) the FP variant
    (memory ops removed); (False, True) the LS variant (FP ops removed).

    ``build_noisy(noise_or_None, k)`` (optional) builds the REFERENCE kernel
    with a loop-level noise slot, following the ``loop_region`` make_fn
    contract (pass ``k`` straight through to ``noise.emit``); it unlocks
    ``region()`` and with it compile-once noise sweeps over this kernel.
    """
    name: str
    build: Callable[[bool, bool], Callable]
    args_for: Callable[[], tuple]
    build_noisy: Optional[Callable] = None
    body_size: int = 0

    def region(self, *, rng=None):
        """RegionTarget over the reference kernel (both parts kept), with
        ``build_rt`` — noise sweeps compile ≤2 executables per mode."""
        if self.build_noisy is None:
            raise ValueError(
                f"DecanTarget {self.name!r} has no build_noisy; pass one to "
                "run noise sweeps against this kernel")
        from repro.core.controller import loop_region
        return loop_region(self.name, self.build_noisy, self.args_for,
                           body_size=self.body_size, rng=rng)


@dataclasses.dataclass
class DecanResult:
    name: str
    t_ref: float
    t_fp: float          # LS removed
    t_ls: float          # FP removed

    @property
    def sat_fp(self) -> float:
        """T(FP)/T(REF): low -> LS (the removed class) was the bottleneck...
        Note the paper's convention: Sat(VAR)=T(VAR)/T(REF) for variant VAR
        which KEEPS that class. Sat_FP ~ 1 -> FP stream alone reproduces the
        run time -> FP saturated."""
        return self.t_fp / self.t_ref

    @property
    def sat_ls(self) -> float:
        return self.t_ls / self.t_ref

    def scenario(self, *, close: float = 0.80, fast: float = 0.6) -> str:
        """Table 3 scenarios."""
        fp, ls = self.sat_fp, self.sat_ls
        if fp >= close and ls < fast:
            return "compute-bound"         # case 1: FP variant ~ ref
        if ls >= close and fp < fast:
            return "data-bound"            # case 2
        if fp >= close and ls >= close:
            return "full-overlap"          # case 3
        if fp < close and ls < close:
            return "limited-overlap"       # case 4 (ambiguous for DECAN)
        return "mixed"


def stored_variant_t(store, name: str, variant: str, *, reps: int,
                     inner: int) -> Optional[float]:
    """The stored timing for one variant, or None when the store has no
    record measured under these settings (reps/inner mismatch = stale)."""
    if store is None:
        return None
    rec = store.decan.get((name, variant))
    if rec is None or rec.get("reps") != reps or rec.get("inner") != inner:
        return None
    return float(rec["t"])


def run_decan(target: DecanTarget, *, reps: int = 5, inner: int = 1,
              store=None, lock=None, stats=None) -> DecanResult:
    """Time the three DECAN variants, replaying from ``store`` when it has
    matching records. ``lock`` serializes the timed sections against
    concurrent campaign measurements; ``stats`` (CampaignStats-shaped)
    accumulates measured/cached counts."""
    args = target.args_for()
    ts: dict[str, float] = {}
    for vname, (fp, ls) in VARIANTS.items():
        t = stored_variant_t(store, target.name, vname, reps=reps,
                             inner=inner)
        if t is None:
            fn = target.build(fp, ls)
            if lock is not None:
                with lock:
                    t = measure(fn, args, reps=reps, inner=inner)
            else:
                t = measure(fn, args, reps=reps, inner=inner)
            if store is not None:
                store.append({"kind": "decan", "region": target.name,
                              "variant": vname, "t": t, "reps": reps,
                              "inner": inner})
            if stats is not None:
                stats.measured += 1
        elif stats is not None:
            stats.cached += 1
        ts[vname] = t
    return DecanResult(target.name, ts["ref"], ts["fp"], ts["ls"])

"""Host ports of the paper's validation kernels (§4), each written as a
``fori_loop`` with a loop-body noise slot so measured absorption reflects the
host CPU's real out-of-order overlap (core.loopnoise).

  stream_region     STREAM triad       — memory-bandwidth-bound
  lat_mem_rd_region LMBench lat_mem_rd — memory-latency-bound (pointer chase)
  haccmk_region     Coral HACCmk       — FMA-throughput-bound force kernel
  spmxv_region      EPI SPMXV (CSR->ELL) with swap probability q (§6)
  matmul_region     Fig. 4 dense matmul, naive ("-O0": gather/scalar-heavy)
                    or fused ("-O3": one jnp.dot)

Every region returns a core.controller.RegionTarget ready for
Controller.characterize().
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import RegionTarget, loop_region
from repro.kernels.spmv_ell.ref import make_band_ell

# ---------------------------------------------------------------------------
# STREAM triad: c[i] = a[i] + s*b[i] over buffers >> LLC
# ---------------------------------------------------------------------------


def stream_region(n: int = 1 << 23, chunk: int = 512) -> RegionTarget:
    def make(noise, k):
        def fn(a, b, c, *nc):
            def body(i, st):
                cb, *ncs = st
                off = i * chunk
                av = jax.lax.dynamic_slice(a, (off,), (chunk,))
                bv = jax.lax.dynamic_slice(b, (off,), (chunk,))
                cb = jax.lax.dynamic_update_slice(cb, av + 3.0 * bv, (off,))
                if noise is not None:
                    ncs = (noise.emit(ncs[0], k, i),)
                return (cb, *ncs)
            st = jax.lax.fori_loop(0, n // chunk, body, (c, *nc))
            return (st[0], noise.finalize(st[1])) if noise is not None else st[0]
        return jax.jit(fn)

    a = jnp.ones((n,), jnp.float32)
    b = jnp.full((n,), 2.0, jnp.float32)
    c = jnp.zeros((n,), jnp.float32)
    return loop_region("stream_triad", make, lambda: (a, b, c), body_size=5)


# ---------------------------------------------------------------------------
# lat_mem_rd: serially dependent pointer chase (the kernel IS a latency probe)
# ---------------------------------------------------------------------------


def lat_mem_rd_region(table_len: int = 1 << 21, hops_per_iter: int = 8,
                      n_iter: int = 4096, seed: int = 1) -> RegionTarget:
    perm = np.random.RandomState(seed).permutation(table_len).astype(np.int32)
    tbl = np.empty(table_len, np.int32)
    tbl[perm[:-1]] = perm[1:]
    tbl[perm[-1]] = perm[0]
    table = jnp.asarray(tbl)

    def make(noise, k):
        def fn(table, idx0, *nc):
            def body(i, st):
                idx, *ncs = st
                for _ in range(hops_per_iter):
                    idx = jax.lax.dynamic_slice(table, (idx,), (1,))[0]
                if noise is not None:
                    ncs = (noise.emit(ncs[0], k, i),)
                return (idx, *ncs)
            st = jax.lax.fori_loop(0, n_iter, body, (idx0, *nc))
            out = st[0].astype(jnp.float32)
            return (out, noise.finalize(st[1])) if noise is not None else out
        return jax.jit(fn)

    return loop_region("lat_mem_rd", make,
                       lambda: (table, jnp.int32(int(perm[0]))),
                       body_size=hops_per_iter)


# ---------------------------------------------------------------------------
# HACCmk: short-range force kernel — FMA-throughput bound. Four independent
# accumulator chains of 8-wide vectors saturate the FMA ports (the paper's
# compute-bound reference).
# ---------------------------------------------------------------------------


def haccmk_region(n_iter: int = 120_000, width: int = 8) -> RegionTarget:
    N_CH = 6   # 6 chains x 5 ops = 30 ops/iter: FMA-throughput bound (not
    # latency-bound), so injected fp patterns cost immediately

    def make(noise, k):
        def fn(x, *nc):
            def body(i, st):
                accs = list(st[0])
                ncs = st[1:]
                for j in range(N_CH):
                    a = accs[j]
                    # f(r) = r*(c1 + r2*(c2 + r2*c3)) — HACC poly kernel
                    r2 = a * a
                    f = a * (0.5 + r2 * (0.25 + r2 * 0.125))
                    accs[j] = a + f * 1e-6
                if noise is not None:
                    ncs = (noise.emit(ncs[0], k, i),)
                return (tuple(accs), *ncs)
            accs0 = tuple(x + j for j in range(N_CH))
            st = jax.lax.fori_loop(0, n_iter, body, (accs0, *nc))
            out = sum(jnp.sum(a) for a in st[0])
            return (out, noise.finalize(st[1])) if noise is not None else out
        return jax.jit(fn)

    x = jnp.linspace(0.1, 0.9, width, dtype=jnp.float32)
    return loop_region("haccmk", make, lambda: (x,), body_size=5 * N_CH)


# ---------------------------------------------------------------------------
# SPMXV (paper §6): ELL spmv, swap probability q controls gather locality
# ---------------------------------------------------------------------------


def spmxv_region(n: int = 1 << 20, nnz_per_row: int = 16, q: float = 0.0,
                 rows_per_iter: int = 64, seed: int = 0,
                 name: str = "") -> RegionTarget:
    vals, cols = make_band_ell(n, nnz_per_row, q, seed=seed)
    x = jnp.asarray(np.random.RandomState(seed + 1)
                    .standard_normal(n).astype(np.float32))
    L = nnz_per_row

    def make(noise, k):
        def fn(vals, cols, x, y, *nc):
            def body(i, st):
                yb, *ncs = st
                r0 = i * rows_per_iter
                vb = jax.lax.dynamic_slice(vals, (r0, 0), (rows_per_iter, L))
                cb = jax.lax.dynamic_slice(cols, (r0, 0), (rows_per_iter, L))
                g = jnp.take(x, cb, axis=0)          # the q-irregular gather
                yv = jnp.sum(vb * g, axis=1)
                yb = jax.lax.dynamic_update_slice(yb, yv, (r0,))
                if noise is not None:
                    ncs = (noise.emit(ncs[0], k, i),)
                return (yb, *ncs)
            st = jax.lax.fori_loop(0, n // rows_per_iter, body, (y, *nc))
            return (st[0], noise.finalize(st[1])) if noise is not None else st[0]
        return jax.jit(fn)

    y = jnp.zeros((n,), jnp.float32)
    return loop_region(name or f"spmxv_q{q}", make,
                       lambda: (vals, cols, x, y), body_size=6)


# ---------------------------------------------------------------------------
# Fig. 4: dense matmul, naive vs fused
# ---------------------------------------------------------------------------


def matmul_region(n: int = 192, optimized: bool = False) -> RegionTarget:
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)

    # Both variants run k-step rank-1 updates. "-O0" (no mem2reg): ONE
    # output row round-trips through memory every k-step — loads/stores
    # dominate. "-O3" (register blocking): each loaded b-row feeds EIGHT
    # independent register-resident accumulator rows — FMA-port bound, the
    # structure a real optimizer emits. Same b traffic per iteration; the
    # register discipline alone flips the absorption signature (Fig. 4).
    R = 8

    if optimized:
        repeats = 16

        def make(noise, k):
            def fn(a, b, *nc):
                def body(i, st):
                    accs = list(st[0])
                    ncs = st[1:]
                    kk = i % n
                    bv = jax.lax.dynamic_slice(b, (kk, 0), (1, n))
                    for r in range(R):
                        av = jax.lax.dynamic_slice(a, (r, kk), (1, 1))
                        accs[r] = accs[r] + av * bv   # 8 independent chains
                    if noise is not None:
                        ncs = (noise.emit(ncs[0], k, i),)
                    return (tuple(accs), *ncs)
                accs0 = tuple(jnp.zeros((1, n), jnp.float32)
                              for _ in range(R))
                st = jax.lax.fori_loop(0, repeats * n, body, (accs0, *nc))
                o = sum(jnp.sum(acc) for acc in st[0])
                return (o, noise.finalize(st[1])) if noise is not None else o
            return jax.jit(fn)

        return loop_region("matmul_O3", make, lambda: (a, b),
                           body_size=2 * R + 1)

    repeats = 32
    UNROLL = 8

    def make(noise, k):
        def fn(a, b, out, *nc):
            def body(i, st):
                ob, *ncs = st
                kk = (i * UNROLL) % n
                for u in range(UNROLL):
                    av = jax.lax.dynamic_slice(a, (0, kk + u), (1, 1))
                    bv = jax.lax.dynamic_slice(b, (kk + u, 0), (1, n))
                    cur = jax.lax.dynamic_slice(ob, (0, 0), (1, n))  # reload
                    cur = cur + av * bv
                    ob = jax.lax.dynamic_update_slice(ob, cur, (0, 0))  # store
                if noise is not None:
                    ncs = (noise.emit(ncs[0], k, i),)
                return (ob, *ncs)
            st = jax.lax.fori_loop(0, repeats * n // UNROLL, body, (out, *nc))
            o = jnp.sum(st[0])
            return (o, noise.finalize(st[1])) if noise is not None else o
        return jax.jit(fn)

    out = jnp.zeros((1, n), jnp.float32)
    return loop_region("matmul_O0", make, lambda: (a, b, out),
                       body_size=5 * UNROLL)

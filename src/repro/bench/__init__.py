from repro.bench.kernels import (  # noqa: F401
    haccmk_region,
    lat_mem_rd_region,
    matmul_region,
    spmxv_region,
    stream_region,
)

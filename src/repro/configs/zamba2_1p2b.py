"""Zamba2-1.2B [arXiv:2411.15242; hf] — hybrid: 38 Mamba2 blocks (d_model=2048,
ssm_state=64) with ONE shared full-attention+MLP block (32H MHA kv=32, d_ff=8192)
re-applied every 6 mamba blocks (weight sharing; per-invocation LoRA omitted —
see DESIGN.md §6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    ssm_conv=4,
    attn_every=6,
    tie_embeddings=True,
)

"""Mamba2-780m [arXiv:2405.21060] — 48L d_model=1536, attention-free SSD
(state-space duality), ssm_state=128, expand 2, headdim 64, vocab 50280."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    ssm_conv=4,
    ssm_ngroups=1,
    tie_embeddings=True,
)

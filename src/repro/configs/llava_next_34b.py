"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6] — LM backbone (Yi-34B-like):
60L d_model=7168 56H GQA(kv=8) d_ff=20480 vocab=64000. Anyres vision tiling is a
STUB: input_specs() provides precomputed patch embeddings (up to 5 tiles x 576)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    n_img_tokens=2880,      # 5 anyres tiles x 576 patches
    rope_theta=5e6,
)

"""Config dataclasses for models, shapes, meshes and training.

Every assigned architecture gets one module in this package exporting CONFIG.
Shapes are global (the assignment pairs every arch with the same 4 LM shapes);
applicability rules (decode/long-context) live here too.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    act: str = "swiglu"          # swiglu | geglu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- attention extras ---
    window: int = 0              # sliding-window size; 0 = full attention
    attn_impl: str = "blocked"   # blocked | flash (online-softmax, static
    #                              triangular/window pruning) — §Perf lever

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden size (d_ff used for dense part if any)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0           # N (state size per head); 0 = no ssm blocks
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (zamba2) ---
    attn_every: int = 0          # insert the shared attention block every N ssm blocks

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 0          # encoder sequence length (precomputed frame embeds)

    # --- vlm (llava) ---
    n_img_tokens: int = 0        # precomputed patch-embedding tokens per example

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ---- derived helpers -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without a full-attention KV scan?

        True for SSM / hybrid (O(1)-ish state) and sliding-window attention
        (bounded rolling cache)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step. All assigned archs decode
        (whisper is enc-dec: the decoder decodes)."""
        return True

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # Parameter count estimate (for MODEL_FLOPS = 6 N D and memory budgeting).
    def param_count(self) -> int:
        n = 0
        d = self.d_model
        # embeddings (+ untied head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm", "moe"):
            per = self._attn_params() + self._mlp_params()
            n += self.n_layers * per
        elif self.family == "encdec":
            enc = self.enc_layers * (self._attn_params() + self._mlp_params())
            dec = self.n_layers * (2 * self._attn_params() + self._mlp_params())
            n += enc + dec
        elif self.family == "ssm":
            n += self.n_layers * self._ssm_params()
        elif self.family == "hybrid":
            n += self.n_layers * self._ssm_params()
            n += self._attn_params() + self._mlp_params()  # one shared block
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        moe_active = 3 * d * self.moe_d_ff * self.top_k
        n += self.n_layers * (self._attn_params() + moe_active)
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.family == "moe" and self.n_experts:
            return self.n_experts * 3 * d * self.moe_d_ff
        return 3 * d * self.d_ff  # gated MLPs (swiglu/geglu): w_gate, w_up, w_down

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        nh, ns = self.ssm_nheads, self.ssm_state
        ng = self.ssm_ngroups
        in_proj = d * (2 * di + 2 * ng * ns + nh)   # x, z, B, C, dt
        conv = self.ssm_conv * (di + 2 * ng * ns)
        out = di * d
        extra = nh * 2 + di                          # A_log, D, norm
        return in_proj + conv + out + extra


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full attention at 524288 ctx — skipped per assignment (sub-quadratic only)"
    if shape.is_decode and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # grad accumulation (also comm/compute overlap)
    remat: str = "nothing"           # nothing | dots | full  (what to SAVE)
    scan_group: int = 1              # layers per checkpointed scan body
    grad_compress: str = "none"      # none | int8
    seed: int = 0
    ckpt_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    step_deadline_s: float = 0.0     # straggler watchdog; 0 = off


# TPU v5e hardware model (targets; per prompt)
@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: int = 16 * 1024**3
    hbm_latency_s: float = 700e-9    # per dependent access chain step (approx)


TPU_V5E = HardwareConfig()
TPU_V5P = HardwareConfig(name="tpu_v5p", peak_flops=459e12, hbm_bw=2765e9,
                         ici_bw=90e9, hbm_bytes=95 * 1024**3)
# A "DDR-like" disaggregated-memory point for the paper's Table-4 style study:
# high capacity, lower bandwidth, higher latency (CXL-attached).
CXL_MEM = HardwareConfig(name="cxl_ddr", peak_flops=197e12, hbm_bw=256e9,
                         ici_bw=50e9, hbm_bytes=512 * 1024**3,
                         hbm_latency_s=1400e-9)

"""Mixtral 8x22B [arXiv:2401.04088; hf] — 56L d_model=6144 48H GQA(kv=8)
MoE 8 experts top-2, per-expert d_ff=16384, vocab 32768, sliding-window attention
(window 4096 per assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,                 # no dense MLP; experts only
    moe_d_ff=16384,
    n_experts=8,
    top_k=2,
    vocab_size=32768,
    window=4096,            # SWA -> sub-quadratic rolling KV cache
    rope_theta=1e6,
)

"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-size ModelConfig; ``get_smoke_config(name)``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (  # noqa: F401
    CXL_MEM,
    MULTI_POD,
    SHAPES,
    SINGLE_POD,
    TPU_V5E,
    TPU_V5P,
    HardwareConfig,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    shape_applicable,
)

ARCHS: tuple[str, ...] = (
    "mixtral_8x22b",
    "qwen3_moe_30b_a3b",
    "mamba2_780m",
    "whisper_large_v3",
    "llava_next_34b",
    "minitron_4b",
    "deepseek_coder_33b",
    "gemma_2b",
    "mistral_large_123b",
    "zamba2_1p2b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-780m": "mamba2_780m",
    "whisper-large-v3": "whisper_large_v3",
    "llava-next-34b": "llava_next_34b",
    "minitron-4b": "minitron_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma-2b": "gemma_2b",
    "mistral-large-123b": "mistral_large_123b",
    "zamba2-1.2b": "zamba2_1p2b",
})


def canonical(name: str) -> str:
    key = name.strip().lower()
    if key in ARCHS:
        return key
    if key in _ALIASES:
        return _ALIASES[key]
    key2 = key.replace("-", "_").replace(".", "p")
    if key2 in ARCHS:
        return key2
    raise KeyError(f"unknown architecture {name!r}; known: {sorted(ARCHS)}")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced config of the same family: tiny widths/depths/vocab, runnable on CPU."""
    cfg = get_config(name)
    small: dict = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
    )
    if cfg.family == "moe":
        small.update(n_experts=4, top_k=2, moe_d_ff=64)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32, ssm_expand=2)
    if cfg.family == "hybrid":
        small.update(attn_every=2, n_kv_heads=4)
    if cfg.family == "encdec":
        small.update(enc_layers=2, enc_frames=16)
    if cfg.family == "vlm":
        small.update(n_img_tokens=8)
    if cfg.window:
        small.update(window=16)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)

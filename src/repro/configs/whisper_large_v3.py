"""Whisper large-v3 [arXiv:2212.04356] — encoder-decoder, 32L enc + 32L dec,
d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866. Conv frontend is a STUB:
input_specs() provides precomputed log-mel frame embeddings (1500 frames)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    enc_layers=32,
    enc_frames=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    act="geglu",            # whisper uses plain gelu MLP; geglu is our gated variant
    vocab_size=51866,
)

"""Deterministic, resumable synthetic data pipeline.

Batches are a pure function of (seed, step): after a restart the pipeline
replays exactly the batch the failed step would have consumed (the trainer's
fault-tolerance contract). In a multi-host deployment each host generates its
own batch shard from (seed, step, host_slice) — no data redistribution needed
on elastic rescale.

Tasks:
  lcg      — t_{n+1} = (a·t_n + c) mod V: deterministic structure a small LM
             drives to near-zero loss (used by examples/train_lm.py to show
             real learning).
  uniform  — i.i.d. tokens (throughput/benchmark runs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

_A, _C = 1103515245, 12345


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    task: str = "lcg"
    seed: int = 0
    batch_override: Optional[int] = None

    def _rng(self, step: int) -> np.random.RandomState:
        return np.random.RandomState((self.seed * 1_000_003 + step) % (2**31))

    def batch(self, step: int) -> dict[str, Any]:
        import jax.numpy as jnp

        V = self.cfg.vocab_size
        B = self.batch_override or self.shape.global_batch
        S = self.shape.seq_len
        rng = self._rng(step)
        if self.task == "lcg":
            a = (_A % V) or 1
            t = rng.randint(0, V, size=(B, 1))
            seq = [t]
            for _ in range(S):
                t = (a * t + _C) % V
                seq.append(t)
            full = np.concatenate(seq, axis=1)           # (B, S+1)
            tokens, labels = full[:, :-1], full[:, 1:]
        else:
            tokens = rng.randint(0, V, size=(B, S))
            labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": jnp.asarray(tokens, jnp.int32),
               "labels": jnp.asarray(labels, jnp.int32)}
        if self.cfg.family == "encdec":
            out["frames"] = jnp.asarray(
                rng.standard_normal((B, self.cfg.enc_frames, self.cfg.d_model))
                .astype(np.float32), jnp.dtype(self.cfg.compute_dtype))
        if self.cfg.family == "vlm":
            out["img_embeds"] = jnp.asarray(
                rng.standard_normal((B, self.cfg.n_img_tokens, self.cfg.d_model))
                .astype(np.float32), jnp.dtype(self.cfg.compute_dtype))
        return out

    # iterator protocol (stateful cursor) — the trainer can also call
    # ``pipeline.batch(step)`` directly for exact replay.
    def __iter__(self):
        self._cursor = 0
        return self

    def __next__(self):
        b = self.batch(self._cursor)
        self._cursor += 1
        return b

    def __call__(self, step: int):
        return self.batch(step)

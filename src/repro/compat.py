"""JAX version-compatibility layer.

The repo targets the modern mesh API (``jax.sharding.get_abstract_mesh``,
``jax.sharding.AxisType``, ``jax.set_mesh``, ``jax.shard_map``) but must run
on JAX 0.4.x where none of those exist. Every version-dependent call goes
through the stable helpers below — no module under ``src/repro/`` may touch
``jax.sharding.get_abstract_mesh`` / ``jax.sharding.AxisType`` directly.

Policy: feature-detect once at import (getattr, never version string
comparison), prefer the modern API when present, and fall back to the oldest
equivalent that preserves semantics:

  get_abstract_mesh  -> thread-local physical mesh (``with mesh:`` context)
  AxisType.Auto      -> omitted (0.4.x meshes are implicitly "auto")
  jax.set_mesh       -> jax.sharding.use_mesh -> ``with mesh:``
  jax.shard_map      -> jax.experimental.shard_map (check_vma -> check_rep)
  AbstractMesh(a, b) -> AbstractMesh(tuple(zip(names, sizes)))
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax

# ``AxisType`` is None on JAX versions that predate explicit/auto axis types.
AxisType = getattr(jax.sharding, "AxisType", None)

_get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
_set_mesh = getattr(jax, "set_mesh", None) or getattr(jax.sharding, "use_mesh",
                                                      None)
_shard_map = getattr(jax, "shard_map", None)


def axis_types_auto(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,) * n`` as a splat-able kwargs dict.

    Empty on JAX versions without axis types, where every mesh axis already
    behaves as Auto.
    """
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(axis_shapes, axis_names, *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types whenever the API supports them."""
    try:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             **axis_types_auto(len(axis_names)))
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def abstract_mesh(axis_shapes, axis_names) -> "jax.sharding.AbstractMesh":
    """Version-proof ``AbstractMesh`` constructor (sizes + names)."""
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(axis_shapes), tuple(axis_names),
                  **axis_types_auto(len(axis_names)))
    except (TypeError, ValueError):
        # 0.4.x signature: AbstractMesh(((name, size), ...))
        return AM(tuple(zip(axis_names, axis_shapes)))


def get_abstract_mesh() -> Optional[Any]:
    """The mesh of the enclosing ``set_mesh`` context, or None.

    Unlike the raw modern API (which returns an *empty* AbstractMesh when no
    mesh is set), this normalizes to None whenever there is no usable mesh, so
    callers only ever branch on ``mesh is None``.
    """
    if _get_abstract_mesh is not None:
        m = _get_abstract_mesh()
    else:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
    if m is None or getattr(m, "empty", False) or not m.axis_names:
        return None
    return m


@contextlib.contextmanager
def set_mesh(mesh):
    """Enter ``mesh`` as the ambient mesh (modern: abstract mesh context;
    0.4.x: the thread-local physical mesh that pjit and collectives read)."""
    if _set_mesh is not None:
        with _set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any JAX."""
    if _shard_map is not None:
        try:
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
        except TypeError:
            pass  # older keyword spelling below
    try:
        from jax.experimental.shard_map import shard_map as sm
    except ImportError:
        sm = _shard_map
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def cost_analysis(compiled) -> Optional[dict]:
    """``compiled.cost_analysis()`` normalized to a single dict (0.4.x wraps
    the per-program properties in a one-element list)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else None
    return cost


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` (newer JAX) or the classic psum-of-ones."""
    f = getattr(jax.lax, "axis_size", None)
    if f is not None:
        return f(axis_name)
    return jax.lax.psum(1, axis_name)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    """{axis name: size} for Mesh and AbstractMesh across versions."""
    if hasattr(mesh, "axis_sizes"):
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    return dict(mesh.shape.items())


def prefetch_scalar_grid_spec(*, num_scalar_prefetch: int, grid,
                              in_specs, out_specs, scratch_shapes=()):
    """A Pallas grid spec whose first ``num_scalar_prefetch`` operands are
    scalar-prefetch refs (SMEM-resident before the kernel body runs) — the
    delivery channel for the runtime-k noise quantity.

    Feature-detects the classic ``pltpu.PrefetchScalarGridSpec``; newer JAX
    folds scalar prefetch into ``pl.GridSpec(num_scalar_prefetch=...)``.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
    if cls is not None:
        return cls(num_scalar_prefetch=num_scalar_prefetch, grid=grid,
                   in_specs=in_specs, out_specs=out_specs,
                   scratch_shapes=list(scratch_shapes))
    return pl.GridSpec(num_scalar_prefetch=num_scalar_prefetch, grid=grid,
                       in_specs=in_specs, out_specs=out_specs,
                       scratch_shapes=list(scratch_shapes))

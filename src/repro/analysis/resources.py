"""Resource tagging for surviving noise instructions.

Maps opcodes to the hardware resource they exercise and turns a census
delta (extra instructions per injected pattern) into a resource-pressure
vector plus a predicted sensitivity direction — the static half of the
paper's claim that each noise mode pressures ONE resource:

  compute    arithmetic / transcendental ops (count per pattern)
  bandwidth  load/store-family ops (result bytes moved per pattern)
  latency    serial def-use chain growth through the load family
             (chain-depth delta per pattern)

The direction rule encodes the cost asymmetry: any load-family payload
dominates the direction (a slice is far more expensive per element than
the add that consumes it), and a load chain that grows as fast as the
load count is serial — a pointer chase — so it pressures latency, not
bandwidth.
"""
from __future__ import annotations

COMPUTE_OPS = frozenset({
    "add", "subtract", "multiply", "divide", "dot", "convolution",
    "exponential", "log", "power", "rsqrt", "sqrt", "tanh",
})
BANDWIDTH_OPS = frozenset({
    "dynamic-slice", "gather", "slice", "dynamic-update-slice", "scatter",
})
ICI_OPS = frozenset({
    "all-reduce", "all-gather", "all-to-all", "collective-permute",
    "reduce-scatter",
})

# a load chain growing at >= this fraction of a link PER PATTERN is serial
SERIAL_CHAIN_FRAC = 0.75

# noise-mode target vocabulary -> resource family the audit predicts
TARGET_FAMILY = {
    "compute": "compute",
    "vmem": "bandwidth",
    "l1": "bandwidth",
    "memory": "bandwidth",
    "latency": "latency",
    "ici": "ici",
}


def pressure_vector(count_delta: dict, bytes_delta: dict,
                    depth_delta: int, patterns: int) -> dict[str, float]:
    """Per-pattern resource pressure from a two-compile census delta.

    ``count_delta``/``bytes_delta`` map (opcode, mult, where) -> extra
    instructions / extra result bytes; mult weights each by its execution
    count. ``depth_delta`` is the load-family chain-depth growth."""
    compute = sum(n * key[1] for key, n in count_delta.items()
                  if key[0] in COMPUTE_OPS)
    bandwidth = sum(n * key[1] for key, n in bytes_delta.items()
                    if key[0] in BANDWIDTH_OPS)
    ici = sum(n * key[1] for key, n in count_delta.items()
              if key[0] in ICI_OPS)
    return {
        "compute": max(0.0, compute / patterns),
        "bandwidth": max(0.0, bandwidth / patterns),
        "latency": max(0.0, depth_delta / patterns),
        "ici": max(0.0, ici / patterns),
    }


def predict_direction(count_delta: dict, depth_delta: int,
                      patterns: int) -> str:
    """Which resource the surviving noise pressures most.

    Precedence: ici > load family > arithmetic; within the load family a
    chain whose depth grows ~one link per injected pattern is serial — a
    pointer chase — and predicts latency. (Depth per PATTERN, not per
    load: XLA may duplicate a chain into several fusion consumers, which
    inflates the load count but not the true dependency depth.)"""
    ici = sum(n for key, n in count_delta.items()
              if key[0] in ICI_OPS and n > 0)
    loads = sum(n for key, n in count_delta.items()
                if key[0] in BANDWIDTH_OPS and n > 0)
    arith = sum(n for key, n in count_delta.items()
                if key[0] in COMPUTE_OPS and n > 0)
    if ici > 0:
        return "ici"
    if loads > 0:
        if depth_delta >= SERIAL_CHAIN_FRAC * patterns:
            return "latency"
        return "bandwidth"
    if arith > 0:
        return "compute"
    return "none"

"""Def-use graph over parsed HLO computations.

HLO text lists instructions in def-before-use order within a computation,
so longest-path questions are a single forward scan — no explicit topo
sort. The graph is per-computation: cross-computation dataflow (operands
of a fusion/call) is intentionally not followed; the audit compares chain
DEPTH DELTAS between two compiles of the same program, where any constant
cross-computation contribution cancels.
"""
from __future__ import annotations

from typing import Callable, Iterable

from repro.hlo.parse import Instr


def defuse_edges(instrs: Iterable[Instr]) -> dict[str, list[str]]:
    """{instruction name: [operand names defined in this computation]}."""
    defined = {ins.name for ins in instrs}
    return {ins.name: [op for op in ins.operand_names() if op in defined]
            for ins in instrs}

def chain_depth(instrs: Iterable[Instr],
                counted: Callable[[Instr], bool]) -> int:
    """Longest def-use chain, scoring only instructions where ``counted``
    holds. Paths may pass through un-counted nodes (a gather chain whose
    links are joined by converts/adds still scores every gather), which is
    what distinguishes a serial pointer chase from k independent loads."""
    depth: dict[str, int] = {}
    best = 0
    for ins in instrs:
        d = max((depth.get(op, 0) for op in ins.operand_names()), default=0)
        if counted(ins):
            d += 1
        depth[ins.name] = d
        best = max(best, d)
    return best

"""Static noise-audit pass over compiled HLO (the paper's §2.3 analogue).

Runs BEFORE any measurement: every planned (region, mode) pair is compiled
at two small static noise counts plus a clean baseline, the optimized HLO
is censused into per-(opcode, nesting-multiplier) instruction counts, and
the k-scaling delta tells us — instruction-accurately — whether the noise
payload survived XLA, which resource it exercises, and (when it died) which
corruption class ate it: DCE, constant folding, strength reduction,
fusion-into-consumer, or loop-invariant hoisting.

  graph.py      def-use graph over parsed HLO; dependency-chain depth
  resources.py  opcode -> resource tagging; pressure vector; direction rule
  audit.py      census, corruption detectors, AuditReport, plan-level audit
"""
from repro.analysis.audit import (  # noqa: F401
    K_HI,
    K_LO,
    AuditError,
    AuditReport,
    audit_pair,
    audit_plan,
    audit_texts,
    compile_text,
    compile_texts,
    take_census,
)
from repro.analysis.graph import chain_depth, defuse_edges  # noqa: F401
from repro.analysis.resources import (  # noqa: F401
    BANDWIDTH_OPS,
    COMPUTE_OPS,
    TARGET_FAMILY,
    predict_direction,
    pressure_vector,
)

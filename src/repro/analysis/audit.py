"""The static noise audit: two-point k-scaling census over optimized HLO.

Counting "did my k patterns survive" on a single noisy compile is brittle:
XLA restructures loop boundaries between a clean and a noisy build, so the
clean-vs-noisy instruction diff carries ±O(1) artifacts that drown a small
k. The audit instead compiles the SAME executable at two static noise
counts (``K_LO``/``K_HI``) and takes the census delta — every instruction
the compiler keeps per extra pattern, with boundary restructuring cancelled
exactly. A third, clean (k=0) compile attributes the corruption class when
the payload died.

Census key is ``(opcode, nesting multiplier, entry|sub)``: computation
names differ between compiles but multipliers (loop trip products) and
entry-ness are structurally stable, so deltas line up. Survival counts the
whole payload family of the mode's target (``core.payload.PAYLOAD_OPS``) —
XLA legitimately CSEs e.g. the loop-invariant dots of an mxu chain while
the carried adds still scale, and family-level counting keeps that pair
honest instead of flagging it dead.

Corruption classes (detected statically, in this order):
  strength_reduction      payload does not scale with k; the hi-vs-clean
                          diff gained a ``multiply`` (k adds -> one a*k)
  constant_folding        payload does not scale; hi-vs-clean gained only
                          constants (the addend was compile-time constant)
  dce                     payload does not scale and left nothing behind
  fusion_into_consumer    payload scales, but lands once (mult 1) inside a
                          sub-computation while the region loops — the
                          noise no longer executes per step
  loop_invariant_hoisting same, but hoisted into the entry computation
  partial_elision         payload scales at < 1 family op per pattern

Verdicts: ``intact`` (>= 1 surviving family op per pattern, placed where
it executes), ``degraded`` (hoisting / fusion / partial), ``dead`` (the
first three classes). Only ``dead`` refuses a fleet plan at the gate.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional

from repro.analysis.graph import chain_depth
from repro.analysis.resources import (BANDWIDTH_OPS, TARGET_FAMILY,
                                      predict_direction, pressure_vector)
from repro.core.noise import NOISE_SCOPE
from repro.core.payload import PAYLOAD_OPS
from repro.hlo.parse import find_entry, nesting_multipliers, parse_module

K_LO = 4
K_HI = 12

# container opcodes: their called computations are censused directly
_CONTAINERS = frozenset({"fusion", "call", "while", "conditional"})
# pure plumbing, never part of a payload family (constant IS counted — the
# constant-folding detector keys on constant growth)
_PLUMBING = frozenset({"tuple", "get-tuple-element", "parameter",
                       "after-all"})


class AuditError(RuntimeError):
    """A planned pair could not be audited (build or compile failed)."""


@dataclasses.dataclass
class Census:
    """One compiled module, reduced to audit-comparable aggregates."""
    counts: Counter          # (opcode, mult, where) -> instructions
    bytes: Counter           # (opcode, mult, where) -> result bytes
    load_depth: int          # longest load-family def-use chain (any comp)
    loop_mult: int           # max loop multiplier over censused comps


def take_census(text: str, *, scoped: bool = False) -> Census:
    """Census one optimized HLO module.

    ``scoped``: count only instructions tagged with the ``noise_pattern``
    named-scope (graph/loop regions keep the tag through optimization;
    Pallas kernel bodies carry no scope metadata, so kernel audits census
    everything and rely on the two-point delta to isolate the noise)."""
    comps = parse_module(text)
    entry = find_entry(comps, text)
    mults = nesting_multipliers(comps, entry)
    counts: Counter = Counter()
    nbytes: Counter = Counter()
    load_depth = 0
    loop_mult = 1
    for cname, instrs in comps.items():
        m = mults.get(cname, 0)
        if not m:
            continue
        loop_mult = max(loop_mult, m)
        where = "entry" if cname == entry else "sub"

        def _counted(ins) -> bool:
            return (ins.opcode in BANDWIDTH_OPS
                    and (not scoped or NOISE_SCOPE in ins.op_name))

        load_depth = max(load_depth, chain_depth(instrs, _counted))
        for ins in instrs:
            if ins.opcode in _CONTAINERS or ins.opcode in _PLUMBING:
                continue
            if scoped and NOISE_SCOPE not in ins.op_name:
                continue
            key = (ins.opcode, m, where)
            counts[key] += 1
            nbytes[key] += ins.result_bytes
    return Census(counts=counts, bytes=nbytes, load_depth=load_depth,
                  loop_mult=loop_mult)


def _delta(hi: Counter, lo: Counter) -> dict:
    """Per-key census difference (keys present in either side)."""
    out = {}
    for key in set(hi) | set(lo):
        d = hi.get(key, 0) - lo.get(key, 0)
        if d:
            out[key] = d
    return out


def _family_total(delta: dict, family: set) -> int:
    return sum(n for key, n in delta.items() if key[0] in family)


@dataclasses.dataclass
class AuditReport:
    """Static verdict for one planned (region, mode) pair."""
    region: str
    mode: str
    target: str                  # the mode's declared resource target
    verdict: str                 # intact | degraded | dead
    corruption: Optional[str]    # corruption class when not intact
    survival: float              # surviving payload-family ops per pattern
    resources: dict              # per-pattern pressure vector
    predicted: str               # compute | bandwidth | latency | ici | none
    agrees: Optional[bool]       # predicted direction matches the target?
    k_lo: int = K_LO
    k_hi: int = K_HI
    detail: str = ""             # human-readable census-delta summary

    @property
    def survival_fraction(self) -> float:
        return max(0.0, min(1.0, self.survival))

    @property
    def ok(self) -> bool:
        return self.verdict != "dead"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["survival"] = round(self.survival, 4)
        d["resources"] = {k: round(v, 4)
                          for k, v in sorted(self.resources.items())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AuditReport":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def explain(self) -> str:
        """One doctor-facing line: what the compiler did to this pair."""
        why = {
            "strength_reduction":
                "k chained adds were strength-reduced to one multiply "
                "(the addend is loop-invariant to XLA)",
            "constant_folding":
                "the noise payload folded to compile-time constants "
                "(the addend was not a runtime value)",
            "dce":
                "the noise payload was dead-code-eliminated (its result "
                "does not reach a live output)",
            "fusion_into_consumer":
                "the payload fused into a consumer computation that runs "
                "once, not per region step",
            "loop_invariant_hoisting":
                "the payload was hoisted out of the region loop and runs "
                "once, not per step",
            "partial_elision":
                "only part of the payload survives per pattern (CSE or "
                "partial folding)",
        }.get(self.corruption or "", "payload scales instruction-for-"
                                     "instruction with k")
        return (f"{self.region} × {self.mode}: {self.verdict} "
                f"(survival {self.survival_fraction:.0%}/pattern, "
                f"predicts {self.predicted}) — {why}")


def _expects_loop_placement(hint: dict, loop_mult: int) -> bool:
    """Should the payload land at a loop multiplier > 1?

    Only when the region says its noise body executes per loop step AND it
    actually loops: a hint with ``steps`` (Pallas grid size) decides from
    that count — a single-step grid legitimately places noise at mult 1,
    and an unrelated inner loop elsewhere in the module must not trip the
    hoisting detector. Hints without ``steps`` (loop regions) fall back to
    the module's own loop multiplier."""
    if not hint.get("in_loop"):
        return False
    steps = hint.get("steps")
    if steps is not None:
        return steps > 1
    return loop_mult > 1


def audit_texts(clean_text: str, lo_text: str, hi_text: str, *,
                region: str, mode: str, target: str,
                hint: Optional[dict] = None,
                k_lo: int = K_LO, k_hi: int = K_HI) -> AuditReport:
    """Audit one pair from its three compiled-HLO texts (pure; this is the
    layer the golden fixtures pin)."""
    hint = hint or {}
    scoped = bool(hint.get("scoped", False))
    c0 = take_census(clean_text, scoped=scoped)
    clo = take_census(lo_text, scoped=scoped)
    chi = take_census(hi_text, scoped=scoped)

    patterns = k_hi - k_lo
    scale = _delta(chi.counts, clo.counts)          # the k-scaling delta
    scale_bytes = _delta(chi.bytes, clo.bytes)
    vs_clean = _delta(chi.counts, c0.counts)        # for attribution only
    family = PAYLOAD_OPS.get(target, PAYLOAD_OPS["compute"])
    survival = max(0, _family_total(scale, family)) / patterns
    depth_delta = max(0, chi.load_depth - clo.load_depth)

    verdict, corruption = "intact", None
    if survival < 1.0 / patterns:                   # < 1 op across the span
        verdict = "dead"
        n_mult = sum(n for key, n in vs_clean.items()
                     if key[0] == "multiply" and n > 0)
        n_const = sum(n for key, n in vs_clean.items()
                      if key[0] == "constant" and n > 0)
        if target == "compute" and n_mult > 0:
            corruption = "strength_reduction"
        elif n_const > 0:
            corruption = "constant_folding"
        else:
            corruption = "dce"
    elif survival < 1.0:
        verdict, corruption = "degraded", "partial_elision"
    elif (_expects_loop_placement(hint, chi.loop_mult)
          and all(key[1] == 1 for key, n in scale.items()
                  if key[0] in family and n > 0)):
        # scales with k but never inside the loop that defines the region
        verdict = "degraded"
        placed_sub = any(key[2] == "sub" for key, n in scale.items()
                         if key[0] in family and n > 0)
        corruption = ("fusion_into_consumer" if placed_sub
                      else "loop_invariant_hoisting")

    resources = pressure_vector(scale, scale_bytes, depth_delta, patterns)
    predicted = predict_direction(scale, depth_delta, patterns)
    fam = TARGET_FAMILY.get(target)
    agrees = (predicted == fam) if predicted != "none" and fam else None

    pieces = [f"{op}@x{m}{'' if w == 'entry' else '/sub'}:{n:+d}"
              for (op, m, w), n in sorted(scale.items())
              if op in family or n > 0]
    return AuditReport(region=region, mode=mode, target=target,
                       verdict=verdict, corruption=corruption,
                       survival=survival, resources=resources,
                       predicted=predicted, agrees=agrees,
                       k_lo=k_lo, k_hi=k_hi,
                       detail=" ".join(pieces[:12]))


def compile_text(target, mode: str, k: int) -> str:
    """Compile ONE static build of a pair and return its optimized HLO text.
    No measurement happens: the executable is lowered and compiled, never
    run."""
    try:
        fn = target.build(mode, k)
        args = target.args_for(mode, k)
        return fn.lower(*args).compile().as_text()
    except Exception as e:                  # noqa: BLE001 — surfaced as audit
        raise AuditError(f"{target.name} × {mode or 'clean'} (k={k}): static "
                         f"build failed during audit: {e}") from e


def compile_texts(target, mode: str, *, k_lo: int = K_LO, k_hi: int = K_HI,
                  clean_text: Optional[str] = None) -> tuple[str, str, str]:
    """The (clean, k_lo, k_hi) static compiles of one pair. ``clean_text``
    reuses an already-compiled clean module (it is mode-independent, so one
    clean compile serves every mode of a region)."""
    if clean_text is None:
        clean_text = compile_text(target, "", 0)
    return (clean_text, compile_text(target, mode, k_lo),
            compile_text(target, mode, k_hi))


def audit_pair(target, mode: str, *, k_lo: int = K_LO, k_hi: int = K_HI,
               clean_text: Optional[str] = None) -> AuditReport:
    """Audit one (RegionTarget, mode) pair: three static compiles (two when
    ``clean_text`` is shared), zero measurements."""
    from repro.core.controller import _default_target

    clean, lo, hi = compile_texts(target, mode, k_lo=k_lo, k_hi=k_hi,
                                  clean_text=clean_text)
    tgt = target.payload_target.get(mode, _default_target(mode))
    return audit_texts(clean, lo, hi, region=target.name, mode=mode,
                       target=tgt, hint=target.audit_hint,
                       k_lo=k_lo, k_hi=k_hi)


def audit_plan(plan, *, skip=frozenset(), on_error=None) -> list[AuditReport]:
    """Audit every (region, mode) pair of a resolved SweepPlan, in plan
    order. The clean (k=0) compile is shared across a region's modes.

    ``skip``: (region, mode) pairs with existing audit records.
    ``on_error``: callback ``(region, mode, AuditError)`` — when given, a
    pair whose static build fails is reported there and skipped instead of
    aborting the whole audit (an unauditable pair is not PROOF of a dead
    payload; the measuring path will surface the real failure)."""
    reports = []
    for spec, targets in plan.resolve():
        for tgt in targets:
            clean: Optional[str] = None
            for mode in spec.modes:
                if (tgt.name, mode) in skip:
                    continue
                try:
                    if clean is None:
                        clean = compile_text(tgt, "", 0)
                    reports.append(audit_pair(tgt, mode, clean_text=clean))
                except AuditError as e:
                    if on_error is None:
                        raise
                    on_error(tgt.name, mode, e)
    return reports

"""Logical-axis sharding: models annotate params/activations with logical axis
names; this module resolves them against the active mesh.

Rules (production mesh: data=DP/FSDP axis, model=TP axis, pod=extra DP axis):

  batch      -> (pod, data)     data parallelism
  heads      -> model           Megatron TP on attention heads (GSPMD pads when
  kv_heads   -> model           non-divisible; padding waste is visible in the
  ff         -> model           roofline FLOPs and is a hillclimb lever)
  experts    -> model           expert parallelism (MoE with many experts)
  vocab      -> model           sharded embedding/logits
  fsdp       -> data            parameter d_model dim (ZeRO-3 style; XLA
                                all-gathers weights at use)
  ssm_heads  -> model           Mamba2 head dim
  cache_seq  -> (decode only)   sequence-parallel KV/flash-decoding; chosen by
                                the cache-spec helpers when kv_heads don't divide
  (anything unknown)            replicated

Divisibility: when concrete dims are supplied, non-divisible axes fall back
to the largest dividing prefix of their rule (often: replication). Examples
that rely on this: MQA (1 kv head -> replicated heads, sharded elsewhere),
Mixtral's 8 experts on model=16 (expert dim replicated, expert_ff picks up
`model` = tensor parallelism inside experts).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),
    "vocab": ("model",),
    "fsdp": ("data",),
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    # decode-cache axes: kv heads shard over model ONLY when divisible (no
    # padding — that would double cache bytes); cache_seq takes whatever axes
    # remain unused (flash-decoding style sequence parallelism).
    "cache_kv_heads": ("model",),
    "cache_seq": ("data", "model"),
    "seq": (),
    "d_model": (),
}



_mesh_axis_sizes = compat.mesh_axis_sizes


def resolve(
    logical: Sequence[Optional[str]],
    dims: Optional[Sequence[int]] = None,
    mesh: Optional[Any] = None,
    rules: Optional[dict[str, tuple[str, ...]]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec for ``mesh``.

    ``dims`` (optional) enables divisibility-aware fallback to replication for
    axes not in PAD_OK. Mesh axes absent from the mesh are dropped, so the same
    annotations work for (data, model), (pod, data, model) and test meshes.
    """
    rules = rules or LOGICAL_RULES
    if mesh is None:
        mesh = compat.get_abstract_mesh()
    sizes = _mesh_axis_sizes(mesh) if mesh is not None and mesh.axis_names else {}
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None or not sizes:
            out.append(None)
            continue
        mesh_axes = tuple(a for a in rules.get(name, ()) if a in sizes and a not in used)
        if not mesh_axes:
            out.append(None)
            continue
        total = 1
        for a in mesh_axes:
            total *= sizes[a]
        truncated = False
        if dims is not None and dims[i] % total != 0:
            # try a prefix of the axes that divides (e.g. batch=1 -> none)
            chosen: tuple[str, ...] = ()
            acc = 1
            for a in mesh_axes:
                if dims[i] % (acc * sizes[a]) == 0:
                    acc *= sizes[a]
                    chosen = chosen + (a,)
                else:
                    break
            mesh_axes = chosen
            truncated = True
        if not mesh_axes:
            out.append(None)
            continue
        used.update(mesh_axes)
        # a truncated multi-axis rule stays a tuple (('pod',) not 'pod') —
        # old PartitionSpec doesn't normalize the two forms as equal
        out.append(mesh_axes if len(mesh_axes) > 1 or truncated
                   else mesh_axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def resolve_tree(logical_tree, shape_tree=None, mesh=None, rules=None):
    """Map ``resolve`` over a pytree of logical-axis tuples (mirrors params)."""
    if shape_tree is None:
        return jax.tree.map(
            lambda lg: resolve(lg, None, mesh, rules), logical_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
    return jax.tree.map(
        lambda lg, sds: resolve(lg, sds.shape, mesh, rules), logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def constrain(x, *logical, rules=None):
    """``with_sharding_constraint`` by logical axes; no-op without a mesh
    context (CPU unit tests) so model code is mesh-agnostic."""
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return x
    spec = resolve(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)


def make_mesh_from_config(mesh_cfg, devices=None) -> Mesh:
    import numpy as np

    if devices is None:
        devices = jax.devices()
    n = int(np.prod(mesh_cfg.shape))
    if len(devices) < n:
        raise ValueError(
            f"mesh {mesh_cfg.shape} needs {n} devices, have {len(devices)} "
            "(dryrun.py sets XLA_FLAGS=--xla_force_host_platform_device_count)")
    return compat.make_mesh(mesh_cfg.shape, mesh_cfg.axes, devices=devices[:n])


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)

from repro.parallel.sharding import (  # noqa: F401
    LOGICAL_RULES,
    constrain,
    make_mesh_from_config,
    resolve,
    resolve_tree,
)

"""Pluggable shard launchers — how a fleet's worker processes come to exist.

``run_fleet`` (executor.py) decides WHAT still needs launching from the
stores; a ``Launcher`` decides HOW a shard becomes a running worker. The
protocol is deliberately small — spawn shard(s), stream their output, report
a returncode per shard — so the executor's retry/merge/classify spine is
identical whether workers run as local subprocesses, over ssh on a cluster,
or inside a deterministic fault-injection mock:

  * ``LocalLauncher``        — subprocess fan-out on this machine (the
    default), or sequential in-process execution for spawn-restricted
    environments (``run --in-process``);
  * ``SSHLauncher``          — one worker per remote host from a declarative
    ``hosts.json`` spec ({addr, python, workdir, env}); pushes the plan (and
    any partial worker store) to the host, runs the standard worker entry
    there, and copies the worker store back so ``merge_stores`` works
    unchanged. Degrades to the documented manual recipe
    (``MANUAL_RECIPE``) when ssh/scp are unavailable;
  * ``MockClusterLauncher``  — deterministic fault injection: a script maps
    shard index -> per-attempt actions ("crash", "drop-point", "timeout",
    "dead", "ok"), so tests and CI exercise the multi-host retry/heal path
    without real hosts.

Retry policy lives in ``RetryBudget``: ``max_attempts`` rounds per
``run_fleet`` call, exponential ``backoff`` between rounds, and an optional
lifetime ``per_shard_cap`` recorded across resumes in ``fleet.json``.

Every launcher hands workers two environment variables as a handshake:
``REPRO_FLEET_EXPECT_DIGEST`` (the plan digest the launcher is driving — the
worker refuses to run if its own plan file disagrees, catching out-of-sync
plan copies across hosts) and ``REPRO_FLEET_HOST`` (the host label the
worker echoes back, recorded in the fleet ledger's attempt log).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import posixpath
import shlex
import shutil
import subprocess
import sys
import threading
from typing import Mapping, Optional, Sequence

from repro.fleet.plan import SweepPlan

log = logging.getLogger("repro.fleet")

LAUNCHER_KINDS = ("local", "ssh", "mock")
MOCK_ACTIONS = ("ok", "crash", "drop-point", "timeout", "dead")

MANUAL_RECIPE = """\
ssh/scp not found on PATH — fall back to the manual multi-host recipe (the
plan file is the only coordination needed):
  1. copy the plan JSON to every host (same bytes => same digest => same grid)
  2. on host i of N:
       PYTHONPATH=src python -m repro.launch.probe --plan plan.json --shard i/N
  3. copy each host's store.wIofN.jsonl back next to the local canonical store
  4. PYTHONPATH=src python -m repro.fleet run --plan plan.json --resume
     (nothing left to launch, so it merges, classifies, writes the report)
A host that died mid-sweep just re-runs its step-2 command: the worker store
heals its torn tail and only the missing points are re-measured."""


class FleetError(RuntimeError):
    """Fleet-level failure the caller must act on (bad state, dead shards,
    unusable launcher config). Re-exported by ``repro.fleet.executor``."""


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryBudget:
    """How persistently ``run_fleet`` re-launches failed/incomplete shards.

    ``max_attempts``   — launch rounds per ``run_fleet`` call (1 = today's
                         behaviour: one launch, then fail loudly);
    ``backoff``        — seconds to sleep before retry round r, doubled each
                         round (``backoff * 2**(r-2)``);
    ``per_shard_cap``  — LIFETIME attempts a single shard may consume across
                         resumes (0 = unlimited); counted from the attempts
                         recorded in ``fleet.json``, so a shard that keeps
                         dying eventually fails permanently instead of
                         burning the budget forever.
    """
    max_attempts: int = 1
    backoff: float = 0.0
    per_shard_cap: int = 0

    def __post_init__(self):
        """Reject nonsense budgets at construction time."""
        if self.max_attempts < 1:
            raise FleetError(f"retry max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.backoff < 0 or self.per_shard_cap < 0:
            raise FleetError("retry backoff and per_shard_cap must be >= 0")

    def delay(self, round_no: int) -> float:
        """Backoff (seconds) to sleep before launch round ``round_no``."""
        if round_no <= 1 or not self.backoff:
            return 0.0
        return self.backoff * (2 ** (round_no - 2))

    def to_dict(self) -> dict:
        """The plan-serializable form (``SweepPlan.retry``)."""
        return {"max_attempts": self.max_attempts, "backoff": self.backoff,
                "per_shard_cap": self.per_shard_cap}

    @classmethod
    def from_dict(cls, d: Optional[Mapping]) -> "RetryBudget":
        """Build from a plan's ``retry`` dict (missing keys -> defaults)."""
        d = dict(d or {})
        unknown = sorted(set(d) - {"max_attempts", "backoff", "per_shard_cap"})
        if unknown:
            raise FleetError(f"unknown retry setting(s) {unknown}; known: "
                             "max_attempts, backoff, per_shard_cap")
        return cls(max_attempts=int(d.get("max_attempts", 1)),
                   backoff=float(d.get("backoff", 0.0)),
                   per_shard_cap=int(d.get("per_shard_cap", 0)))


# ---------------------------------------------------------------------------
# the launcher protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardOutcome:
    """What one launched shard attempt reported back: its returncode and the
    host label it ran on (None when the launcher has no host notion)."""
    rc: int
    host: Optional[str] = None


class Launcher:
    """Spawn shard workers, stream their output, report a returncode each.

    Implementations override ``launch``; ``attempts`` maps each index to the
    shard's 1-based LIFETIME attempt ordinal (including attempts recorded in
    ``fleet.json`` by previous runs), so fault-injection scripts and logs
    stay deterministic across resumes. Completeness is never decided here —
    the executor re-derives it from the stores after every round.
    """

    name = "?"

    def launch(self, plan_path: str, plan: SweepPlan,
               indices: Sequence[int], *,
               attempts: Optional[Mapping[int, int]] = None
               ) -> dict[int, ShardOutcome]:
        """Run the given shard indices; return {index: ShardOutcome}."""
        raise NotImplementedError


def worker_env(plan: Optional[SweepPlan] = None,
               host: Optional[str] = None) -> dict:
    """The environment a spawned worker needs: this repro's src dir on
    PYTHONPATH (so ``-m repro.launch.probe`` resolves regardless of how the
    parent was launched) plus the launcher->worker handshake variables."""
    import repro

    # repro is a namespace package: __file__ is None, __path__ holds the dir
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    parts = [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    if src not in parts:
        env["PYTHONPATH"] = os.pathsep.join([src] + parts)
    if plan is not None:
        env["REPRO_FLEET_EXPECT_DIGEST"] = plan.digest()
    if host:
        env["REPRO_FLEET_HOST"] = host
    return env


def _pump(pipe, prefix: str) -> None:
    """Stream a worker's merged stdout/stderr line-prefixed to our stdout."""
    for line in pipe:
        print(prefix + line.rstrip("\n"), flush=True)


def _run_worker_inline(plan_path: str, plan: SweepPlan, index: int) -> int:
    """Execute one shard in THIS process (re-loading the plan from disk like
    a real worker would); exceptions become nonzero returncodes."""
    from repro.fleet.executor import run_worker

    try:
        run_worker(SweepPlan.load(plan_path), index=index, count=plan.shards)
        return 0
    except SystemExit as e:
        return int(bool(e.code))
    except Exception:
        log.warning("in-process shard %d failed", index, exc_info=True)
        return 1


# ---------------------------------------------------------------------------
# LocalLauncher — subprocess fan-out / in-process fallback on this machine
# ---------------------------------------------------------------------------


class LocalLauncher(Launcher):
    """Workers on THIS machine.

    Default: one ``python -m repro.launch.probe --plan P --shard i/N``
    subprocess per index, all concurrent (the grid is embarrassingly
    parallel; wall-clock interference between co-located shards is the
    fan-out's price and ``SSHLauncher`` is the escape), output streamed
    line-prefixed. ``in_process=True`` runs shards sequentially inside this
    process instead — for spawn-restricted environments and fast tests.
    """

    def __init__(self, *, in_process: bool = False):
        """``in_process``: sequential same-process workers instead of
        concurrent subprocesses."""
        self.in_process = bool(in_process)
        self.name = "in-process" if in_process else "local"

    def launch(self, plan_path: str, plan: SweepPlan,
               indices: Sequence[int], *,
               attempts: Optional[Mapping[int, int]] = None
               ) -> dict[int, ShardOutcome]:
        """Spawn (or inline-run) every index; see class docstring."""
        if self.in_process:
            return {i: ShardOutcome(_run_worker_inline(plan_path, plan, i))
                    for i in indices}
        procs: dict[int, tuple] = {}
        env = worker_env(plan, host="localhost")
        for i in indices:
            cmd = [sys.executable, "-m", "repro.launch.probe",
                   "--plan", plan_path, "--shard", f"{i}/{plan.shards}"]
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True,
                                 bufsize=1, env=env)
            t = threading.Thread(
                target=_pump, args=(p.stdout, f"[shard {i}/{plan.shards}] "),
                daemon=True)
            t.start()
            procs[i] = (p, t)
        out: dict[int, ShardOutcome] = {}
        for i, (p, t) in procs.items():
            out[i] = ShardOutcome(p.wait(), "localhost")
            t.join(timeout=5)
        return out


# ---------------------------------------------------------------------------
# SSHLauncher — one worker per remote host from a hosts.json spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """One remote host in an ``SSHLauncher`` fleet.

    ``addr``    — the ssh destination (``user@host`` or an ssh_config alias);
    ``python``  — the interpreter to run there (a venv path works);
    ``workdir`` — remote directory to cd into; the plan file is copied here
                  and the plan's (relative) store path resolves under it;
    ``env``     — extra environment exported before the worker starts
                  (e.g. ``{"PYTHONPATH": "src"}`` for a checkout).
    """
    addr: str
    python: str = "python3"
    workdir: str = "."
    env: tuple = ()          # tuple of (key, value) pairs; hashable

    @classmethod
    def from_dict(cls, d: Mapping) -> "HostSpec":
        """Build from one hosts.json entry; only ``addr`` is required."""
        if not d.get("addr"):
            raise FleetError(f"host spec {dict(d)!r} needs an 'addr'")
        unknown = sorted(set(d) - {"addr", "python", "workdir", "env"})
        if unknown:
            raise FleetError(f"host {d['addr']!r}: unknown key(s) {unknown}; "
                             "known: addr, python, workdir, env")
        return cls(addr=str(d["addr"]), python=str(d.get("python", "python3")),
                   workdir=str(d.get("workdir", ".")),
                   env=tuple(sorted((str(k), str(v))
                             for k, v in dict(d.get("env", {})).items())))


def load_hosts(path: str) -> list[HostSpec]:
    """Parse a hosts.json file: either a bare list of host specs or an
    object ``{"hosts": [...]}`` (see ``HostSpec`` for the entry keys)."""
    with open(path) as f:
        data = json.load(f)
    entries = data.get("hosts") if isinstance(data, dict) else data
    if not isinstance(entries, list) or not entries:
        raise FleetError(f"{path}: expected a non-empty list of host specs "
                         "(or {\"hosts\": [...]})")
    return [HostSpec.from_dict(h) for h in entries]


class SSHLauncher(Launcher):
    """One worker per remote host, coordinated only by the plan file.

    Per shard i: pick host ``hosts[i % len(hosts)]``, push the plan (and the
    shard's partial worker store, if any — so retries on a different host
    still re-measure only missing points), run the standard worker entry
    under the handshake env, stream its output line-prefixed, then copy the
    worker store (+ stats) back through a per-host staging name
    (``repro.core.campaign.host_store``) and atomically rename it into
    place. ``merge_stores`` and classification see exactly the same files a
    local fan-out produces.

    Requires a RELATIVE plan store path (it must resolve under each host's
    workdir). When ssh/scp are missing this launcher refuses to start and
    prints ``MANUAL_RECIPE`` instead — the documented by-hand flow.
    """

    name = "ssh"

    def __init__(self, hosts: Sequence[HostSpec]):
        """``hosts``: the fleet's host ring (shard i -> hosts[i % len])."""
        if not hosts:
            raise FleetError("SSHLauncher needs at least one host "
                             "(--hosts hosts.json)")
        self.hosts = list(hosts)

    # -- availability -------------------------------------------------------
    @staticmethod
    def available() -> bool:
        """True when both ssh and a file-copy tool (rsync or scp) exist."""
        return bool(shutil.which("ssh")
                    and (shutil.which("rsync") or shutil.which("scp")))

    def _require_available(self) -> None:
        """Degrade loudly: no ssh/scp -> FleetError carrying the manual
        multi-host recipe."""
        if not self.available():
            raise FleetError(MANUAL_RECIPE)

    # -- host/shard geometry ------------------------------------------------
    def host_for(self, index: int) -> HostSpec:
        """The host shard ``index`` runs on (round-robin over the ring)."""
        return self.hosts[index % len(self.hosts)]

    # -- command construction (unit-testable without a live host) -----------
    @staticmethod
    def _copy_cmd(src: str, dst: str) -> list[str]:
        """rsync (preferred) or scp argv copying ``src`` to ``dst``; either
        side may be a ``host:path`` remote."""
        if shutil.which("rsync"):
            return ["rsync", "-az", "-e", "ssh -o BatchMode=yes", src, dst]
        return ["scp", "-q", "-o", "BatchMode=yes", src, dst]

    def _remote_command(self, host: HostSpec, plan: SweepPlan,
                        plan_base: str, index: int) -> list[str]:
        """The full ssh argv that runs shard ``index`` on ``host``: cd into
        the workdir, export the handshake + host env, exec the worker."""
        ws = plan.worker_stores()[index]
        # handshake keys merge LAST: a hosts.json env block must never be
        # able to clobber the digest check the handshake exists to enforce
        exports = {**dict(host.env),
                   "REPRO_FLEET_EXPECT_DIGEST": plan.digest(),
                   "REPRO_FLEET_HOST": host.addr}
        parts = [f"cd {shlex.quote(host.workdir)}"]
        d = posixpath.dirname(ws)
        if d:
            parts.append(f"mkdir -p {shlex.quote(d)}")
        # a stale stats file from a previous attempt on this host must not
        # be pulled back and credited to an attempt whose worker never
        # finished (run_worker writes stats only on completion)
        parts.append(f"rm -f {shlex.quote(ws + '.stats.json')}")
        env_str = " ".join(f"{k}={shlex.quote(v)}"
                           for k, v in sorted(exports.items()))
        parts.append(f"env {env_str} {host.python} -m repro.launch.probe "
                     f"--plan {shlex.quote(plan_base)} "
                     f"--shard {index}/{plan.shards}")
        return ["ssh", "-o", "BatchMode=yes", host.addr, " && ".join(parts)]

    # -- file movement ------------------------------------------------------
    @staticmethod
    def _run_quiet(cmd: list[str]) -> int:
        """Run a copy/setup command, logging (not raising) on failure."""
        res = subprocess.run(cmd, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        if res.returncode:
            log.warning("ssh launcher: %s failed (rc=%d): %s",
                        " ".join(cmd[:2]), res.returncode,
                        (res.stdout or "").strip()[-500:])
        return res.returncode

    def _push(self, host: HostSpec, plan_path: str, plan: SweepPlan,
              index: int) -> int:
        """Stage the plan (and any partial worker store) onto the host."""
        ws = plan.worker_stores()[index]
        rdir = posixpath.join(host.workdir, posixpath.dirname(ws)) \
            if posixpath.dirname(ws) else host.workdir
        rc = self._run_quiet(["ssh", "-o", "BatchMode=yes", host.addr,
                              f"mkdir -p {shlex.quote(rdir)}"])
        if rc:
            return rc
        rc = self._run_quiet(self._copy_cmd(
            plan_path, f"{host.addr}:{posixpath.join(host.workdir, os.path.basename(plan_path))}"))
        if rc:
            return rc
        if os.path.exists(ws):      # partial store: let the host heal/resume
            rc = self._run_quiet(self._copy_cmd(
                ws, f"{host.addr}:{posixpath.join(host.workdir, ws)}"))
        return rc

    def _pull(self, host: HostSpec, plan: SweepPlan, index: int) -> int:
        """Fetch the worker store (+ stats) back through the per-host
        staging name, then atomically rename over the local path."""
        from repro.core.campaign import host_store

        ws = plan.worker_stores()[index]
        d = os.path.dirname(ws)
        if d:
            os.makedirs(d, exist_ok=True)
        for remote, local in ((ws, ws), (ws + ".stats.json",
                                         ws + ".stats.json")):
            stage = host_store(local, host.addr)
            rc = self._run_quiet(self._copy_cmd(
                f"{host.addr}:{posixpath.join(host.workdir, remote)}", stage))
            if rc and local == ws:
                return rc           # no store came back: the attempt failed
            if not rc and os.path.exists(stage):
                os.replace(stage, local)
        return 0

    # -- the protocol -------------------------------------------------------
    def launch(self, plan_path: str, plan: SweepPlan,
               indices: Sequence[int], *,
               attempts: Optional[Mapping[int, int]] = None
               ) -> dict[int, ShardOutcome]:
        """Push plan+store, run the worker over ssh, pull the store back —
        one thread per shard, concurrently across hosts."""
        self._require_available()
        if os.path.isabs(plan.store):
            raise FleetError(
                f"SSHLauncher needs a RELATIVE plan store path (it resolves "
                f"under each host's workdir); got {plan.store!r} — rebuild "
                "the plan with a relative --store")
        plan_base = os.path.basename(plan_path)
        out: dict[int, ShardOutcome] = {}
        lock = threading.Lock()

        def one(i: int) -> None:
            host = self.host_for(i)
            rc = self._push(host, plan_path, plan, i)
            if rc:
                with lock:
                    out[i] = ShardOutcome(255, host.addr)
                return
            p = subprocess.Popen(self._remote_command(host, plan, plan_base,
                                                      i),
                                 stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True,
                                 bufsize=1)
            _pump(p.stdout, f"[shard {i}/{plan.shards} @ {host.addr}] ")
            rc = p.wait()
            pull_rc = self._pull(host, plan, i)
            if pull_rc and rc == 0:
                rc = 255            # worker "succeeded" but store never landed
            with lock:
                out[i] = ShardOutcome(rc, host.addr)

        threads = [threading.Thread(target=one, args=(i,), daemon=True)
                   for i in indices]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return out


# ---------------------------------------------------------------------------
# MockClusterLauncher — deterministic fault injection for tests and CI
# ---------------------------------------------------------------------------


def _loads(line: str) -> dict:
    """Tolerant record parse for fault injection: a torn line is just not a
    match, never a crash (read_store_records owns real corruption policy)."""
    try:
        rec = json.loads(line)
        return rec if isinstance(rec, dict) else {}
    except ValueError:
        return {}


def _store_segment_files(path: str) -> tuple[dict, list]:
    """A segmented store's ``(manifest, [(name, entry_or_None, lines)])`` in
    replay order — manifest segments first, then unfolded orphans by name."""
    from repro.core.segments import load_manifest, segments_dir

    sdir = segments_dir(path)
    m = load_manifest(sdir)
    listed = {e["file"] for e in m["segments"]}
    folded = set(m["folded"])
    order = [(e["file"], e) for e in m["segments"]]
    order += [(n, None) for n in sorted(os.listdir(sdir))
              if n.endswith(".jsonl") and n not in listed
              and n[:-len(".jsonl")] not in folded]
    out = []
    for name, ent in order:
        with open(os.path.join(sdir, name)) as f:
            out.append((name, ent,
                        [ln for ln in f.read().split("\n") if ln]))
    return m, out


def _torn(lines: Sequence[str]) -> Optional[bytes]:
    """The torn-tail byte image of ``lines``: last ``done`` marker dropped,
    then truncated mid-way into the (now) trailing record. None when there
    is no done marker to tear."""
    done_idx = max((i for i, ln in enumerate(lines)
                    if _loads(ln).get("kind") == "done"), default=None)
    if done_idx is None:
        return None
    rest = [ln for i, ln in enumerate(lines) if i != done_idx]
    return ("\n".join(rest) + "\n").encode()[:-9]


def tear_store_tail(path: str) -> None:
    """Reproduce the damage a SIGKILL mid-append leaves in a worker store:
    drop the final ``done`` marker, then truncate mid-way into the (now)
    trailing record. ``read_store_records`` heals exactly this shape.

    On a segmented store the same crash leaves a different artifact: the
    writer dies before SEALING, so its done-bearing segment must lose its
    manifest entry (becoming an unsealed orphan) as well as its tail — the
    shape the next writable open heals."""
    from repro.core.segments import is_segmented, save_manifest, segments_dir

    if not is_segmented(path):
        lines = [ln for ln in open(path).read().split("\n") if ln]
        data = _torn(lines)
        if data is None:
            raise FleetError(f"{path}: no done-marked sweep to tear")
        with open(path, "wb") as f:
            f.write(data)
        return
    sdir = segments_dir(path)
    m, files = _store_segment_files(path)
    for name, ent, lines in reversed(files):
        data = _torn(lines)
        if data is None:
            continue
        with open(os.path.join(sdir, name), "wb") as f:
            f.write(data)
        if ent is not None:     # un-seal: the crash shape is an orphan
            m["segments"] = [e for e in m["segments"] if e is not ent]
            save_manifest(sdir, m)
        return
    raise FleetError(f"{path}: no done-marked sweep to tear")


def _done_point_victim(recs: Sequence[dict]) -> Optional[int]:
    """Index (in replay order) of one done-promised point record, or None."""
    for i in range(len(recs) - 1, -1, -1):
        if recs[i].get("kind") == "done" and recs[i].get("ks"):
            key = (recs[i]["region"], recs[i]["mode"])
            ks = {int(k) for k in recs[i]["ks"]}
            for j in range(len(recs) - 1, -1, -1):
                r = recs[j]
                if (r.get("kind") == "point" and int(r.get("k", -1)) in ks
                        and (r.get("region"), r.get("mode")) == key):
                    return j
    return None


def drop_done_point(path: str) -> None:
    """Delete one done-promised ``point`` record while KEEPING its ``done``
    marker — the store shape a lost append or partial merge leaves behind.
    ``pair_status`` then names exactly which (pair, k) is missing, and a
    relaunch re-measures only that point. On a segmented store the victim's
    segment is rewritten and its manifest entry (bytes/records/coverage)
    updated, so the store still loads cleanly — the damage is semantic, not
    structural."""
    from repro.core import segments as seg_mod

    if not seg_mod.is_segmented(path):
        lines = [ln for ln in open(path).read().split("\n") if ln]
        victim = _done_point_victim([_loads(ln) for ln in lines])
        if victim is None:
            raise FleetError(f"{path}: no done-promised point to drop")
        del lines[victim]
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        return
    sdir = seg_mod.segments_dir(path)
    m, files = _store_segment_files(path)
    flat = [(fi, li) for fi, (_, _, lines) in enumerate(files)
            for li in range(len(lines))]
    victim = _done_point_victim(
        [_loads(files[fi][2][li]) for fi, li in flat])
    if victim is None:
        raise FleetError(f"{path}: no done-promised point to drop")
    fi, li = flat[victim]
    name, ent, lines = files[fi]
    del lines[li]
    fp = os.path.join(sdir, name)
    with open(fp, "w") as f:
        for ln in lines:
            f.write(ln + "\n")
    if ent is not None:         # keep the sealed entry honest about the file
        ent["bytes"] = os.path.getsize(fp)
        ent["records"] = len(lines)
        ent["pairs"] = seg_mod._coverage(_loads(ln) for ln in lines)
        seg_mod.save_manifest(sdir, m)


class MockClusterLauncher(Launcher):
    """Deterministic fault injection: a cluster that fails on schedule.

    ``script`` maps shard index -> a sequence of per-attempt actions; attempt
    n of shard i performs ``script[i][n-1]`` and every attempt past the end
    of the list is "ok". Attempt ordinals come from the executor's fleet
    ledger, so scripts stay deterministic across ``--resume`` runs. Actions:

      * "ok"         — run the worker in-process, rc 0;
      * "crash"      — run the worker, then tear the store tail like a
                       SIGKILL mid-append (``tear_store_tail``), rc -9;
      * "drop-point" — run the worker, then delete one done-promised point
                       (``drop_done_point``) so doctor/status can name the
                       exact missing (pair, k), rc -9;
      * "timeout"    — the worker never runs (a hung host killed by its
                       supervisor), rc 124;
      * "dead"       — the worker never runs (host unreachable), rc 1.

    Tests and CI use this to exercise the whole multi-host retry/heal path
    on one machine with zero network dependencies.
    """

    name = "mock"
    DEFAULT_SCRIPT: Mapping = {0: ("crash",)}

    def __init__(self, script: Optional[Mapping] = None):
        """``script``: {shard_index: [action, ...]}; None -> DEFAULT_SCRIPT
        (shard 0 crashes on its first attempt, then recovers)."""
        src = self.DEFAULT_SCRIPT if script is None else script
        try:
            self.script = {int(i): tuple(acts)
                           for i, acts in dict(src).items()}
        except (TypeError, ValueError) as e:
            raise FleetError(f"mock script must map shard indices to "
                             f"action lists: {e}") from e
        bad = sorted({a for acts in self.script.values() for a in acts}
                     - set(MOCK_ACTIONS))
        if bad:
            raise FleetError(f"unknown mock action(s) {bad}; "
                             f"one of {list(MOCK_ACTIONS)}")
        self._seen: dict[int, int] = {}

    def action_for(self, index: int, attempt: int) -> str:
        """The scripted action for shard ``index``'s attempt ``attempt``
        (1-based); past the end of the script every attempt is "ok"."""
        acts = self.script.get(index, ())
        return acts[attempt - 1] if 1 <= attempt <= len(acts) else "ok"

    def launch(self, plan_path: str, plan: SweepPlan,
               indices: Sequence[int], *,
               attempts: Optional[Mapping[int, int]] = None
               ) -> dict[int, ShardOutcome]:
        """Run each index in-process, then apply its scripted fault."""
        out: dict[int, ShardOutcome] = {}
        for i in indices:
            n = (attempts or {}).get(i)
            if n is None:                 # standalone use: count locally
                n = self._seen.get(i, 0) + 1
            self._seen[i] = n
            action = self.action_for(i, n)
            host = f"mock-host-{i}"
            print(f"[mock] shard {i} attempt {n}: scripted action "
                  f"{action!r} on {host}")
            if action == "timeout":
                out[i] = ShardOutcome(124, host)
                continue
            if action == "dead":
                out[i] = ShardOutcome(1, host)
                continue
            rc = _run_worker_inline(plan_path, plan, i)
            ws = plan.worker_stores()[i]
            if rc == 0 and action == "crash":
                tear_store_tail(ws)
                rc = -9
            elif rc == 0 and action == "drop-point":
                drop_done_point(ws)
                rc = -9
            out[i] = ShardOutcome(rc, host)
        return out


# ---------------------------------------------------------------------------
# resolution: CLI flags / plan spec -> a Launcher instance
# ---------------------------------------------------------------------------


def resolve_launcher(kind: Optional[str] = None, *,
                     plan: Optional[SweepPlan] = None,
                     hosts_path: Optional[str] = None,
                     mock_script: Optional[Mapping] = None,
                     in_process: bool = False) -> Launcher:
    """Build the Launcher a fleet run should use.

    Explicit arguments (CLI flags) override the plan's declarative
    ``launcher`` spec; with neither, the default is a subprocess
    ``LocalLauncher``. ``hosts_path`` loads a hosts.json for ssh;
    ``mock_script`` overrides the plan's scripted faults for mock.
    """
    spec = dict(getattr(plan, "launcher", None) or {})
    kind = kind or spec.get("kind") or "local"
    if kind not in LAUNCHER_KINDS:
        raise FleetError(f"unknown launcher kind {kind!r}; "
                         f"one of {list(LAUNCHER_KINDS)}")
    if kind == "local":
        # silently dropping these would run an ssh/mock-shaped request as
        # plain local subprocesses — the sweep would land on the wrong hosts
        if hosts_path or mock_script is not None:
            raise FleetError(
                "--hosts/--mock-script apply to the ssh/mock launchers; "
                "pass --launcher ssh|mock (or declare launcher in the plan)")
        return LocalLauncher(in_process=in_process
                             or bool(spec.get("in_process", False)))
    if in_process:
        raise FleetError(f"--in-process applies to the local launcher only, "
                         f"not {kind!r}")
    if kind == "ssh":
        if hosts_path:
            hosts = load_hosts(hosts_path)
        else:
            hosts = [HostSpec.from_dict(h) for h in spec.get("hosts", [])]
        if not hosts:
            raise FleetError("ssh launcher needs hosts: pass --hosts "
                             "hosts.json or declare launcher.hosts in the "
                             "plan")
        return SSHLauncher(hosts)
    return MockClusterLauncher(mock_script if mock_script is not None
                               else spec.get("script"))

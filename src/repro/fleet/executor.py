"""Fleet executor — plan in, classified report out, no hands in between.

``run_fleet`` drives the whole pipeline the ROADMAP called the NEXT step:

  spawn    N worker shards through a pluggable ``Launcher``
           (repro.fleet.launchers: local subprocesses, ssh hosts, or the
           mock fault-injection cluster), each measuring its slice of the
           plan's grid into its own worker store, output streamed
           line-prefixed;
  retry    a ``RetryBudget`` gives failed/incomplete shards more launch
           rounds within one run; completeness is re-derived from the
           stores between rounds, so a retried shard heals its torn store
           and re-measures only missing points, and every attempt lands in
           the ledger (launcher, host, rc, heal stats);
  survive  a killed shard leaves a truncated worker store; resume re-launches
           ONLY the shards whose slice is incomplete, and the campaign layer
           heals the torn tail and re-measures only the missing points;
  merge    worker stores fold into the plan's canonical store
           (``merge_stores`` — idempotent, atomic);
  classify one ``Campaign.characterize`` per region replays the merged store
           (a complete fleet classifies with ZERO new measurements) and the
           cross-region report lands in ``<store>.report.json``.

Ground truth is the stores, not the bookkeeping: shard completeness is
decided by ``CampaignStore.grid_status`` against the plan's grid, so a lying
or lost ``fleet.json`` can never cause double measurement or a hole.
``fleet.json`` (next to the store) records the plan digest, per-shard
status/attempts/attempt-log/stats, the merge manifest, and the final
classification — the fleet's observable state for humans, the ``status``
CLI, and ``fleet_doctor`` (which explains per shard WHY a fleet is
incomplete: missing ks per pair, torn store to be healed, attempts
exhausted).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import socket
import time
from typing import Callable, Optional, Sequence, Union

from repro.fleet.launchers import (FleetError, Launcher, LocalLauncher,  # noqa: F401  (FleetError re-exported)
                                   RetryBudget, ShardOutcome,
                                   resolve_launcher)
from repro.fleet.plan import SweepPlan

log = logging.getLogger("repro.fleet")

FLEET_SCHEMA = 1


# ---------------------------------------------------------------------------
# reporting helpers (shared by the executor, the fleet CLI, and probe)
# ---------------------------------------------------------------------------


def finish_stats(stats, expect_no_measure: bool) -> None:
    """The campaign tail every entry point prints; ``--expect-no-measure``
    turns "the store fully covers this run" into an exit code."""
    print(f"  [{stats.measured} points measured, "
          f"{stats.cached} replayed from store]")
    if expect_no_measure and stats.measured:
        raise SystemExit(
            f"--expect-no-measure: store was incomplete, {stats.measured} "
            "fresh measurements were needed")


def print_report(rep, *, name_line: bool = False) -> None:
    """Human-readable per-mode summary of one RegionReport (one line per
    mode: Abs^raw, fit params, payload verification; then the verdict)."""
    if name_line:
        print(f"  -- {rep.region} (|body|={rep.body_size})")
    for m, r in rep.results.items():
        inj = r.injection
        pay = (f"payload={inj.payload}/{inj.expected} overhead={inj.overhead}"
               if inj else "payload=n/a")
        print(f"  {m:14s} Abs^raw={r.fit.k1:7.1f} t0={r.fit.t0*1e3:8.2f}ms "
              f"slope={r.fit.slope*1e6:9.2f}us/pat {pay}")
    print(f"  => {rep.bottleneck}")


def report_json(reports: dict) -> str:
    """Canonical serialization of {region: RegionReport} — sorted keys and
    regions, so two runs of the same plan produce byte-comparable files."""
    return json.dumps({name: json.loads(rep.to_json())
                       for name, rep in sorted(reports.items())},
                      indent=1, sort_keys=True)


def write_report(path: str, reports: dict) -> str:
    """Atomically write ``report_json(reports)`` to ``path``; returns it."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(report_json(reports) + "\n")
    os.replace(tmp, path)
    return path


def characterize_region(region, modes: Sequence[str], *, controller,
                        store: str, echo_stats: bool = True):
    """Store-backed characterize of ONE region — the spine the benchmark
    harness rides (``benchmarks.common.characterize``)."""
    from repro.core import Campaign

    camp = Campaign(store, controller)
    try:
        rep = camp.characterize(region, list(modes))
    finally:
        camp.store.close()
    if echo_stats and camp.stats.cached:
        print(f"  [{region.name}: {camp.stats.cached} points from store, "
              f"{camp.stats.measured} measured]")
    return rep


# ---------------------------------------------------------------------------
# the static audit gate (repro.analysis) — runs BEFORE any measurement
# ---------------------------------------------------------------------------

AUDIT_CHOICES = ("gate", "warn", "off")

# the runtime measurement-quality gate mirrors the static audit gate, but
# runs AFTER the merge (quality is a property of the measurements, so it
# cannot be checked before they exist): "gate" refuses a fleet whose
# classification was refused (majority-quarantined curves), "warn" reports
# and proceeds, "off" skips evidence attachment entirely
QUALITY_CHOICES = ("gate", "warn", "off")


def _check_audit_choice(audit: str) -> None:
    if audit not in AUDIT_CHOICES:
        raise FleetError(f"audit policy {audit!r}: one of {AUDIT_CHOICES}")


def _check_quality_choice(quality: str) -> None:
    if quality not in QUALITY_CHOICES:
        raise FleetError(
            f"quality policy {quality!r}: one of {QUALITY_CHOICES}")


def _plan_quality(plan: SweepPlan):
    """The plan's declared (QualityPolicy, RemeasureBudget), or (None, None)
    when the plan doesn't opt into the measurement-integrity guard."""
    if plan.quality is None:
        return None, None
    from repro.core import quality_from_dict

    return quality_from_dict(plan.quality)


def _attach_audit_evidence(rep, store):
    """Fold the store's audit records into one RegionReport's classification.

    A no-op for regions without audit records, so a non-audited run
    serializes byte-identically to a pre-audit one."""
    from repro.core import apply_audit_evidence

    audits = {m: rec for (r, m), rec in store.audits.items()
              if r == rep.region and m in rep.results}
    if not audits:
        return rep
    return dataclasses.replace(
        rep, bottleneck=apply_audit_evidence(rep.bottleneck, audits))


def _attach_quality_evidence(rep, store):
    """Fold the store's runtime measurement-quality records into one
    RegionReport's classification (per-mode aggregate of quarantined
    points and why — ``apply_quality_evidence`` decides the downgrade or
    the label refusal).

    A no-op for regions with no quarantined points, so a clean guarded run
    serializes byte-identically to an unguarded one."""
    from repro.core import apply_quality_evidence

    agg = {}
    any_quarantined = False
    for (r, m), per_k in store.quality.items():
        if r != rep.region or m not in rep.results:
            continue
        reasons: dict[str, int] = {}
        quarantined = 0
        for rec in per_k.values():
            if rec.get("verdict") == "quarantine":
                quarantined += 1
                reason = rec.get("reason") or "unknown"
                reasons[reason] = reasons.get(reason, 0) + 1
        agg[m] = {"points": len(per_k), "quarantined": quarantined,
                  "reasons": reasons}
        any_quarantined = any_quarantined or bool(quarantined)
    if not any_quarantined:
        return rep
    return dataclasses.replace(
        rep, bottleneck=apply_quality_evidence(rep.bottleneck, agg))


def _gate_quality(reports: dict, quality: str) -> None:
    """The runtime quality gate: a region whose label was REFUSED by
    ``apply_quality_evidence`` (majority-quarantined curve) fails the fleet
    under ``"gate"``, is printed and tolerated under ``"warn"``."""
    from repro.core.classifier import UNRELIABLE

    if quality == "off":
        return
    bad = {name: rep for name, rep in sorted(reports.items())
           if rep.bottleneck.label == UNRELIABLE}
    if not bad:
        return
    lines = "\n".join(f"  {name}: {rep.bottleneck.explanation}"
                      for name, rep in bad.items())
    msg = (f"quality gate: {len(bad)} region(s) are majority-quarantined — "
           f"the measurements cannot back a label:\n{lines}")
    if quality == "gate":
        raise FleetError(
            msg + "\n`python -m repro.fleet doctor --plan ...` names every "
            "quarantined point and why; re-measure under a quieter clock "
            "with `fleet run --plan ... --resume`, or report anyway with "
            "--quality warn")
    print(f"!! {msg}\n!! --quality warn: reporting anyway")


def audit_fleet_plan(plan: SweepPlan, store=None, *, gate: str = "gate",
                     force: bool = False, echo: bool = True) -> dict:
    """Statically audit every planned (region, mode) pair into the plan's
    canonical store, BEFORE any measurement happens.

    Each pair compiles three static builds (clean / K_LO / K_HI — the clean
    one shared across a region's modes) and the two-point census delta
    decides whether the noise payload survived XLA (``repro.analysis``).
    Verdicts persist as ``audit`` records in the canonical ``CampaignStore``
    — pairs that already carry a record are NOT re-compiled (``force``
    re-audits them; fresh records supersede), so resumed fleets and replay
    runs audit for free.

    ``gate`` policy: ``"gate"`` raises ``FleetError`` when any pair is
    statically DEAD (measuring it would time nothing); ``"warn"`` prints the
    same explanation and proceeds. Callers handle ``"off"`` by not calling
    this at all. A pair whose static build fails is UNAUDITABLE — reported,
    never fatal: a broken build is not proof of a dead payload, and the
    measuring path will surface the real failure.

    Returns ``{(region, mode): audit record}`` for the plan's whole grid.
    """
    from repro.analysis import AuditReport, audit_plan

    owned = store is None
    if owned:
        store = _plan_store(plan, plan.store)
    try:
        grid = plan.grid()
        skip = frozenset() if force else frozenset(store.audits)
        todo = [key for key in grid if key not in skip]
        if todo and echo:
            print(f"== audit: statically verifying {len(todo)} pair(s) "
                  f"({len(grid) - len(todo)} already in store)")
        unauditable: list[tuple] = []
        fresh = audit_plan(plan, skip=skip,
                           on_error=lambda r, m, e:
                               unauditable.append((r, m, e)))
        for rep in fresh:
            store.append({"kind": "audit", **rep.to_dict()})
        records = {key: store.audits[key] for key in grid
                   if key in store.audits}
        if echo:
            for key in grid:
                rec = records.get(key)
                if rec is not None:
                    print("  " + AuditReport.from_dict(rec).explain())
            for r, m, e in unauditable:
                print(f"  {r} × {m}: UNAUDITABLE — {e}")
        dead = [key for key in grid
                if records.get(key, {}).get("verdict") == "dead"]
        if dead:
            lines = "\n".join(
                "  " + AuditReport.from_dict(records[key]).explain()
                for key in dead)
            msg = (f"audit gate: {len(dead)} planned pair(s) carry "
                   "statically DEAD noise — the compiler removed the "
                   f"payload, so measuring them would time nothing:\n{lines}")
            if gate == "gate":
                raise FleetError(
                    msg + "\nfix the noise body (`python -m repro.fleet "
                    "doctor --plan ...` repeats each explanation), or "
                    "measure anyway with --audit warn")
            print(f"!! {msg}\n!! --audit warn: measuring anyway")
        return records
    finally:
        if owned:
            store.close()


# ---------------------------------------------------------------------------
# the single-process worker entry (probe --plan lands here)
# ---------------------------------------------------------------------------


def _plan_store(plan: SweepPlan, path: str, *, readonly: bool = False):
    """Open a store under the plan's declared layout: ``store_format:
    "segments"`` opts writable opens into the segmented backend (readonly
    opens auto-detect — they must never create anything)."""
    from repro.core import CampaignStore

    seg = True if plan.store_format == "segments" else None
    return CampaignStore(path, readonly=readonly,
                         segmented=None if readonly else seg)


def _stats_path(store: str) -> str:
    return store + ".stats.json"


def _write_worker_stats(store: str, stats) -> None:
    with open(_stats_path(store), "w") as f:
        json.dump({"measured": stats.measured, "cached": stats.cached}, f)


def _read_worker_stats(store: str) -> Optional[dict]:
    try:
        with open(_stats_path(store)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _handshake(plan: SweepPlan) -> str:
    """The launcher->worker handshake: a launcher exports the plan digest it
    is driving (``REPRO_FLEET_EXPECT_DIGEST``); a worker whose own plan file
    resolves to a different digest must refuse to measure — an out-of-sync
    plan copy on one host would silently splice a different grid into the
    fleet's stores. Returns the host label to echo in the worker banner."""
    expect = os.environ.get("REPRO_FLEET_EXPECT_DIGEST")
    if expect and expect != plan.digest():
        raise FleetError(
            f"worker handshake failed: the launcher expects plan digest "
            f"{expect} but this worker's plan file resolves to "
            f"{plan.digest()} — the plan copies are out of sync across "
            "hosts; re-distribute the plan file (same bytes => same digest)")
    return os.environ.get("REPRO_FLEET_HOST") or socket.gethostname()


def run_worker(plan: SweepPlan, *, index: Optional[int] = None,
               count: Optional[int] = None, fresh: bool = False,
               expect_no_measure: bool = False,
               header: Optional[str] = None, audit: str = "gate",
               quality: str = "gate"):
    """Execute a plan (or one shard of it) in THIS process.

    ``index``/``count`` given: measure shard ``index`` of ``count``'s slice
    of the plan's pair grid into its worker store and stop — classification
    happens after the merge. Without a shard: run the whole grid into the
    canonical store, classify every region, and write the report file.

    ``audit`` applies to the whole-plan path only (a shard never audits —
    the fleet audits once at the gate): the static noise audit runs before
    any measurement, ``"gate"`` refusing statically-dead pairs, and its
    records back the per-mode evidence attached to every classification.

    A plan that declares a ``quality`` policy measures under the runtime
    integrity guard on BOTH paths (variance gating, sentinels, watchdog —
    quality records land in the store either way); ``quality`` then governs
    the classification side on the whole-plan path: ``"gate"`` refuses a
    majority-quarantined region, ``"warn"`` reports it, ``"off"`` attaches
    no quality evidence.

    Returns ``(results_or_reports, CampaignStats)``.
    """
    from repro.core import Campaign, Controller, remove_store, worker_store
    from repro.core.calibration import resolve_thresholds

    _check_audit_choice(audit)
    _check_quality_choice(quality)

    if index is not None:
        count = plan.shards if count is None else count
        if count != plan.shards:
            raise FleetError(f"--shard I/N count {count} does not match the "
                             f"plan's shards={plan.shards}; the slice "
                             "assignment is part of the plan")
        store = worker_store(plan.store, index, count)
    else:
        store = plan.store
    if fresh:
        remove_store(store)
    host = _handshake(plan)
    title = header or f"fleet plan {plan.name!r} [{plan.digest()}]"
    plan.grid()     # rejects plans whose targets enumerate duplicate pairs
    ctl = Controller(reps=plan.reps, compile_once=plan.compile_once)
    qpolicy, qbudget = _plan_quality(plan)
    camp = Campaign(_plan_store(plan, store), ctl, workers=plan.workers,
                    quality=qpolicy, remeasure=qbudget)
    try:
        pairs = plan.pairs()
        if index is not None:
            print(f"== {title} [shard {index}/{count}] ({len(pairs)}-pair "
                  f"grid; worker store: {store})")
            print(f"  [worker handshake: plan {plan.digest()}, host {host}, "
                  f"pid {os.getpid()}]")
            res = camp.measure_pairs(pairs, index=index, count=count)
            for (r, m), mr in sorted(res.items()):
                print(f"  {r}/{m}: Abs^raw={mr.fit.k1:7.1f} "
                      f"t0={mr.fit.t0*1e3:8.2f}ms")
            if not res:
                print(f"  (no pairs land on shard {index} of {count})")
            print("  [classification happens after the merge; a shard sees "
                  "only its slice]")
            _write_worker_stats(store, camp.stats)
            finish_stats(camp.stats, expect_no_measure)
            return res, camp.stats

        print(f"== {title} (campaign store: {store})")
        if audit != "off":
            audit_fleet_plan(plan, camp.store, gate=audit)
        low, high, prov = resolve_thresholds(camp.store)
        camp.thresholds = (low, high)
        if prov != "default":
            print(f"  [classification thresholds: {prov} "
                  f"low={low:g} high={high:g}]")
        reports = {}
        many = sum(len(regions) for _, regions in plan.resolve()) > 1
        for spec, regions in plan.resolve():
            for region in regions:
                rep = _attach_audit_evidence(
                    camp.characterize(region, list(spec.modes)), camp.store)
                if quality != "off":
                    rep = _attach_quality_evidence(rep, camp.store)
                reports[region.name] = rep
                print_report(rep, name_line=many)
        _gate_quality(reports, quality)
        write_report(plan.report_path(), reports)
        finish_stats(camp.stats, expect_no_measure)
        return reports, camp.stats
    finally:
        camp.store.close()


# ---------------------------------------------------------------------------
# fleet state (fleet.json)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardState:
    """One shard's ledger entry in ``fleet.json``.

    ``attempts`` counts LIFETIME launches (across resumes — what
    ``RetryBudget.per_shard_cap`` is checked against) and ``attempt_log``
    records each one: {attempt, launcher, host, rc, measured, cached} —
    ``measured``/``cached`` are the worker's heal stats (a retry that
    replayed N cached points and measured only the missing ones shows
    exactly that). Status vocabulary: pending | running | done | failed |
    exhausted (per-shard attempt cap reached)."""
    index: int
    store: str
    status: str = "pending"
    returncode: Optional[int] = None
    attempts: int = 0
    measured: Optional[int] = None
    cached: Optional[int] = None
    host: Optional[str] = None
    attempt_log: list = dataclasses.field(default_factory=list)


class FleetState:
    """The durable fleet ledger. Advisory (stores are ground truth), but it
    is what ``status`` shows and what resume uses to report history."""

    def __init__(self, path: str, plan_digest: str,
                 shard_stores: Sequence[str]):
        self.path = path
        self.plan_digest = plan_digest
        self.shards = {i: ShardState(i, s)
                       for i, s in enumerate(shard_stores)}
        self.merge: Optional[dict] = None
        self.classification: Optional[dict] = None
        self.stats: Optional[dict] = None

    def to_dict(self) -> dict:
        """The JSON form written to ``fleet.json`` (schema-versioned)."""
        return {"fleet": FLEET_SCHEMA, "plan": self.plan_digest,
                "shards": {str(i): dataclasses.asdict(s)
                           for i, s in self.shards.items()},
                "merge": self.merge, "classification": self.classification,
                "stats": self.stats}

    def save(self) -> None:
        """Atomically rewrite ``fleet.json`` with the current state."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    @classmethod
    def load(cls, path: str) -> "FleetState":
        """Load a ``fleet.json`` (older files without host/attempt_log
        fields load with defaults)."""
        with open(path) as f:
            d = json.load(f)
        if d.get("fleet") != FLEET_SCHEMA:
            raise FleetError(f"{path}: not a fleet state file "
                             f"(fleet={d.get('fleet')!r})")
        state = cls(path, d.get("plan", ""), [])
        state.shards = {int(i): ShardState(**s)
                        for i, s in d.get("shards", {}).items()}
        state.merge = d.get("merge")
        state.classification = d.get("classification")
        state.stats = d.get("stats")
        return state


# ---------------------------------------------------------------------------
# shard launchers (implementations live in repro.fleet.launchers)
# ---------------------------------------------------------------------------


def subprocess_launcher(plan_path: str, plan: SweepPlan,
                        indices: Sequence[int]) -> dict[int, int]:
    """Back-compat shim for the pre-Launcher API: a subprocess
    ``LocalLauncher`` round, returned as the legacy {index: returncode}."""
    out = LocalLauncher().launch(plan_path, plan, indices)
    return {i: o.rc for i, o in out.items()}


def in_process_launcher(plan_path: str, plan: SweepPlan,
                        indices: Sequence[int]) -> dict[int, int]:
    """Back-compat shim for the pre-Launcher API: an in-process
    ``LocalLauncher`` round, returned as the legacy {index: returncode}."""
    out = LocalLauncher(in_process=True).launch(plan_path, plan, indices)
    return {i: o.rc for i, o in out.items()}


class _CallableLauncher(Launcher):
    """Adapter for legacy ``fn(plan_path, plan, indices) -> {i: rc}``
    launcher callables (still accepted by ``run_fleet(launcher=...)``)."""

    def __init__(self, fn: Callable):
        self.fn = fn
        self.name = getattr(fn, "__name__", "callable")

    def launch(self, plan_path, plan, indices, *, attempts=None):
        """Call the wrapped function and lift rcs into ShardOutcomes."""
        return {i: ShardOutcome(int(rc), None)
                for i, rc in self.fn(plan_path, plan, indices).items()}


def _as_launcher(launcher: Union[Launcher, Callable, None],
                 plan: SweepPlan) -> Launcher:
    """Normalize run_fleet's ``launcher`` argument: None -> resolve from the
    plan's declarative spec (default local subprocesses); a ``Launcher`` is
    used as-is; any other callable goes through the legacy adapter."""
    if launcher is None:
        return resolve_launcher(plan=plan)
    if isinstance(launcher, Launcher):
        return launcher
    return _CallableLauncher(launcher)


# ---------------------------------------------------------------------------
# the fleet pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetResult:
    """What ``run_fleet`` hands back: the plan, one RegionReport per region,
    the finalize replay's CampaignStats, the saved FleetState ledger, and
    the shard indices that were (re)launched during this run."""
    plan: SweepPlan
    reports: dict
    stats: object                    # CampaignStats of the finalize replay
    state: FleetState
    launched: list[int]              # shard indices (re)launched this run


def _incomplete_shards(plan: SweepPlan, grid, *,
                       heal: bool = False) -> list[int]:
    """Which shards still owe measurements — decided from the stores alone.

    The canonical store is consulted first: once a fleet has merged (or the
    same plan ran single-process), a complete canonical store means NO shard
    has anything left to do, even if worker stores were deleted.

    ``heal``: treat a complete pair that carries QUARANTINED points as still
    owing, so a resume re-launches its shard and the worker re-measures the
    condemned points (hopefully under a quieter clock)."""
    from repro.core import CampaignStore, store_exists

    def ok(ps) -> bool:
        return ps.complete and not (heal and ps.quarantined)

    if store_exists(plan.store):
        st = CampaignStore(plan.store, readonly=True)
        if all(ok(ps) for ps in st.grid_status(grid).values()):
            return []
    out = []
    for i in range(plan.shards):
        mine = grid[i::plan.shards]
        if not mine:
            continue
        ws = plan.worker_stores()[i]
        if not store_exists(ws):
            out.append(i)
            continue
        # readonly: completeness probing must not heal anything — the worker
        # owns its store and heals the torn tail itself on relaunch
        st = CampaignStore(ws, readonly=True)
        if not all(ok(ps) for ps in st.grid_status(mine).values()):
            out.append(i)
    return out


def _classify(plan: SweepPlan, quality: str = "gate"):
    """Merge-side finalize: replay the canonical store into one RegionReport
    per region (a complete store measures nothing here — quarantined points
    are NOT healed by finalize; it must classify what the fleet measured,
    with the quality evidence attached when ``quality`` != "off"). A
    ``calib`` record in the store (``repro.core.calibration``) swaps the
    classifier's paper-default thresholds for the fitted ones."""
    from repro.core import Campaign, Controller
    from repro.core.calibration import resolve_thresholds

    qpolicy, qbudget = _plan_quality(plan)
    ctl = Controller(reps=plan.reps, compile_once=plan.compile_once)
    camp = Campaign(_plan_store(plan, plan.store), ctl, workers=plan.workers,
                    quality=qpolicy, remeasure=qbudget,
                    heal_quarantined=False)
    low, high, _prov = resolve_thresholds(camp.store)
    camp.thresholds = (low, high)
    try:
        reports = {}
        for spec, regions in plan.resolve():
            for region in regions:
                rep = _attach_audit_evidence(
                    camp.characterize(region, list(spec.modes)), camp.store)
                if quality != "off":
                    rep = _attach_quality_evidence(rep, camp.store)
                reports[region.name] = rep
    finally:
        camp.store.close()
    return reports, camp.stats


def _clean_fleet(plan: SweepPlan) -> None:
    from repro.core import remove_store

    stores = [plan.store] + plan.worker_stores()
    for s in stores:
        remove_store(s)            # removes either layout (file/segment dir)
    paths = [plan.fleet_path(), plan.report_path()]
    paths += [_stats_path(ws) for ws in plan.worker_stores()]
    for p in paths:
        if os.path.exists(p):
            os.unlink(p)


def run_fleet(plan_path: str, *, resume: bool = False, fresh: bool = False,
              expect_no_measure: bool = False,
              launcher: Union[Launcher, Callable, None] = None,
              retry: Optional[RetryBudget] = None,
              audit: str = "gate", quality: str = "gate") -> FleetResult:
    """Plan → audit → spawn (with retries) → merge → classify, resumably.

    * the static noise audit runs FIRST, before anything launches: every
      planned pair is verified against the compiler (``audit_fleet_plan``);
      under the default ``audit="gate"`` a statically-dead pair refuses the
      whole fleet (no machine time is spent measuring nothing), ``"warn"``
      proceeds anyway, ``"off"`` skips the audit. Audit records live in the
      canonical store, so resumes never re-compile them, and the classify
      step attaches them as per-mode evidence;
    * first run: launches every shard whose slice is incomplete (all of
      them), merges, classifies;
    * within one call, the ``retry`` budget (or the plan's declarative
      ``retry`` settings) governs how many launch rounds failed/incomplete
      shards get — completeness is re-derived from the STORES after every
      round, so a retried shard heals its torn store and re-measures only
      missing points; every attempt lands in ``fleet.json``'s per-shard
      attempt log (launcher, host, rc, heal stats);
    * a plan that declares a ``quality`` policy measures every point under
      the runtime integrity guard; after the merge, ``quality="gate"``
      refuses a fleet whose classification was refused (majority-quarantined
      curve — the ``unreliable`` label), ``"warn"`` reports it and writes
      the report anyway, ``"off"`` attaches no quality evidence;
    * ``resume`` after a crash: re-launches ONLY incomplete shards, then
      merges and classifies as usual; a resume also re-launches shards whose
      pairs are complete but QUARANTINED, so the workers re-measure the
      condemned points (run it under a quieter clock to heal the fleet);
    * ``resume`` on a completed fleet: launches nothing and the classify
      step replays the canonical store with ZERO new measurements;
    * ``fresh``: delete every store/state file of this plan first.

    ``launcher`` is a ``Launcher`` (Local/SSH/MockCluster), a legacy
    ``fn(plan_path, plan, indices) -> {i: rc}`` callable, or None to resolve
    from the plan's ``launcher`` spec (default: local subprocesses).

    Raises ``FleetError`` when fleet state exists for a different plan
    digest, when state exists and neither flag was given, when shards still
    owe measurements after the last allowed attempt round, or when a shard
    has exhausted its lifetime ``per_shard_cap``.
    """
    _check_audit_choice(audit)
    _check_quality_choice(quality)
    plan = SweepPlan.load(plan_path)
    if fresh:
        _clean_fleet(plan)
    fleet_path = plan.fleet_path()
    state = None
    if os.path.exists(fleet_path):
        state = FleetState.load(fleet_path)
        if state.plan_digest != plan.digest():
            raise FleetError(
                f"{fleet_path} belongs to plan digest {state.plan_digest}, "
                f"this plan is {plan.digest()}; a changed plan must not "
                "splice into old shards — use --fresh to restart")
        if not resume:
            raise FleetError(
                f"{fleet_path} already exists; use --resume to continue (or "
                "replay) this fleet, or --fresh to restart it")
    grid = plan.grid()
    if state is None:
        state = FleetState(fleet_path, plan.digest(), plan.worker_stores())
    budget = retry if retry is not None \
        else RetryBudget.from_dict(plan.retry)
    lch = _as_launcher(launcher, plan)
    if audit != "off":
        # fail-fast: a statically-dead pair refuses the fleet BEFORE any
        # shard launches; records land in the canonical store (pre-merge,
        # so the merge streams them through) and back the evidence below
        audit_fleet_plan(plan, gate=audit)

    incomplete = sorted(_incomplete_shards(plan, grid, heal=resume))
    for i, ss in state.shards.items():
        ss.status = "pending" if i in incomplete else "done"
    state.save()

    launched: list[int] = []
    round_no = 0
    while incomplete:
        capped = [i for i in incomplete
                  if budget.per_shard_cap
                  and state.shards[i].attempts >= budget.per_shard_cap]
        for i in capped:
            state.shards[i].status = "exhausted"
        runnable = [i for i in incomplete if i not in capped]
        if not runnable:
            state.save()
            raise FleetError(
                f"shard(s) {sorted(capped)} exhausted the lifetime "
                f"per-shard attempt cap ({budget.per_shard_cap}); "
                "fleet.json records every attempt (launcher, host, rc) — "
                "`python -m repro.fleet doctor` explains each shard; fix "
                "the cause, then raise --per-shard-cap or restart with "
                "--fresh")
        if round_no >= budget.max_attempts:
            break
        round_no += 1
        delay = budget.delay(round_no)
        if delay:
            print(f"== retry backoff: sleeping {delay:.1f}s before attempt "
                  f"round {round_no}/{budget.max_attempts}")
            time.sleep(delay)
        print(f"== fleet {plan.name!r} [{plan.digest()}]: "
              f"{len(grid)}-pair grid, launching shard(s) {runnable} of "
              f"{plan.shards} (round {round_no}/{budget.max_attempts}, "
              f"launcher {lch.name})")
        attempts_map = {}
        for i in runnable:
            ss = state.shards[i]
            ss.status = "running"
            ss.attempts += 1
            attempts_map[i] = ss.attempts
            # a stale stats file from a previous attempt must not be
            # misattributed to this one (a worker that never runs writes
            # no stats; the ledger then honestly records None)
            try:
                os.unlink(_stats_path(ss.store))
            except OSError:
                pass
        state.save()
        outcomes = lch.launch(plan_path, plan, runnable,
                              attempts=attempts_map)
        still = set(_incomplete_shards(plan, grid, heal=resume))
        for i in runnable:
            ss = state.shards[i]
            o = outcomes.get(i)
            ss.returncode = None if o is None else o.rc
            ss.host = None if o is None else o.host
            ss.status = "failed" if i in still else "done"
            wstats = _read_worker_stats(ss.store)
            if wstats:
                ss.measured = wstats.get("measured")
                ss.cached = wstats.get("cached")
            ss.attempt_log.append({
                "attempt": ss.attempts, "launcher": lch.name,
                "host": ss.host, "rc": ss.returncode,
                "measured": (wstats or {}).get("measured"),
                "cached": (wstats or {}).get("cached")})
            if i not in launched:
                launched.append(i)
        state.save()
        incomplete = sorted(still)
    if incomplete:
        codes = {i: state.shards[i].returncode for i in incomplete}
        raise FleetError(
            f"shard(s) {sorted(incomplete)} did not complete after "
            f"{round_no} attempt round(s) (returncodes {codes}); completed "
            "work is preserved in the worker stores — `python -m repro.fleet "
            "doctor` explains each shard, and re-running with --resume (or "
            "a higher --max-attempts) heals and finishes them")
    if not launched:
        print(f"== fleet {plan.name!r} [{plan.digest()}]: all "
              f"{plan.shards} shard slice(s) already complete, "
              "nothing to launch")

    from repro.core import merge_stores, store_exists

    sources = [ws for ws in plan.worker_stores() if store_exists(ws)]
    if sources:
        # the canonical store (when present) streams FIRST so freshly
        # re-measured worker records supersede any stale merged ones (an
        # incremental merge into a segmented canonical store skips the
        # self-source and adopts only never-seen worker segments)
        if store_exists(plan.store):
            sources = [plan.store] + sources
        mstats = merge_stores(plan.store, sources)
        state.merge = {"dest": plan.store, "sources": sources,
                       "records_in": mstats.records_in,
                       "records_out": mstats.records_out,
                       "conflicts": sorted(set(map(tuple, mstats.conflicts)))}
        state.merge["conflicts"] = [list(c) for c in
                                    state.merge["conflicts"]]
        if mstats.incremental:
            state.merge["segments_new"] = mstats.segments_new
            state.merge["segments_skipped"] = mstats.segments_skipped
        print(f"== merge: {mstats}")

    reports, cstats = _classify(plan, quality)
    state.classification = {
        name: {"label": rep.bottleneck.label,
               "confidence": rep.bottleneck.confidence,
               "abs": rep.absorptions()}
        for name, rep in sorted(reports.items())}
    state.stats = {"measured": cstats.measured, "cached": cstats.cached}
    state.save()
    # the ledger records the refused classification (forensics) but the gate
    # refuses to WRITE a report a majority-quarantined fleet cannot back
    _gate_quality(reports, quality)
    write_report(plan.report_path(), reports)
    print(f"== classification ({plan.report_path()}):")
    for name, rep in sorted(reports.items()):
        print(f"  {name}: {rep.bottleneck}")
    finish_stats(cstats, expect_no_measure)
    return FleetResult(plan=plan, reports=reports, stats=cstats, state=state,
                       launched=launched)


# ---------------------------------------------------------------------------
# fleet doctor — explain, per shard, why the fleet is (in)complete
# ---------------------------------------------------------------------------


def _pair_lines(store_path: str, mine, canon_status) -> tuple[list[str], int]:
    """Diagnose one shard's slice against its worker store (and the
    canonical store): returns (report lines, #pairs still owing)."""
    from repro.core import (CampaignStore, CampaignStoreError, is_segmented,
                            manifest_status, store_exists)
    from repro.core.campaign import read_store_records

    lines: list[str] = []
    wstore = None
    if not store_exists(store_path):
        status = {}
        lines.append(f"  worker store {store_path}: absent")
    else:
        try:
            if is_segmented(store_path):
                ms = manifest_status(store_path)
                if ms["orphans"]:
                    lines.append(
                        f"  worker store {store_path}: {ms['orphans']} "
                        f"unsealed segment(s) ({ms['orphan_bytes']} byte(s))"
                        " — a live or killed writer; healed (sealed, torn "
                        "tail truncated) on the next writable open")
            else:
                records, valid = read_store_records(store_path)
                size = os.path.getsize(store_path)
                if valid < size:
                    lines.append(
                        f"  worker store {store_path}: torn tail — "
                        f"{size - valid} byte(s) past the last valid record "
                        "(a SIGKILL mid-append; healed automatically on the "
                        "next load, costing at most one point)")
            wstore = CampaignStore(store_path, readonly=True)
            status = wstore.grid_status(mine)
        except CampaignStoreError as e:
            lines.append(f"  worker store {store_path}: CORRUPT beyond the "
                         f"final record — {e}; delete it and relaunch the "
                         "shard (--resume re-measures its whole slice)")
            status = {}
    owing = 0
    for pair in mine:
        r, m = pair
        # quarantine evidence lives in the worker store even before any
        # merge, so a hung-kernel timeout is explainable right after the
        # failed round, not only once a canonical store exists
        qwhy = ""
        if wstore is not None:
            per_k = wstore.quality.get(pair, {})
            by: dict[str, list[int]] = {}
            for k in wstore.quarantined_ks(r, m):
                reason = per_k.get(k, {}).get("reason") or "unknown"
                by.setdefault(reason, []).append(k)
            qwhy = "; ".join(f"{reason} at k(s) {sorted(ks)}"
                             for reason, ks in sorted(by.items()))
        if canon_status and canon_status.get(pair) \
                and canon_status[pair].complete:
            continue                      # already satisfied by the merge
        ps = status.get(pair)
        if ps is None or (not ps.done and not ps.points):
            owing += 1
            lines.append(f"  {r}/{m}: absent — never measured")
            if qwhy:      # e.g. the sensitivity probe itself timed out
                lines.append(f"    quarantined: {qwhy}")
        elif ps.complete:
            if qwhy:
                lines.append(
                    f"  {r}/{m}: complete but quarantined — {qwhy}; "
                    "`--resume` re-measures exactly these points")
            continue
        elif ps.done and ps.missing:
            owing += 1
            lines.append(
                f"  {r}/{m}: done-marked but {ps.points}/{ps.expected} "
                f"point(s) present — missing k(s) {sorted(ps.missing)}; a "
                "relaunch re-measures ONLY these")
            if qwhy:
                lines.append(f"    quarantined: {qwhy}")
        else:
            owing += 1
            lines.append(
                f"  {r}/{m}: in progress — {ps.points} point(s), no done "
                "marker (the k grid is adaptive; a relaunch resumes at the "
                "first missing k)")
            if qwhy:
                lines.append(f"    quarantined: {qwhy}")
    return lines, owing


def fleet_doctor(plan: SweepPlan, budget: Optional[RetryBudget] = None,
                 *, explain: bool = False) -> tuple[int, str]:
    """Explain, per shard, why a fleet is incomplete — the forensics behind
    ``_incomplete_shards``'s yes/no answer.

    For every shard: its ledger history (attempts, launcher, host, rc, heal
    stats from ``fleet.json``), whether its lifetime attempt cap is
    exhausted, the worker store's physical condition (torn tail to be
    healed, corruption), and each owing (region, mode) pair with its
    missing ks when the ``done`` marker pins them. Returns
    ``(exit_code, report)``: 0 when the grid is fully covered, 1 otherwise.

    ``explain`` appends the classification forensics for a COVERED grid: a
    measurement-free replay of every region's classification, rendering
    the strategy tree's evaluated decision path — which node fired, under
    which thresholds, whether those were calibrated or the paper defaults,
    and any audit/quality downgrades.
    """
    from repro.core import CampaignStore, store_exists

    grid = plan.grid()
    budget = budget if budget is not None else RetryBudget.from_dict(plan.retry)
    state = None
    if os.path.exists(plan.fleet_path()):
        state = FleetState.load(plan.fleet_path())
    out = [f"== fleet doctor: plan {plan.name!r} [{plan.digest()}] — "
           f"{len(grid)} pair(s) over {plan.shards} shard(s)"]
    if state is None:
        out.append(f"fleet ledger {plan.fleet_path()}: not created yet "
                   "(no run attempted)")
    elif state.plan_digest != plan.digest():
        out.append(f"fleet ledger {plan.fleet_path()}: STALE — built by "
                   f"plan digest {state.plan_digest}; --fresh required")
    canon_status = None
    if store_exists(plan.store):
        canon = CampaignStore(plan.store, readonly=True)
        canon_status = canon.grid_status(grid)
        done = sum(ps.complete for ps in canon_status.values())
        out.append(f"canonical store {plan.store}: {done}/{len(grid)} "
                   "pair(s) complete")
        audited = {key: canon.audits[key] for key in grid
                   if key in canon.audits}
        if audited:
            from repro.analysis import AuditReport

            n_dead = sum(r.get("verdict") == "dead"
                         for r in audited.values())
            n_intact = sum(r.get("verdict") == "intact"
                           for r in audited.values())
            out.append(f"static audit: {len(audited)}/{len(grid)} pair(s) "
                       f"audited — {n_intact} intact, {n_dead} dead")
            for key in grid:
                rec = audited.get(key)
                if rec is not None and rec.get("verdict") != "intact":
                    out.append("  " + AuditReport.from_dict(rec).explain())
                    if rec.get("verdict") == "dead":
                        out.append("    (the audit gate refuses this pair; "
                                   "fix the noise body or run with "
                                   "--audit warn)")
        # runtime measurement quality: quarantined points, and why
        qpairs = {key: canon.quarantined_ks(*key) for key in grid}
        qpairs = {key: ks for key, ks in qpairs.items() if ks}
        if qpairs:
            nq = sum(len(ks) for ks in qpairs.values())
            out.append(f"measurement quality: {nq} quarantined point(s) "
                       f"across {len(qpairs)} pair(s)")
            for (r, m), ks in sorted(qpairs.items()):
                per_k = canon.quality.get((r, m), {})
                reasons: dict[str, list[int]] = {}
                for k in ks:
                    reason = per_k.get(k, {}).get("reason") or "unknown"
                    reasons.setdefault(reason, []).append(k)
                why = "; ".join(f"{reason} at k(s) {sorted(kk)}"
                                for reason, kk in sorted(reasons.items()))
                out.append(f"  {r}/{m}: {why}")
                for k in ks:
                    detail = per_k.get(k, {}).get("detail")
                    if detail:
                        out.append(f"    k={k}: {detail}")
            out.append("  (a quarantined point condemns its reading, not "
                       "the pair; `fleet run --plan ... --resume` "
                       "re-measures exactly these points — run it under a "
                       "quieter clock)")
        # implausible baseline drift the campaign refused to correct for
        for key in grid:
            rec = canon.done.get(key)
            drift = (rec or {}).get("drift")
            if drift is not None and not (0.5 < drift < 2.0):
                r, m = key
                out.append(f"  {r}/{m}: implausible baseline drift factor "
                           f"{drift:.3g} recorded — outside (0.5, 2.0), so "
                           "drift correction was refused and the sweep's "
                           "tail is suspect; re-measure under a steadier "
                           "clock")
    else:
        out.append(f"canonical store {plan.store}: absent (no merge yet)")
    total_owing = 0
    for i in range(plan.shards):
        mine = grid[i::plan.shards]
        ss = state.shards.get(i) if state else None
        hist = ""
        if ss is not None and ss.attempt_log:
            tries = ", ".join(
                f"#{a.get('attempt')}: {a.get('launcher')}"
                + (f"@{a.get('host')}" if a.get("host") else "")
                + f" rc={a.get('rc')}"
                + (f" measured={a.get('measured')} cached={a.get('cached')}"
                   if a.get("measured") is not None else "")
                for a in ss.attempt_log)
            hist = f" — attempts: [{tries}]"
        elif ss is not None and ss.attempts:
            hist = f" — {ss.attempts} attempt(s), rc={ss.returncode}"
        if not mine:
            out.append(f"shard {i}: no pairs land on this shard{hist}")
            continue
        lines, owing = _pair_lines(plan.worker_stores()[i], mine,
                                   canon_status)
        total_owing += owing
        verdict = "complete" if not owing else f"INCOMPLETE ({owing} " \
            f"pair(s) owing)"
        out.append(f"shard {i}: {verdict}{hist}")
        if owing:
            if ss is not None and budget.per_shard_cap \
                    and ss.attempts >= budget.per_shard_cap:
                out.append(
                    f"  attempts exhausted: lifetime per-shard cap "
                    f"{budget.per_shard_cap} reached ({ss.attempts} used) — "
                    "raise --per-shard-cap, or --fresh to restart")
            out.extend(lines)
    if total_owing:
        out.append(f"== verdict: INCOMPLETE — {total_owing} pair(s) still "
                   "owe measurements; `python -m repro.fleet run --plan ... "
                   "--resume` re-launches only the owing shards")
    else:
        out.append("== verdict: COMPLETE — every pair is covered; a resume "
                   "replays with zero new measurements")
    if explain:
        out.extend(_explain_lines(plan, covered=not total_owing))
    return (1 if total_owing else 0), "\n".join(out)


def _explain_lines(plan: SweepPlan, *, covered: bool) -> list[str]:
    """The ``doctor --explain`` section: replay the covered store's
    classification (readonly, measurement-free) and render each region's
    evaluated decision path."""
    from repro.core import Campaign, CampaignStore, Controller, store_exists
    from repro.core.calibration import resolve_thresholds

    out = ["== explain: decision path per region"]
    if not store_exists(plan.store):
        out.append("  canonical store absent — run the fleet (or merge the "
                   "worker stores) first")
        return out
    if not covered:
        out.append("  grid incomplete — explain replays the store without "
                   "measuring, so it needs full coverage first")
        return out
    store = CampaignStore(plan.store, readonly=True)
    low, high, prov = resolve_thresholds(store)
    out.append(f"  thresholds: {prov} (low={low:g}, high={high:g})")
    qpolicy, qbudget = _plan_quality(plan)
    ctl = Controller(reps=plan.reps, compile_once=plan.compile_once)
    camp = Campaign(store, ctl, workers=plan.workers, quality=qpolicy,
                    remeasure=qbudget, heal_quarantined=False,
                    thresholds=(low, high))
    reports = {}
    try:
        for spec, regions in plan.resolve():
            for region in regions:
                rep = _attach_audit_evidence(
                    camp.characterize(region, list(spec.modes)), store)
                reports[region.name] = _attach_quality_evidence(rep, store)
    except Exception as e:                  # noqa: BLE001 — forensics only
        out.append(f"  explain failed to replay the store: {e}")
        return out
    for name, rep in sorted(reports.items()):
        b = rep.bottleneck
        out.append(f"  {name}: {b.label} (confidence {b.confidence:.2f})")
        out.append("    absorptions: " + ", ".join(
            f"{m}={r.fit.k1:.1f}" for m, r in sorted(rep.results.items())))
        path = b.path or {}
        nodes = path.get("nodes", [])
        if nodes:
            chain = " -> ".join(f"{n['node']}{'*' if n['fired'] else ''}"
                                for n in nodes)
            out.append(f"    path [{path.get('strategy')}]: {chain} "
                       "(* = fired)")
        out.append(f"    why: {b.explanation}")
        if b.evidence is not None:
            bad = [e["mode"] for e in b.evidence if not e["supports"]]
            if bad:
                out.append("    audit downgrade: conflicting mode(s) "
                           + ", ".join(sorted(bad)))
        if b.quality is not None:
            quar = [q["mode"] for q in b.quality if q["quarantined"]]
            if quar:
                out.append("    quality downgrade: quarantined point(s) in "
                           + ", ".join(sorted(quar)))
    return out

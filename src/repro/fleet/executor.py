"""Fleet executor — plan in, classified report out, no hands in between.

``run_fleet`` drives the whole pipeline the ROADMAP called the NEXT step:

  spawn    N real subprocess shards (``python -m repro.launch.probe --plan P
           --shard i/N``), each measuring its slice of the plan's grid into
           its own worker store, output streamed line-prefixed;
  survive  a killed shard leaves a truncated worker store; resume re-launches
           ONLY the shards whose slice is incomplete, and the campaign layer
           heals the torn tail and re-measures only the missing points;
  merge    worker stores fold into the plan's canonical store
           (``merge_stores`` — idempotent, atomic);
  classify one ``Campaign.characterize`` per region replays the merged store
           (a complete fleet classifies with ZERO new measurements) and the
           cross-region report lands in ``<store>.report.json``.

Ground truth is the stores, not the bookkeeping: shard completeness is
decided by ``CampaignStore.grid_status`` against the plan's grid, so a lying
or lost ``fleet.json`` can never cause double measurement or a hole.
``fleet.json`` (next to the store) records the plan digest, per-shard
status/attempts/stats, the merge manifest, and the final classification —
the fleet's observable state for humans and the ``status`` CLI.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import subprocess
import sys
import threading
from typing import Callable, Optional, Sequence

from repro.fleet.plan import SweepPlan

log = logging.getLogger("repro.fleet")

FLEET_SCHEMA = 1


class FleetError(RuntimeError):
    """Fleet-level failure the caller must act on (bad state, dead shards)."""


# ---------------------------------------------------------------------------
# reporting helpers (shared by the executor, the fleet CLI, and probe)
# ---------------------------------------------------------------------------


def finish_stats(stats, expect_no_measure: bool) -> None:
    """The campaign tail every entry point prints; ``--expect-no-measure``
    turns "the store fully covers this run" into an exit code."""
    print(f"  [{stats.measured} points measured, "
          f"{stats.cached} replayed from store]")
    if expect_no_measure and stats.measured:
        raise SystemExit(
            f"--expect-no-measure: store was incomplete, {stats.measured} "
            "fresh measurements were needed")


def print_report(rep, *, name_line: bool = False) -> None:
    if name_line:
        print(f"  -- {rep.region} (|body|={rep.body_size})")
    for m, r in rep.results.items():
        inj = r.injection
        pay = (f"payload={inj.payload}/{inj.expected} overhead={inj.overhead}"
               if inj else "payload=n/a")
        print(f"  {m:14s} Abs^raw={r.fit.k1:7.1f} t0={r.fit.t0*1e3:8.2f}ms "
              f"slope={r.fit.slope*1e6:9.2f}us/pat {pay}")
    print(f"  => {rep.bottleneck}")


def report_json(reports: dict) -> str:
    """Canonical serialization of {region: RegionReport} — sorted keys and
    regions, so two runs of the same plan produce byte-comparable files."""
    return json.dumps({name: json.loads(rep.to_json())
                       for name, rep in sorted(reports.items())},
                      indent=1, sort_keys=True)


def write_report(path: str, reports: dict) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(report_json(reports) + "\n")
    os.replace(tmp, path)
    return path


def characterize_region(region, modes: Sequence[str], *, controller,
                        store: str, echo_stats: bool = True):
    """Store-backed characterize of ONE region — the spine the benchmark
    harness rides (``benchmarks.common.characterize``)."""
    from repro.core import Campaign

    camp = Campaign(store, controller)
    try:
        rep = camp.characterize(region, list(modes))
    finally:
        camp.store.close()
    if echo_stats and camp.stats.cached:
        print(f"  [{region.name}: {camp.stats.cached} points from store, "
              f"{camp.stats.measured} measured]")
    return rep


# ---------------------------------------------------------------------------
# the single-process worker entry (probe --plan lands here)
# ---------------------------------------------------------------------------


def _stats_path(store: str) -> str:
    return store + ".stats.json"


def _write_worker_stats(store: str, stats) -> None:
    with open(_stats_path(store), "w") as f:
        json.dump({"measured": stats.measured, "cached": stats.cached}, f)


def _read_worker_stats(store: str) -> Optional[dict]:
    try:
        with open(_stats_path(store)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def run_worker(plan: SweepPlan, *, index: Optional[int] = None,
               count: Optional[int] = None, fresh: bool = False,
               expect_no_measure: bool = False,
               header: Optional[str] = None):
    """Execute a plan (or one shard of it) in THIS process.

    ``index``/``count`` given: measure shard ``index`` of ``count``'s slice
    of the plan's pair grid into its worker store and stop — classification
    happens after the merge. Without a shard: run the whole grid into the
    canonical store, classify every region, and write the report file.

    Returns ``(results_or_reports, CampaignStats)``.
    """
    from repro.core import Campaign, Controller, worker_store

    if index is not None:
        count = plan.shards if count is None else count
        if count != plan.shards:
            raise FleetError(f"--shard I/N count {count} does not match the "
                             f"plan's shards={plan.shards}; the slice "
                             "assignment is part of the plan")
        store = worker_store(plan.store, index, count)
    else:
        store = plan.store
    if fresh and os.path.exists(store):
        os.unlink(store)
    title = header or f"fleet plan {plan.name!r} [{plan.digest()}]"
    plan.grid()     # rejects plans whose targets enumerate duplicate pairs
    ctl = Controller(reps=plan.reps, compile_once=plan.compile_once)
    camp = Campaign(store, ctl, workers=plan.workers)
    try:
        pairs = plan.pairs()
        if index is not None:
            print(f"== {title} [shard {index}/{count}] ({len(pairs)}-pair "
                  f"grid; worker store: {store})")
            res = camp.measure_pairs(pairs, index=index, count=count)
            for (r, m), mr in sorted(res.items()):
                print(f"  {r}/{m}: Abs^raw={mr.fit.k1:7.1f} "
                      f"t0={mr.fit.t0*1e3:8.2f}ms")
            if not res:
                print(f"  (no pairs land on shard {index} of {count})")
            print("  [classification happens after the merge; a shard sees "
                  "only its slice]")
            _write_worker_stats(store, camp.stats)
            finish_stats(camp.stats, expect_no_measure)
            return res, camp.stats

        print(f"== {title} (campaign store: {store})")
        reports = {}
        many = sum(len(regions) for _, regions in plan.resolve()) > 1
        for spec, regions in plan.resolve():
            for region in regions:
                rep = camp.characterize(region, list(spec.modes))
                reports[region.name] = rep
                print_report(rep, name_line=many)
        write_report(plan.report_path(), reports)
        finish_stats(camp.stats, expect_no_measure)
        return reports, camp.stats
    finally:
        camp.store.close()


# ---------------------------------------------------------------------------
# fleet state (fleet.json)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardState:
    index: int
    store: str
    status: str = "pending"      # pending | running | done | failed
    returncode: Optional[int] = None
    attempts: int = 0
    measured: Optional[int] = None
    cached: Optional[int] = None


class FleetState:
    """The durable fleet ledger. Advisory (stores are ground truth), but it
    is what ``status`` shows and what resume uses to report history."""

    def __init__(self, path: str, plan_digest: str,
                 shard_stores: Sequence[str]):
        self.path = path
        self.plan_digest = plan_digest
        self.shards = {i: ShardState(i, s)
                       for i, s in enumerate(shard_stores)}
        self.merge: Optional[dict] = None
        self.classification: Optional[dict] = None
        self.stats: Optional[dict] = None

    def to_dict(self) -> dict:
        return {"fleet": FLEET_SCHEMA, "plan": self.plan_digest,
                "shards": {str(i): dataclasses.asdict(s)
                           for i, s in self.shards.items()},
                "merge": self.merge, "classification": self.classification,
                "stats": self.stats}

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    @classmethod
    def load(cls, path: str) -> "FleetState":
        with open(path) as f:
            d = json.load(f)
        if d.get("fleet") != FLEET_SCHEMA:
            raise FleetError(f"{path}: not a fleet state file "
                             f"(fleet={d.get('fleet')!r})")
        state = cls(path, d.get("plan", ""), [])
        state.shards = {int(i): ShardState(**s)
                        for i, s in d.get("shards", {}).items()}
        state.merge = d.get("merge")
        state.classification = d.get("classification")
        state.stats = d.get("stats")
        return state


# ---------------------------------------------------------------------------
# shard launchers
# ---------------------------------------------------------------------------


def _worker_env() -> dict:
    """The parent's environment, with this repro's src dir on PYTHONPATH so
    ``-m repro.launch.probe`` resolves in the subprocess regardless of how
    the parent itself was launched (installed, PYTHONPATH, conftest hack)."""
    import repro

    # repro is a namespace package: __file__ is None, __path__ holds the dir
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    parts = [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    if src not in parts:
        env["PYTHONPATH"] = os.pathsep.join([src] + parts)
    return env


def _pump(pipe, prefix: str) -> None:
    for line in pipe:
        print(prefix + line.rstrip("\n"), flush=True)


def subprocess_launcher(plan_path: str, plan: SweepPlan,
                        indices: Sequence[int]) -> dict[int, int]:
    """Spawn one ``python -m repro.launch.probe --plan P --shard i/N`` per
    index — all concurrently (the grid is embarrassingly parallel; wall-clock
    interference between co-located shards is the fan-out's price and the
    per-host recipe in docs/orchestration.md is the escape). Output streams
    line-prefixed; returns {index: returncode}."""
    procs: dict[int, tuple] = {}
    env = _worker_env()
    for i in indices:
        cmd = [sys.executable, "-m", "repro.launch.probe",
               "--plan", plan_path, "--shard", f"{i}/{plan.shards}"]
        p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True, bufsize=1,
                             env=env)
        t = threading.Thread(target=_pump,
                             args=(p.stdout, f"[shard {i}/{plan.shards}] "),
                             daemon=True)
        t.start()
        procs[i] = (p, t)
    rcs: dict[int, int] = {}
    for i, (p, t) in procs.items():
        rcs[i] = p.wait()
        t.join(timeout=5)
    return rcs


def in_process_launcher(plan_path: str, plan: SweepPlan,
                        indices: Sequence[int]) -> dict[int, int]:
    """Run shards sequentially in THIS process — ``run --in-process`` for
    spawn-restricted environments, and the executor tests' fast path. Each
    shard still re-loads the plan from disk, like a real worker would."""
    rcs: dict[int, int] = {}
    for i in indices:
        try:
            run_worker(SweepPlan.load(plan_path), index=i, count=plan.shards)
            rcs[i] = 0
        except SystemExit as e:
            rcs[i] = int(bool(e.code))
        except Exception:
            log.warning("in-process shard %d failed", i, exc_info=True)
            rcs[i] = 1
    return rcs


# ---------------------------------------------------------------------------
# the fleet pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetResult:
    plan: SweepPlan
    reports: dict
    stats: object                    # CampaignStats of the finalize replay
    state: FleetState
    launched: list[int]              # shard indices (re)launched this run


def _incomplete_shards(plan: SweepPlan, grid) -> list[int]:
    """Which shards still owe measurements — decided from the stores alone.

    The canonical store is consulted first: once a fleet has merged (or the
    same plan ran single-process), a complete canonical store means NO shard
    has anything left to do, even if worker stores were deleted."""
    from repro.core import CampaignStore

    if os.path.exists(plan.store):
        st = CampaignStore(plan.store, readonly=True)
        if all(ps.complete for ps in st.grid_status(grid).values()):
            return []
    out = []
    for i in range(plan.shards):
        mine = grid[i::plan.shards]
        if not mine:
            continue
        ws = plan.worker_stores()[i]
        if not os.path.exists(ws):
            out.append(i)
            continue
        # readonly: completeness probing must not heal anything — the worker
        # owns its store and heals the torn tail itself on relaunch
        st = CampaignStore(ws, readonly=True)
        if not all(ps.complete for ps in st.grid_status(mine).values()):
            out.append(i)
    return out


def _classify(plan: SweepPlan):
    """Merge-side finalize: replay the canonical store into one RegionReport
    per region (a complete store measures nothing here)."""
    from repro.core import Campaign, Controller

    ctl = Controller(reps=plan.reps, compile_once=plan.compile_once)
    camp = Campaign(plan.store, ctl, workers=plan.workers)
    try:
        reports = {}
        for spec, regions in plan.resolve():
            for region in regions:
                reports[region.name] = camp.characterize(region,
                                                         list(spec.modes))
    finally:
        camp.store.close()
    return reports, camp.stats


def _clean_fleet(plan: SweepPlan) -> None:
    paths = [plan.store, plan.fleet_path(), plan.report_path()]
    for ws in plan.worker_stores():
        paths += [ws, _stats_path(ws)]
    for p in paths:
        if os.path.exists(p):
            os.unlink(p)


def run_fleet(plan_path: str, *, resume: bool = False, fresh: bool = False,
              expect_no_measure: bool = False,
              launcher: Optional[Callable] = None) -> FleetResult:
    """Plan → spawn → merge → classify, resumably.

    * first run: launches every shard whose slice is incomplete (all of
      them), merges, classifies;
    * ``resume`` after a crash: re-launches ONLY incomplete shards (their
      worker stores heal and re-measure only missing points), then merges
      and classifies as usual;
    * ``resume`` on a completed fleet: launches nothing and the classify
      step replays the canonical store with ZERO new measurements;
    * ``fresh``: delete every store/state file of this plan first.

    Raises ``FleetError`` when fleet state exists for a different plan
    digest, when state exists and neither flag was given, or when launched
    shards still owe measurements afterwards.
    """
    plan = SweepPlan.load(plan_path)
    if fresh:
        _clean_fleet(plan)
    fleet_path = plan.fleet_path()
    state = None
    if os.path.exists(fleet_path):
        state = FleetState.load(fleet_path)
        if state.plan_digest != plan.digest():
            raise FleetError(
                f"{fleet_path} belongs to plan digest {state.plan_digest}, "
                f"this plan is {plan.digest()}; a changed plan must not "
                "splice into old shards — use --fresh to restart")
        if not resume:
            raise FleetError(
                f"{fleet_path} already exists; use --resume to continue (or "
                "replay) this fleet, or --fresh to restart it")
    grid = plan.grid()
    if state is None:
        state = FleetState(fleet_path, plan.digest(), plan.worker_stores())

    incomplete = _incomplete_shards(plan, grid)
    for i, ss in state.shards.items():
        ss.status = "pending" if i in incomplete else "done"
    state.save()

    launched = list(incomplete)
    if incomplete:
        print(f"== fleet {plan.name!r} [{plan.digest()}]: "
              f"{len(grid)}-pair grid, launching shard(s) "
              f"{incomplete} of {plan.shards}")
        for i in incomplete:
            state.shards[i].status = "running"
            state.shards[i].attempts += 1
        state.save()
        rcs = (launcher or subprocess_launcher)(plan_path, plan, incomplete)
        still = set(_incomplete_shards(plan, grid))
        for i in incomplete:
            ss = state.shards[i]
            ss.returncode = rcs.get(i)
            ss.status = "failed" if i in still else "done"
            wstats = _read_worker_stats(ss.store)
            if wstats:
                ss.measured = wstats.get("measured")
                ss.cached = wstats.get("cached")
        state.save()
        if still:
            codes = {i: rcs.get(i) for i in sorted(still)}
            raise FleetError(
                f"shard(s) {sorted(still)} did not complete (returncodes "
                f"{codes}); completed work is preserved in the worker "
                "stores — re-run with --resume to heal and finish them")
    else:
        print(f"== fleet {plan.name!r} [{plan.digest()}]: all "
              f"{plan.shards} shard slice(s) already complete, "
              "nothing to launch")

    from repro.core import merge_stores

    sources = [ws for ws in plan.worker_stores() if os.path.exists(ws)]
    if sources:
        # the canonical store (when present) streams FIRST so freshly
        # re-measured worker records supersede any stale merged ones
        if os.path.exists(plan.store):
            sources = [plan.store] + sources
        mstats = merge_stores(plan.store, sources)
        state.merge = {"dest": plan.store, "sources": sources,
                       "records_in": mstats.records_in,
                       "records_out": mstats.records_out,
                       "conflicts": sorted(set(map(tuple, mstats.conflicts)))}
        state.merge["conflicts"] = [list(c) for c in
                                    state.merge["conflicts"]]
        print(f"== merge: {mstats}")

    reports, cstats = _classify(plan)
    state.classification = {
        name: {"label": rep.bottleneck.label,
               "confidence": rep.bottleneck.confidence,
               "abs": rep.absorptions()}
        for name, rep in sorted(reports.items())}
    state.stats = {"measured": cstats.measured, "cached": cstats.cached}
    state.save()
    write_report(plan.report_path(), reports)
    print(f"== classification ({plan.report_path()}):")
    for name, rep in sorted(reports.items()):
        print(f"  {name}: {rep.bottleneck}")
    finish_stats(cstats, expect_no_measure)
    return FleetResult(plan=plan, reports=reports, stats=cstats, state=state,
                       launched=launched)

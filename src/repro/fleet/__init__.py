"""Fleet orchestration: one launcher that plans, spawns, merges, classifies.

``SweepPlan`` (plan.py) declares the full grid — regions × modes × kernel
size/q families — and ``run_fleet`` (executor.py) drives it end to end:
spawn N subprocess shards, survive crashes, merge worker stores, classify
from the merged store. ``python -m repro.fleet`` is the CLI.
"""
from repro.fleet.executor import (FleetError, FleetResult, FleetState,  # noqa: F401
                                  in_process_launcher, run_fleet,
                                  run_worker, subprocess_launcher)
from repro.fleet.plan import PlanError, SweepPlan, TargetSpec  # noqa: F401

"""Fleet orchestration: one launcher that plans, spawns, merges, classifies.

``SweepPlan`` (plan.py) declares the full grid — regions × modes × kernel
size/q families — plus, optionally, HOW to distribute it (a launcher spec
and a retry budget). ``run_fleet`` (executor.py) drives it end to end:
spawn N worker shards through a pluggable ``Launcher`` (launchers.py —
local subprocesses, ssh hosts from a hosts.json, or a deterministic
fault-injection mock), retry failed shards within the ``RetryBudget``,
survive crashes, merge worker stores (incrementally, by segment adoption,
when the plan declares ``store_format: "segments"``), classify from the
merged store. ``python -m repro.fleet`` is the CLI
(plan / run / audit / doctor / status / watch).
"""
from repro.fleet.executor import (FleetError, FleetResult, FleetState,  # noqa: F401
                                  fleet_doctor, in_process_launcher,
                                  run_fleet, run_worker,
                                  subprocess_launcher)
from repro.fleet.launchers import (HostSpec, Launcher, LocalLauncher,  # noqa: F401
                                   MockClusterLauncher, RetryBudget,
                                   SSHLauncher, ShardOutcome, load_hosts,
                                   resolve_launcher)
from repro.fleet.plan import PlanError, SweepPlan, TargetSpec  # noqa: F401

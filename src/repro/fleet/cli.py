"""Fleet CLI — build plans, run fleets, inspect fleet state.

    # declare a whole size/q family as one plan (2 subprocess shards)
    PYTHONPATH=src python -m repro.fleet plan --out plan.json \
        --pallas spmxv --sizes 256,512 --qs 0,1 --modes fp,vmem \
        --shards 2 --reps 2 --backend interpret

    # plan -> spawn -> merge -> classify (resumable; stores are ground truth)
    PYTHONPATH=src python -m repro.fleet run --plan plan.json
    PYTHONPATH=src python -m repro.fleet run --plan plan.json --resume
    PYTHONPATH=src python -m repro.fleet run --plan plan.json --resume \
        --expect-no-measure          # assert a completed fleet replays free

    # where is my fleet?
    PYTHONPATH=src python -m repro.fleet status --plan plan.json

Multi-host: run ``python -m repro.launch.probe --plan plan.json --shard i/N``
on each host against a shared filesystem (or copy the worker stores back),
then ``run --resume`` anywhere to merge + classify. docs/orchestration.md
has the full walkthrough.
"""
from __future__ import annotations

import argparse
import os
from typing import Optional, Sequence

CAMPAIGN_DIR = "experiments/campaigns/fleet"


def _csv(text: str, cast) -> list:
    return [cast(p.strip()) for p in text.split(",") if p.strip()]


def _build_plan(args) -> "object":
    from repro.fleet.plan import PlanError, SweepPlan, TargetSpec

    if bool(args.pallas) == bool(args.arch):
        raise SystemExit("plan: give exactly one of --pallas KERNEL or "
                         "--arch ARCH")
    if args.pallas:
        from repro.kernels.region import KERNEL_MODES, SIZE_DEFAULT
        if args.pallas not in KERNEL_MODES:
            raise SystemExit(f"unknown pallas kernel {args.pallas!r}; one of "
                             f"{', '.join(sorted(KERNEL_MODES))}")
        modes = (_csv(args.modes, str) if args.modes
                 else list(KERNEL_MODES[args.pallas]))
        params = {"kernel": args.pallas,
                  "sizes": (_csv(args.sizes, int) if args.sizes
                            else [SIZE_DEFAULT[args.pallas]])}
        if args.qs:
            params["qs"] = _csv(args.qs, float)
        if args.nnz_per_row is not None:
            params["nnz_per_row"] = args.nnz_per_row
        spec = TargetSpec("pallas", tuple(modes), params)
        default_name = f"fleet_{args.pallas}"
    else:
        from repro.launch.probe import DEFAULT_GRAPH_MODES
        modes = (_csv(args.modes, str) if args.modes
                 else list(DEFAULT_GRAPH_MODES))
        spec = TargetSpec("step", tuple(modes),
                          {"arch": args.arch, "kind": args.kind,
                           "seq": args.seq, "batch": args.batch})
        default_name = f"fleet_{args.arch}_{args.kind}"
    name = args.name or default_name
    plan = SweepPlan(name=name,
                     store=args.store or os.path.join(CAMPAIGN_DIR,
                                                      f"{name}.jsonl"),
                     targets=[spec], reps=args.reps, shards=args.shards,
                     workers=args.workers,
                     compile_once=not args.no_compile_once,
                     backend=args.backend)
    try:
        plan.validate()
    except PlanError as e:
        raise SystemExit(f"plan: {e}")
    return plan


def _cmd_plan(args) -> int:
    from repro.fleet.plan import PlanError

    plan = _build_plan(args)
    try:
        grid = plan.grid()       # reject (e.g. duplicate pairs) BEFORE the
    except PlanError as e:       # invalid plan file lands on disk
        raise SystemExit(f"plan: {e}")
    plan.save(args.out)
    print(f"wrote plan {plan.name!r} [{plan.digest()}] -> {args.out}")
    print(f"  {len(grid)} (region, mode) pair(s) over {plan.shards} "
          f"shard(s); store: {plan.store}")
    for r, m in grid:
        print(f"    {r}/{m}")
    print(f"run it:   PYTHONPATH=src python -m repro.fleet run "
          f"--plan {args.out}")
    return 0


def _cmd_run(args) -> int:
    from repro.fleet.executor import (FleetError, in_process_launcher,
                                      run_fleet)

    try:
        res = run_fleet(args.plan, resume=args.resume, fresh=args.fresh,
                        expect_no_measure=args.expect_no_measure,
                        launcher=(in_process_launcher if args.in_process
                                  else None))
    except FleetError as e:
        raise SystemExit(f"fleet: {e}")
    print(f"fleet {res.plan.name!r} complete: {len(res.reports)} region(s) "
          f"classified, shard(s) launched this run: "
          f"{res.launched or 'none'}")
    return 0


def _cmd_status(args) -> int:
    from repro.core import CampaignStore
    from repro.fleet.executor import FleetState
    from repro.fleet.plan import SweepPlan

    plan = SweepPlan.load(args.plan)
    grid = plan.grid()
    print(f"plan {plan.name!r} [{plan.digest()}]: {len(grid)} pair(s), "
          f"{plan.shards} shard(s), store {plan.store}")
    fleet_path = plan.fleet_path()
    if os.path.exists(fleet_path):
        state = FleetState.load(fleet_path)
        tag = ("" if state.plan_digest == plan.digest()
               else f" (STALE: fleet built by {state.plan_digest})")
        print(f"fleet state {fleet_path}{tag}:")
        for i, ss in sorted(state.shards.items()):
            extra = ""
            if ss.measured is not None:
                extra = f", {ss.measured} measured / {ss.cached} replayed"
            print(f"  shard {i}: {ss.status} (attempts={ss.attempts}"
                  f"{extra})")
        if state.classification:
            for name, c in sorted(state.classification.items()):
                print(f"  {name}: {c['label']} ({c['confidence']})")
    else:
        print(f"fleet state {fleet_path}: not created yet")
    incomplete_pairs = 0
    if os.path.exists(plan.store):
        st = CampaignStore(plan.store, readonly=True)
        status = st.grid_status(grid)
        incomplete_pairs = sum(not ps.complete for ps in status.values())
        print(f"canonical store: {len(grid) - incomplete_pairs}/{len(grid)} "
              "pair(s) complete")
    else:
        incomplete_pairs = len(grid)
        print("canonical store: absent")
    for i in range(plan.shards):
        ws = plan.worker_stores()[i]
        mine = grid[i::plan.shards]
        if not os.path.exists(ws):
            print(f"  worker store {i}: absent ({len(mine)} pair slice)")
            continue
        st = CampaignStore(ws, readonly=True)
        done = sum(ps.complete for ps in st.grid_status(mine).values())
        print(f"  worker store {i}: {done}/{len(mine)} slice pair(s) "
              "complete")
    return 1 if incomplete_pairs else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="fleet orchestrator: plan, spawn, merge, classify")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pp = sub.add_parser("plan", help="build a SweepPlan JSON")
    pp.add_argument("--out", required=True, help="plan JSON path to write")
    pp.add_argument("--name", default=None)
    pp.add_argument("--store", default=None,
                    help=f"campaign store (default: under {CAMPAIGN_DIR}/)")
    pp.add_argument("--pallas", default=None, metavar="KERNEL",
                    help="pallas kernel family target "
                         "(matmul|spmxv|attention|probe)")
    pp.add_argument("--sizes", default=None,
                    help="comma list for the kernel's size knob "
                         "(rows / seq / grid steps)")
    pp.add_argument("--qs", default=None,
                    help="comma list of swap probabilities (spmxv only)")
    pp.add_argument("--nnz-per-row", type=int, default=None,
                    help="spmxv nonzeros per row")
    pp.add_argument("--arch", default=None,
                    help="model-step target architecture")
    pp.add_argument("--kind", default="train", choices=("train", "decode"))
    pp.add_argument("--seq", type=int, default=128)
    pp.add_argument("--batch", type=int, default=4)
    pp.add_argument("--modes", default=None,
                    help="comma list (default: the target's full mode set)")
    pp.add_argument("--reps", type=int, default=2)
    pp.add_argument("--shards", type=int, default=2)
    pp.add_argument("--workers", type=int, default=1,
                    help="threads per shard")
    pp.add_argument("--backend", default="auto",
                    choices=("auto", "interpret", "pallas"))
    pp.add_argument("--no-compile-once", action="store_true")
    pp.set_defaults(fn=_cmd_plan)

    rp = sub.add_parser("run", help="plan -> spawn shards -> merge -> "
                                    "classify (resumable)")
    rp.add_argument("--plan", required=True)
    rp.add_argument("--resume", action="store_true",
                    help="continue an existing fleet: re-launch only "
                         "incomplete shards; a complete fleet replays with "
                         "zero new measurements")
    rp.add_argument("--fresh", action="store_true",
                    help="delete this plan's stores and fleet state first")
    rp.add_argument("--expect-no-measure", action="store_true",
                    help="exit non-zero if the finalize replay had to "
                         "measure anything")
    rp.add_argument("--in-process", action="store_true",
                    help="run shards sequentially in this process instead "
                         "of spawning subprocesses")
    rp.set_defaults(fn=_cmd_run)

    sp = sub.add_parser("status", help="show fleet/shard/store completeness "
                                       "(exit 1 while incomplete)")
    sp.add_argument("--plan", required=True)
    sp.set_defaults(fn=_cmd_status)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
